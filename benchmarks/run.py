"""Benchmark harness: one function per paper table/figure.

Each benchmark prints CSV rows ``name,us_per_call,derived``:

* ``us_per_call`` -- measured wall time of the functional simulator /
  kernels on this machine (CPU; interpret-mode Pallas);
* ``derived``     -- the paper-comparable figure from the ZN540-calibrated
  performance model (MiB/s, seconds, ...), reproducing the paper's trends
  (the hardware itself is not available here; see DESIGN.md §7).

Besides the CSV on stdout, sweeps write a machine-readable JSON file mapping
each benchmark name to its measured ``us_per_call`` and ``derived`` figure,
so the perf trajectory can be tracked across PRs.  Each command maps to its
own file so no sweep clobbers another's baseline: ``--quick`` (small shapes,
cheap subset, carries the perf acceptance figures) writes the committed
``BENCH_PR10.json``; full runs write ``BENCH_FULL.json``; ``--only`` sweeps
skip the JSON unless ``--json PATH`` is given explicitly.  ``--check
BENCH_PR10.json`` is the CI regression gate: it reruns the quick set and
fails on a >25% wall-clock regression against the committed baseline
(virtual-time ``service/*`` rows gate unscaled -- they are deterministic).

Timed scenarios (``exp10/trace_timed_*``, ``qos/*``) run on the
discrete-event engine (``repro.sim``): their ``us_per_call`` column is a
*virtual-time latency percentile* from the ZN540-calibrated device model,
not host wall time.

Run: PYTHONPATH=src python -m benchmarks.run [--only NAME] [--quick]
     [--json PATH] [--check BASELINE.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

ROWS: list[tuple[str, float, str]] = []
QUICK = False  # set by --quick: small shapes / fewer iterations


def emit(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}", flush=True)


def _timeit(fn, n=3):
    fn()  # warmup
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def _timeit_min(fn, n=5):
    """Best-of-n wall time: estimates the code's cost, not the machine's
    load -- the statistic the --check regression gate compares."""
    fn()  # warmup
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


# ---------------------------------------------------------------- Fig. 2

def bench_zns_primitives():
    """Figure 2: Zone Write vs Zone Append vs open zones & request size."""
    from repro.core import perfmodel as pm

    for size in (4, 8, 16):
        for zones in (1, 2, 4, 6, 8):
            zw = pm.zone_write_tput(size, zones)
            za = pm.zone_append_tput(size, 4, zones)
            emit(f"fig2/zw_{size}k_z{zones}", 0.0, f"{zw:.1f}MiB/s")
            emit(f"fig2/za_{size}k_z{zones}", 0.0, f"{za:.1f}MiB/s")


# ---------------------------------------------------------------- Exp#1

def bench_write():
    """Exp#1 (Fig. 6): single-open-segment write performance."""
    from repro.core import perfmodel as pm
    from repro.core.array import ZapRaidConfig, ZapRAIDArray
    from repro.core.zns import ZnsConfig

    rng = np.random.default_rng(0)
    for chunk_k in (4, 8, 16):
        za = pm.zapraid_write_perf(k=3, m=1, chunk_kib=chunk_k, group_size=256)
        zw = pm.zapraid_write_perf(k=3, m=1, chunk_kib=chunk_k, group_size=1,
                                   use_append=False)
        zaonly = pm.zapraid_write_perf(k=3, m=1, chunk_kib=chunk_k,
                                       group_size=1 << 19)
        # functional-sim wall time for the same pattern (metadata cost)
        cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=16,
                            chunk_blocks=1, logical_blocks=512,
                            gc_free_segments_low=1)
        zns = ZnsConfig(n_zones=16, zone_cap_blocks=128, block_bytes=256)
        arr = ZapRAIDArray(cfg, zns)
        blk = rng.integers(0, 256, (1, 256), dtype=np.uint8)

        def wr():
            for i in range(32):
                arr.write(int(rng.integers(0, 512)), blk)
            arr.flush()

        us = _timeit(wr, n=2)
        emit(f"exp1/zapraid_{chunk_k}k", us / 32,
             f"{za.throughput_mib_s:.0f}MiB/s_p50={za.median_lat_us:.0f}us")
        emit(f"exp1/zwonly_{chunk_k}k", 0.0, f"{zw.throughput_mib_s:.0f}MiB/s")
        emit(f"exp1/zaonly_{chunk_k}k", 0.0, f"{zaonly.throughput_mib_s:.0f}MiB/s")


# ---------------------------------------------------------------- Exp#2

def bench_reads():
    """Exp#2 (Fig. 7): normal vs degraded reads (functional sim, measured)."""
    from repro.core.array import ZapRaidConfig, ZapRAIDArray
    from repro.core import perfmodel as pm
    from repro.core.zns import ZnsConfig

    rng = np.random.default_rng(1)
    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=16,
                        chunk_blocks=1, logical_blocks=256,
                        gc_free_segments_low=1)
    zns = ZnsConfig(n_zones=12, zone_cap_blocks=128, block_bytes=256)
    arr = ZapRAIDArray(cfg, zns)
    for lba in range(256):
        arr.write(lba, rng.integers(0, 256, (1, 256), dtype=np.uint8))
    arr.flush()
    lbas = rng.integers(0, 256, 64)
    us_nr = _timeit(lambda: [arr.read(int(l), 1) for l in lbas]) / 64
    arr.fail_drive(1)
    us_dr = _timeit(lambda: [arr.read(int(l), 1) for l in lbas]) / 64
    emit("exp2/normal_read", us_nr, "paper~82us@4k")
    emit("exp2/degraded_read_zapraid", us_dr,
         f"model={pm.degraded_read_latency_us(k=3, chunk_kib=4, group_size=256):.0f}us")


# ---------------------------------------------------------------- Exp#3

def bench_group_size():
    """Exp#3 (Fig. 8): stripe-group size sweep -- write tput + degraded-read
    latency + CST memory."""
    from repro.core import perfmodel as pm
    from repro.core.group_layout import CompactStripeTable

    for g in (4, 16, 64, 256, 1024, 4096):
        p = pm.zapraid_write_perf(k=3, m=1, chunk_kib=4, group_size=g)
        d = pm.degraded_read_latency_us(k=3, chunk_kib=4, group_size=g)
        cst = CompactStripeTable(4, 274366, g)
        emit(f"exp3/g{g}", 0.0,
             f"{p.throughput_mib_s:.0f}MiB/s_dr={d:.0f}us_cst={cst.memory_bytes()//1024}KiB")


# ---------------------------------------------------------------- Exp#4

def bench_raid_schemes():
    """Exp#4 (Fig. 9): RAID-0/01/4/5/6 write throughput, ZapRAID vs ZW-only."""
    from repro.core import perfmodel as pm
    from repro.core.raid import make_scheme

    for name in ("raid0", "raid01", "raid4", "raid5", "raid6"):
        s = make_scheme(name, 4)
        za = pm.zapraid_write_perf(k=s.k, m=s.m, chunk_kib=4, group_size=256)
        zw = pm.zapraid_write_perf(k=s.k, m=s.m, chunk_kib=4, group_size=1,
                                   use_append=False)
        gain = za.throughput_mib_s / zw.throughput_mib_s - 1
        emit(f"exp4/{name}", 0.0,
             f"zap={za.throughput_mib_s:.0f}MiB/s_zw={zw.throughput_mib_s:.0f}MiB/s_gain={gain*100:.0f}%")


# ---------------------------------------------------------------- Exp#5

def bench_recovery():
    """Exp#5 (Fig. 10): crash + full-drive recovery vs logical space."""
    from repro.core import perfmodel as pm
    from repro.core.array import ZapRaidConfig, ZapRAIDArray
    from repro.core.recovery import recover_array
    from repro.core.zns import ZnsConfig

    rng = np.random.default_rng(2)
    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=16,
                        chunk_blocks=1, logical_blocks=256,
                        gc_free_segments_low=1)
    zns = ZnsConfig(n_zones=12, zone_cap_blocks=128, block_bytes=256)
    arr = ZapRAIDArray(cfg, zns)
    for lba in range(256):
        arr.write(lba, rng.integers(0, 256, (1, 256), dtype=np.uint8))
    arr.flush()
    t0 = time.perf_counter()
    arr2 = recover_array(arr.drives, cfg, zns)
    us_cr = (time.perf_counter() - t0) * 1e6
    blocks_read = arr2.stats.recovery_blocks_read
    t0 = time.perf_counter()
    arr2.fail_drive(0)
    arr2.rebuild_drive(0)
    us_fr = (time.perf_counter() - t0) * 1e6
    for gib in (100, 500, 1000):
        emit(f"exp5/crash_{gib}gib", us_cr,
             f"model={pm.crash_recovery_time_s(logical_gib=gib, chunk_kib=4):.2f}s")
        emit(f"exp5/fulldrive_{gib}gib", us_fr,
             f"model={pm.full_drive_recovery_time_s(logical_gib=gib, k=3, chunk_kib=4):.0f}s")
    emit("exp5/recovery_blocks_read", 0.0, f"{blocks_read}blocks")


# ---------------------------------------------------------------- Exp#7

def bench_hybrid():
    """Exp#7 (Figs. 12-13): multiple open segments / hybrid management."""
    from repro.core import perfmodel as pm

    for (ns, nl) in ((4, 0), (3, 1), (2, 2), (1, 3), (0, 4)):
        for frac_small, wname in ((1.0, "4k"), (0.0, "16k"), (0.75, "mix")):
            p = pm.hybrid_write_perf(k=3, m=1, cs_kib=8, cl_kib=16,
                                     n_small=ns, n_large=nl,
                                     frac_small=frac_small, group_size=256)
            emit(f"exp7/ns{ns}_nl{nl}_{wname}", 0.0,
                 f"{p.throughput_mib_s:.0f}MiB/s_p95={p.p95_lat_us:.0f}us")


# ---------------------------------------------------------------- Exp#8

def bench_gc():
    """Exp#8 (Fig. 14): GC overhead vs reserved space (functional WA)."""
    from repro.core.array import ZapRaidConfig, ZapRAIDArray
    from repro.core.zns import ZnsConfig

    rng = np.random.default_rng(3)
    for zones, label in ((6, "tight_20pct"), (8, "mid_50pct"), (12, "ample_100pct")):
        cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=8,
                            chunk_blocks=1, logical_blocks=96,
                            gc_free_segments_low=2)
        zns = ZnsConfig(n_zones=zones, zone_cap_blocks=64, block_bytes=256)
        arr = ZapRAIDArray(cfg, zns)
        t0 = time.perf_counter()
        for _ in range(1200):
            arr.write(int(rng.integers(0, 96)),
                      rng.integers(0, 256, (1, 256), dtype=np.uint8))
        arr.flush()
        us = (time.perf_counter() - t0) * 1e6 / 1200
        emit(f"exp8/{label}", us,
             f"WA={arr.stats.write_amp():.2f}_gc={arr.stats.gc_runs}")


# ---------------------------------------------------------------- Exp#9

def bench_l2p_offload():
    """Exp#9 (Fig. 15): L2P memory cap sweep (miss/eviction rates)."""
    from repro.core.array import ZapRaidConfig, ZapRAIDArray
    from repro.core.zns import ZnsConfig

    rng = np.random.default_rng(4)
    for limit, label in ((None, "full"), (256, "half"), (128, "quarter")):
        cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=8,
                            chunk_blocks=1, logical_blocks=512,
                            gc_free_segments_low=1,
                            l2p_memory_limit_entries=limit)
        zns = ZnsConfig(n_zones=24, zone_cap_blocks=64, block_bytes=256)
        arr = ZapRAIDArray(cfg, zns)
        t0 = time.perf_counter()
        for _ in range(800):
            arr.write(int(rng.integers(0, 512)),
                      rng.integers(0, 256, (1, 256), dtype=np.uint8))
        arr.flush()
        us = (time.perf_counter() - t0) * 1e6 / 800
        ev = getattr(arr.l2p, "evictions", 0)
        emit(f"exp9/{label}", us,
             f"evictions={ev}_meta_blocks={arr.stats.meta_blocks_written}")


# --------------------------------------------------------------- Exp#10

def bench_trace():
    """Exp#10: cloud-block-storage-like trace (60% <=4K, 25% >=16K writes),
    replayed through the discrete-event timed pipeline (repro.sim): the same
    mixed workload now reports measured p50/p99 latency from the ZN540
    device model alongside the analytic throughput comparison."""
    from repro.core import perfmodel as pm
    from repro.core.array import ZapRaidConfig
    from repro.core.handlers import HandlerPipeline
    from repro.core.zns import ZnsConfig
    from repro.sim import Request

    rng = np.random.default_rng(5)
    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, hybrid=True,
                        n_small=1, n_large=3, group_size=8,
                        small_chunk_blocks=1, large_chunk_blocks=2,
                        logical_blocks=256, gc_free_segments_low=1)
    zns = ZnsConfig(n_zones=20, zone_cap_blocks=64, block_bytes=256)
    pipe = HandlerPipeline.build_timed(cfg, zns, seed=5)
    pipe.precondition(
        (lba, rng.integers(0, 256, (1, 256), dtype=np.uint8))
        for lba in range(256)
    )
    n_ops = 400 if QUICK else 600
    reqs, t = [], 0.0
    for _ in range(n_ops):
        t += float(rng.exponential(40.0))  # ~25k IOPS open-loop arrivals
        r = rng.random()
        n = 1 if r < 0.60 else (2 if r < 0.75 else 3)
        lba = int(rng.integers(0, 256 - n))
        op = "W" if rng.random() < 0.85 else "R"
        reqs.append(Request(t, "trace", op, lba, n))
    rec = pipe.replay(reqs)
    for name, lat_us, derived in rec.to_bench_rows("exp10/trace_timed"):
        emit(name, lat_us, derived)
    # us column: mean virtual time per op (deterministic), not host wall time
    emit("exp10/trace_timed_tput", rec.span_us() / n_ops,
         f"{rec.throughput_mib_s(256):.1f}MiB/s_sim")
    zap = pm.hybrid_write_perf(k=3, m=1, cs_kib=8, cl_kib=16, n_small=1,
                               n_large=3, frac_small=0.75, group_size=256)
    zw = pm.hybrid_write_perf(k=3, m=1, cs_kib=8, cl_kib=16, n_small=1,
                              n_large=3, frac_small=0.75, group_size=1)
    emit("exp10/trace_model", 0.0,
         f"zap={zap.throughput_mib_s:.0f}MiB/s_zw={zw.throughput_mib_s:.0f}MiB/s"
         f"_gain={100*(zap.throughput_mib_s/zw.throughput_mib_s-1):.0f}%")


# ------------------------------------------------- latency QoS (timed engine)

def bench_latency_qos():
    """Latency QoS on the timed engine, three scenario families:

    * multi-tenant fairness -- a bursty hotspot writer next to a uniform
      reader on a healthy array (per-tenant p50/p99);
    * degraded reads under load -- the same read load replayed healthy vs
      with one failed drive: reads landing on the failed drive pay k
      survivor reads + decode and queue behind the scan traffic (the
      paper's Fig. 7 gap, now as a measured tail);
    * recovery under load -- the read load with a full-drive rebuild
      running as an engine actor contending for device time.
    """
    from repro.core.array import ZapRaidConfig
    from repro.core.handlers import HandlerPipeline
    from repro.core.zns import ZnsConfig
    from repro.sim import TenantSpec, multi_tenant

    n_ops = 300 if QUICK else 800

    def make_pipe():
        rng = np.random.default_rng(11)
        cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=8,
                            chunk_blocks=1, logical_blocks=256,
                            gc_free_segments_low=1)
        zns = ZnsConfig(n_zones=16, zone_cap_blocks=64, block_bytes=256)
        pipe = HandlerPipeline.build_timed(cfg, zns, seed=11)
        pipe.precondition(
            (lba, rng.integers(0, 256, (1, 256), dtype=np.uint8))
            for lba in range(256)
        )
        return pipe

    # heavy read load: ~90k IOPS across 4 drives pushes the survivors toward
    # saturation once every failed-drive read fans out into k survivor reads
    read_load = multi_tenant([
        TenantSpec(name="scanner", kind="seq", n_ops=n_ops,
                   rate_iops=60_000, read_frac=1.0, seed=31),
        TenantSpec(name="reader", kind="uniform", n_ops=n_ops,
                   rate_iops=30_000, read_frac=1.0, seed=32),
    ], logical_blocks=256)

    # multi-tenant fairness (healthy, mixed read/write)
    # the writer's ON bursts (~240k IOPS) fill stripe groups faster than the
    # append queues drain them, so inter-group barriers genuinely bind
    pipe = make_pipe()
    mixed = pipe.replay(multi_tenant([
        TenantSpec(name="writer", kind="hotspot", n_ops=n_ops,
                   rate_iops=80_000, burst_factor=3.0, seed=21),
        TenantSpec(name="reader", kind="uniform", n_ops=n_ops,
                   rate_iops=12_000, read_frac=1.0, seed=22),
    ], logical_blocks=256))
    for tenant, op in (("writer", "W"), ("reader", "R")):
        p = mixed.percentiles(op=op, tenant=tenant)
        emit(f"qos/tenant_{tenant}_p99", p.get("p99", 0.0),
             f"n={p.get('n', 0)}_p50={p.get('p50', 0.0):.1f}us")
    barrier = mixed.notes.get("group_barrier_wait_us", 0.0)
    emit("qos/group_barrier_wait", 0.0,
         f"total={barrier:.0f}us_groups={mixed.note_counts.get('group_barrier_wait_us', 0)}")

    # degraded reads under load (same load, healthy vs one failed drive)
    healthy = make_pipe().replay(read_load)
    pipe = make_pipe()
    pipe.array.fail_drive(1)
    degraded = pipe.replay(read_load)
    h_r = healthy.percentiles(op="R")
    d_r = degraded.percentiles(op="R")
    emit("qos/healthy_read_p50", h_r["p50"],
         f"p99={h_r['p99']:.1f}us_p999={h_r['p999']:.1f}us")
    emit("qos/degraded_read_p50", d_r["p50"],
         f"p99={d_r['p99']:.1f}us_p999={d_r['p999']:.1f}us")
    emit("qos/degraded_tail_inflation", 0.0,
         f"p99_ratio={d_r['p99'] / max(h_r['p99'], 1e-9):.2f}x_vs_healthy")

    # recovery under load: rebuild actor contends with the read load
    pipe = make_pipe()
    pipe.array.fail_drive(1)
    pipe.schedule_rebuild(1, at=50.0)
    rebuild = pipe.replay(read_load)
    r_r = rebuild.percentiles(op="R")
    emit("qos/rebuild_read_p50", r_r["p50"],
         f"p99={r_r['p99']:.1f}us_rebuild_busy="
         f"{rebuild.notes.get('rebuild_device_us', 0.0):.0f}us")


# ------------------------------------------------------- batched datapath

def bench_e2e_write():
    """Sequential-write microbenchmark: whole-group fused encode + vectorized
    staging (``batched=True``, this PR) vs the per-block/per-stripe legacy
    path, at the paper's default group size G=256 (DESIGN.md §2-3)."""
    from repro.core.array import ZapRaidConfig, ZapRAIDArray
    from repro.core.zns import ZnsConfig

    n_blocks = 1024 if QUICK else 2048
    bb = 512
    rng = np.random.default_rng(13)
    data = rng.integers(0, 256, (n_blocks, bb), dtype=np.uint8)

    def run(batched: bool) -> float:
        cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=256,
                            chunk_blocks=1, logical_blocks=8192,
                            gc_free_segments_low=1, batched=batched)
        zns = ZnsConfig(n_zones=16, zone_cap_blocks=2048, block_bytes=bb)
        arr = ZapRAIDArray(cfg, zns)
        t0 = time.perf_counter()
        arr.write(0, data)
        arr.flush()
        return (time.perf_counter() - t0) / n_blocks * 1e6

    run(True)  # warm the jit/XLA caches so both modes pay compile once
    run(False)
    # best-of-3: the batched row feeds the --check regression gate, so
    # estimate code cost rather than transient machine load
    us_b = min(run(True) for _ in range(3))
    us_l = min(run(False) for _ in range(3))
    mib_s = bb / us_b * 1e6 / (1 << 20)
    emit("e2e/seq_write_batched_g256", us_b, f"{mib_s:.0f}MiB/s_sim")
    emit("e2e/seq_write_legacy_g256", us_l, "per_stripe_encode")
    emit("e2e/seq_write_speedup_g256", 0.0, f"{us_l / us_b:.1f}x")


def bench_read_batched():
    """Batched read path (this PR): healthy gather reads and grouped
    degraded reads (one fused decode per surviving-role set) vs the
    per-stripe/per-block baseline, plus host<->device copy accounting."""
    from repro.core.array import ZapRaidConfig, ZapRAIDArray
    from repro.core.zns import ZnsConfig

    n_blocks = 512 if QUICK else 1024
    bb = 512
    rng = np.random.default_rng(17)
    data = rng.integers(0, 256, (n_blocks, bb), dtype=np.uint8)

    def mk(batched):
        cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=64,
                            chunk_blocks=1, logical_blocks=4096,
                            gc_free_segments_low=1, batched=batched)
        zns = ZnsConfig(n_zones=16, zone_cap_blocks=1024, block_bytes=bb)
        arr = ZapRAIDArray(cfg, zns)
        arr.write(0, data)
        arr.flush()
        return arr

    ab = mk(True)
    al = mk(False)
    # healthy: one vectorized read vs a per-block loop
    us_b = _timeit_min(lambda: ab.read(0, n_blocks)) / n_blocks
    us_l = _timeit_min(lambda: [al.read(i, 1) for i in range(n_blocks)]) / n_blocks
    emit("read/healthy_batched", us_b, f"{us_l / us_b:.1f}x_vs_per_block")
    # degraded: grouped reconstruction vs per-block chunk decode
    ab.fail_drive(1)
    al.fail_drive(1)
    us_db = _timeit_min(lambda: ab.read(0, n_blocks)) / n_blocks
    us_dl = _timeit_min(lambda: [al.read(i, 1) for i in range(n_blocks)]) / n_blocks
    emit("read/degraded_batched", us_db, f"{us_dl / us_db:.1f}x_vs_per_stripe")
    emit("read/degraded_per_stripe", us_dl, "per_block_decode_baseline")
    s = ab.stats
    emit("read/h2d_copies", 0.0,
         f"h2d={s.h2d_copies}x{s.h2d_bytes // max(s.h2d_copies, 1)}B"
         f"_d2h={s.d2h_copies}x{s.d2h_bytes // max(s.d2h_copies, 1)}B")


def bench_kernels_batched():
    """Group-level kernel dispatch: one fused (S, k, n) call vs S per-stripe
    calls for XOR parity and GF(256) RS encode."""
    import jax.numpy as jnp
    from repro.kernels import ops

    s_count = 32 if QUICK else 64
    n = 4096 if QUICK else 16384
    rng = np.random.default_rng(14)
    data = jnp.asarray(
        rng.integers(0, 2**31, (s_count, 3, n), dtype=np.int64), jnp.int32
    )

    def per_stripe_xor():
        for s in range(s_count):
            ops.xor_parity(data[s]).block_until_ready()

    def per_stripe_rs():
        for s in range(s_count):
            ops.rs_encode(data[s], 2).block_until_ready()

    us_b = _timeit(lambda: ops.xor_parity_batch(data).block_until_ready())
    us_l = _timeit(per_stripe_xor)
    emit(f"kernels/parity_xor_batch_S{s_count}", us_b, f"{us_l / us_b:.1f}x_vs_loop")
    us_b = _timeit(lambda: ops.rs_encode_batch(data, 2).block_until_ready())
    us_l = _timeit(per_stripe_rs)
    emit(f"kernels/rs_encode_batch_S{s_count}", us_b, f"{us_l / us_b:.1f}x_vs_loop")


# ------------------------------------------- GC / recovery pipelines (PR 5)

def _aged_shape(n_zones, zone_cap, bb=256, k=3):
    """(logical, n_writes): sequential-wraparound churn sized so the oldest
    sealed segment ends ~50% live (GC genuinely moves blocks) while the open
    segment keeps a restage-sized slack (no zone exhaustion, GC disabled)."""
    from repro.core.segment import solve_stripes_per_segment

    s, _ = solve_stripes_per_segment(zone_cap, 1, bb)
    seg_cap = k * s
    # manual-GC arrays (gc_free_segments_low=0) escrow one zone per drive as
    # the guaranteed restage destination, so only n_zones-1 are writable
    cap = (n_zones - 1) * seg_cap
    n_writes = int(cap - 0.55 * seg_cap)
    logical = int(n_writes - 0.5 * seg_cap)
    return logical, n_writes


def _aged_array(batched, *, n_zones, zone_cap, logical, n_writes, bb=256,
                seed=31, gc_low=0):
    """Sequential-wraparound churn leaves the oldest sealed segments
    partially live, so a GC pass genuinely moves blocks."""
    from repro.core.array import ZapRaidConfig, ZapRAIDArray
    from repro.core.zns import ZnsConfig

    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=64,
                        chunk_blocks=1, logical_blocks=logical,
                        gc_free_segments_low=gc_low, batched=batched)
    zns = ZnsConfig(n_zones=n_zones, zone_cap_blocks=zone_cap, block_bytes=bb)
    arr = ZapRAIDArray(cfg, zns)
    rng = np.random.default_rng(seed)
    run = 24  # multi-block writes keep construction cheap in both modes
    i = 0
    while i < n_writes:
        lba = i % logical
        n = min(run, logical - lba, n_writes - i)
        arr.write(lba, rng.integers(0, 256, (n, bb), dtype=np.uint8))
        i += n
    arr.flush()
    return arr, cfg, zns


def bench_gc_pipeline():
    """GC throughput: the vectorized collection/restage pipeline (one gather
    + OOB read per drive, mask liveness, bulk arena restage) vs the scalar
    per-block baseline, plus foreground write p99 under GC pressure with the
    rate-limited background-GC actor on the timed engine."""
    zone_cap = 448 if QUICK else 576
    logical, n_writes = _aged_shape(6, zone_cap)

    def gc_pass(batched):
        best = float("inf")
        moved = 0
        for _ in range(3):  # iteration 1 warms the XLA cache; min() is warm
            arr, _, _ = _aged_array(batched, n_zones=6, zone_cap=zone_cap,
                                    logical=logical, n_writes=n_writes)
            before = arr.stats.gc_blocks_moved
            t0 = time.perf_counter()
            arr.gc_once()
            best = min(best, time.perf_counter() - t0)
            moved = arr.stats.gc_blocks_moved - before
        return best * 1e6, moved

    us_b, moved_b = gc_pass(True)
    us_s, moved_s = gc_pass(False)
    assert moved_b == moved_s and moved_b > 0, (moved_b, moved_s)
    emit("gc/batched_once", us_b, f"{moved_b}blocks_moved")
    emit("gc/scalar_once", us_s, f"{moved_s}blocks_moved")
    emit("gc/speedup", 0.0, f"{us_s / us_b:.1f}x_batched_vs_scalar")

    # timed mode: foreground write p99 with inline GC bursts vs the paced
    # proactive background-GC actor (same load, same device model)
    from repro.core.array import ZapRaidConfig
    from repro.core.handlers import HandlerPipeline
    from repro.core.zns import ZnsConfig
    from repro.sim import TenantSpec, multi_tenant

    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=8,
                        chunk_blocks=1, logical_blocks=360,
                        gc_free_segments_low=1)
    zns = ZnsConfig(n_zones=7, zone_cap_blocks=64, block_bytes=256)

    def make_pipe():
        rng = np.random.default_rng(11)
        pipe = HandlerPipeline.build_timed(cfg, zns, seed=11)
        pipe.precondition(
            (i % 360, rng.integers(0, 256, (1, 256), dtype=np.uint8))
            for i in range(900)
        )
        return pipe

    # enough write churn that GC pressure recurs inside the measured window
    load = multi_tenant([
        TenantSpec(name="writer", kind="seq", n_ops=500, rate_iops=50_000,
                   seed=41),
        TenantSpec(name="reader", kind="uniform", n_ops=300,
                   rate_iops=20_000, read_frac=1.0, seed=42),
    ], logical_blocks=360)

    inline = make_pipe().replay(load)
    pipe = make_pipe()
    pipe.schedule_gc(at=5.0, interval_us=300.0, n_ticks=200)
    actor = pipe.replay(load)
    p_i = inline.percentiles(op="W")["p99"]
    p_a = actor.percentiles(op="W")["p99"]
    emit("gc/p99_inline_bursts", p_i, "write_p99_us_sim")
    emit("gc/p99_under_paced_gc", p_a,
         f"{p_i / max(p_a, 1e-9):.2f}x_better_gc_busy="
         f"{actor.notes.get('gc_device_us', 0.0):.0f}us")


def bench_recovery_pipeline():
    """Crash-recovery scan time: batched header gather + vectorized OOB
    scan/harvest/install vs the per-chunk/per-block scalar scanner, on the
    same media image (a mix of sealed and open segments)."""
    import dataclasses as _dc

    from repro.core.recovery import recover_array

    zone_cap = 512 if QUICK else 640
    n_zones = 8
    # _aged_shape stops the churn mid-segment: the open-OOB-scan path runs
    logical, n_writes = _aged_shape(n_zones, zone_cap)

    def recover(batched):
        best = float("inf")
        blocks = 0
        for _ in range(2):
            arr, cfg, zns = _aged_array(True, n_zones=n_zones,
                                        zone_cap=zone_cap, logical=logical,
                                        n_writes=n_writes)
            rcfg = _dc.replace(cfg, batched=batched)
            t0 = time.perf_counter()
            arr2 = recover_array(arr.drives, rcfg, zns)
            best = min(best, time.perf_counter() - t0)
            blocks = arr2.stats.recovery_blocks_read
        return best * 1e6, blocks

    us_b, blocks_b = recover(True)
    us_s, blocks_s = recover(False)
    assert blocks_b == blocks_s, (blocks_b, blocks_s)
    emit("recovery/batched", us_b, f"{blocks_b}blocks_read")
    emit("recovery/scalar", us_s, f"{blocks_s}blocks_read")
    emit("recovery/speedup", 0.0, f"{us_s / us_b:.1f}x_batched_vs_scalar")


# ------------------------------------------------------------- kernels

def bench_kernels():
    """Kernel microbenchmarks (interpret mode: correctness-path timing)."""
    import jax.numpy as jnp
    from repro.kernels import ops

    rng = np.random.default_rng(6)
    data = jnp.asarray(rng.integers(0, 2**31, (3, 65536), dtype=np.int64), jnp.int32)
    us = _timeit(lambda: ops.xor_parity(data).block_until_ready())
    emit("kernels/parity_xor_256KiB", us, f"{3*65536*4/1e3:.0f}KB_in")
    us = _timeit(lambda: ops.rs_encode(data, 2).block_until_ready())
    emit("kernels/rs_encode_m2_256KiB", us, "gf256_swar")
    x = jnp.asarray(rng.standard_normal((4, 512, 64)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (4, 512)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2, (4,)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((4, 512, 32)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((4, 512, 32)), jnp.float32)
    us = _timeit(lambda: ops.ssd_chunk_scan(x, dt, a, b, c, chunk=128)[0].block_until_ready())
    emit("kernels/ssd_scan_4x512", us, "pallas_interpret")


# ----------------------------------------------------------- checkpoint

def bench_checkpoint():
    """Checkpoint engine: save/restore/degraded-restore throughput."""
    import jax.numpy as jnp
    from repro.checkpoint.zapraid_ckpt import CheckpointConfig, CheckpointEngine

    rng = np.random.default_rng(7)
    state = {"w": jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)}
    nbytes = 256 * 256 * 4
    eng = CheckpointEngine(
        CheckpointConfig(n_lanes=4, group_size=8, block_bytes=4096,
                         zone_cap_blocks=512, n_zones=64, chunk_blocks=2),
        logical_blocks=1 << 13,
    )
    step = [0]

    def save():
        step[0] += 1
        eng.save(step[0], state)

    us = _timeit(save, n=2)
    emit("ckpt/save_256KiB", us, f"{nbytes/us:.1f}MB/s_sim")
    last = max(eng.catalog)
    us = _timeit(lambda: eng.restore(last, state), n=2)
    emit("ckpt/restore_256KiB", us, f"{nbytes/us:.1f}MB/s_sim")
    eng.fail_lane(1)
    us = _timeit(lambda: eng.restore(last, state), n=2)
    emit("ckpt/degraded_restore_256KiB", us, f"{nbytes/us:.1f}MB/s_sim")


# ------------------------------------------------------- service tier


def bench_service():
    """Async block-device service (PR 6): closed-loop QD saturation and the
    QoS-vs-FIFO serving-tail separation under checkpoint traffic at scale.
    Virtual-time figures from the calibrated device model -- deterministic
    for a given seed, gated by --check without machine-speed rescaling."""
    from repro.service.scenario import checkpoint_under_serving, read_qd_sweep

    rows = read_qd_sweep(qds=(1, 4, 16, 32), n_ops=96 if QUICK else 192)
    for r in rows:
        emit(f"service/qd_sweep_qd{r['qd']}", r["p99_us"],
             f"virtual_iops={r['virtual_iops']:.0f}")
    sat = rows[-1]["virtual_iops"] / rows[0]["virtual_iops"]
    emit("service/qd_sweep_scaling", sat,
         f"iops_qd32_over_qd1={sat:.1f}x_saturating")

    res = {}
    for pol in ("qos", "fifo"):
        res[pol] = checkpoint_under_serving(policy=pol)
        emit(f"service/ckpt_vs_serve_p99_{pol}", res[pol]["serve_p99_us"],
             f"ckpt_save_mean={res[pol]['ckpt_save_mean_us']:.0f}us_"
             f"restore_ok={res[pol]['restore_ok']}")
    gain = res["fifo"]["serve_p99_us"] / res["qos"]["serve_p99_us"]
    emit("service/ckpt_vs_serve_gain", gain,
         f"qos_cuts_serve_read_p99_{gain:.1f}x_vs_fifo")


# ------------------------------------------------------- ZNS cache tier

def bench_cache():
    """ZNS cache tier (PR 7): hit-rate vs read tail under zipf / hotspot /
    bursty address streams on a healthy array, and the headline figure --
    degraded-read p99 with a warm cache after a drive failure vs cold.
    All rows are virtual-time figures (deterministic for a given seed)."""
    from repro.cache import CacheConfig, ZnsCacheTier
    from repro.checkpoint.zapraid_ckpt import CheckpointConfig
    from repro.core.handlers import HandlerPipeline
    from repro.service.scenario import _precondition_region, degraded_read_cache
    from repro.sim import TenantSpec
    from repro.sim.workload import synthetic

    n_ops = 300 if QUICK else 600
    logical = 2048

    def healthy(kind, burst_factor=1.0):
        cfg = CheckpointConfig(zone_cap_blocks=2048, n_zones=32)
        pipe = HandlerPipeline.build_timed(
            cfg.zap_cfg(logical), cfg.zns_cfg(), seed=7,
            flush_interval_us=200.0,
        )
        cache = ZnsCacheTier(
            CacheConfig(n_zones=8, zone_cap_blocks=32,
                        block_bytes=cfg.block_bytes),
            logical,
        )
        pipe.attach_cache(cache)
        _precondition_region(pipe, 0, logical, seed=8)
        rec = pipe.replay(synthetic(
            TenantSpec(name="c", kind=kind, n_ops=n_ops, rate_iops=40_000,
                       read_frac=1.0, burst_factor=burst_factor, seed=9),
            logical,
        ))
        return rec.percentiles(op="R"), cache.stats.hit_rate()

    for kind, bf, label in (("zipf", 1.0, "zipf"), ("hotspot", 1.0, "hotspot"),
                            ("hotspot", 3.0, "bursty")):
        p, hr = healthy(kind, burst_factor=bf)
        emit(f"cache/hit_{label}_p99", p["p99"],
             f"hit_rate={hr:.2f}_p50={p['p50']:.1f}us")

    # the degraded pair keeps the full stream length even under --quick: a
    # shorter stream's working set fits the cache entirely and the warm row
    # degenerates to 100% hits at sub-gate latency
    cold = degraded_read_cache(warm=False, n_ops=600)
    warm = degraded_read_cache(warm=True, n_ops=600)
    emit("cache/degraded_cold_p99", cold["p99_us"],
         f"hit_rate={cold['hit_rate']:.2f}_n={cold['n']}")
    emit("cache/degraded_warm_p99", warm["p99_us"],
         f"hit_rate={warm['hit_rate']:.2f}_bypasses={warm['cache_bypasses']}")
    emit("cache/degraded_warm_gain", 0.0,
         f"p99_{cold['p99_us'] / max(warm['p99_us'], 1e-9):.1f}x_lower_warm")


# -------------------------------------------------------- observability

def bench_obs():
    """Observability layer (PR 8): the observe-only gate -- the qd-sweep
    with the full tracing+metrics stack attached must match the plain run
    to within 5% virtual IOPS (it is in fact bit-identical: spans are
    recorded off bookings the engine already computes) -- and the SLO
    monitor's dynamic-admission recovery of serving p99 under checkpoint
    pressure.  All rows are virtual-time figures, deterministic per seed."""
    from repro.service.scenario import checkpoint_under_serving, read_qd_sweep

    n_ops = 96 if QUICK else 192
    qds = (4, 16)
    plain = read_qd_sweep(qds=qds, n_ops=n_ops)
    traced = read_qd_sweep(qds=qds, n_ops=n_ops, obs=True)
    for p, t in zip(plain, traced):
        delta = abs(t["virtual_iops"] - p["virtual_iops"]) \
            / max(p["virtual_iops"], 1e-9)
        assert delta < 0.05, (
            f"tracing perturbed the timeline at qd{p['qd']}: "
            f"{t['virtual_iops']:.0f} vs {p['virtual_iops']:.0f} iops")
        emit(f"obs/trace_overhead_qd{p['qd']}", t["p99_us"],
             f"iops_delta={delta * 100:.2f}pct_of_{p['virtual_iops']:.0f}")

    slo_kw = dict(window_us=1500.0, interval_us=250.0, min_samples=8)
    static = checkpoint_under_serving(policy="qos", seed=0,
                                      restore_check=False)
    dyn = checkpoint_under_serving(
        policy="qos", seed=0, restore_check=False,
        slo_objective_us=150.0, slo_kwargs=slo_kw,
    )
    s = dyn["slo"]
    emit("obs/slo_admission_static", static["serve_p99_us"],
         f"ckpt_save_max={static['ckpt_save_max_us']:.0f}us")
    emit("obs/slo_admission_slo", dyn["serve_p99_us"],
         f"cap_{s['default_cap']}to{s['min_cap']}_"
         f"shrinks={s['n_shrinks']}_restores={s['n_restores']}")
    gain = static["serve_p99_us"] / max(dyn["serve_p99_us"], 1e-9)
    emit("obs/slo_admission_gain", 0.0,
         f"slo_recovers_serve_p99_{gain:.2f}x_vs_static")


# ------------------------------------------------------------ straggler

def bench_straggler():
    """Beyond-paper: group-bounded commit window vs per-step barrier
    (the paper's G-sweep applied to gradient commits)."""
    from repro.distributed.elastic import GroupCommitScheduler

    sched = GroupCommitScheduler(n_workers=256, straggle_p=0.03,
                                 straggle_factor=6.0, seed=1)
    for g in (1, 4, 16, 64):
        t0 = time.perf_counter()
        res = sched.simulate(steps=512, group_size=g)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"straggler/G{g}", us,
             f"speedup={res.speedup:.3f}_cst_bits={sched.commit_table_bits(g)}")


def bench_degraded_write():
    """Always-writable degraded array: survivor-width write tail vs healthy,
    re-widening rebuild cost (see benchmarks/bench_degraded_write.py)."""
    from benchmarks.bench_degraded_write import run_degraded_write

    run_degraded_write(emit, QUICK)


def bench_scrub():
    """End-to-end integrity: scrub throughput, verify-on-read tax, repair
    storm under foreground load (see benchmarks/bench_scrub.py)."""
    from benchmarks.bench_scrub import run_scrub

    run_scrub(emit, QUICK)


ALL = [
    bench_zns_primitives, bench_write, bench_reads, bench_group_size,
    bench_raid_schemes, bench_recovery, bench_hybrid, bench_gc,
    bench_l2p_offload, bench_trace, bench_latency_qos, bench_e2e_write,
    bench_read_batched, bench_gc_pipeline, bench_recovery_pipeline,
    bench_kernels_batched, bench_kernels, bench_checkpoint, bench_service,
    bench_cache, bench_obs, bench_degraded_write, bench_straggler,
    bench_scrub,
]

# --quick runs the cheap subset (each well under a minute on CPU)
QUICK_SET = [
    bench_zns_primitives, bench_group_size, bench_raid_schemes,
    bench_trace, bench_latency_qos, bench_e2e_write, bench_read_batched,
    bench_gc_pipeline, bench_recovery_pipeline, bench_kernels_batched,
    bench_service, bench_cache, bench_obs, bench_degraded_write,
    bench_straggler, bench_scrub,
]


def write_json(path: str) -> None:
    out = {
        name: {"us_per_call": round(us, 2), "derived": derived}
        for name, us, derived in ROWS
    }
    out[CALIBRATION_KEY] = {
        "us_per_call": round(calibration_us(), 2),
        "derived": "host_speed_reference_for_--check",
    }
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path} ({len(out)} entries)", flush=True)


# Wall-clock rows checked by --check: the device-resident datapath rows this
# repo's perf work protects.  Virtual-time / analytic rows are
# bit-deterministic and would flag any change at all, while the legacy-path
# and interpret-mode kernel comparison rows exist to compute speedup ratios
# and are far too noisy (2x run-to-run) to gate CI on.
CHECK_PREFIXES = (
    "e2e/seq_write_batched", "read/healthy_batched", "read/degraded_batched",
    "gc/batched_once", "recovery/batched",
)
# Virtual-time service rows: deterministic figures from the device model, so
# they gate without the machine-speed rescale (scale 1.0) -- any drift is a
# semantic change in the service/engine, not a slower host.  The gain row is
# excluded: it *growing* is an improvement, which the gate would misread.
CHECK_NOSCALE_PREFIXES = (
    "service/qd_sweep_qd", "service/ckpt_vs_serve_p99_",
    "cache/hit_", "cache/degraded_",
    "obs/trace_overhead_qd", "obs/slo_admission_static",
    "obs/slo_admission_slo",
    "degraded/", "integrity/",
)
CHECK_SLACK = 1.25   # fail when us_per_call grows >25% over the baseline
CHECK_MIN_US = 5.0   # skip sub-5us rows: timer/scheduler noise swamps them
CALIBRATION_KEY = "_calibration_us"


def calibration_us() -> float:
    """Fixed host workload timing the machine itself (numpy + Python mix).

    Stored in every baseline JSON and re-measured by ``--check`` so the gate
    compares *relative* datapath cost: a CI runner that is wholesale slower
    (or faster) than the machine that produced the committed baseline scales
    the baseline instead of tripping -- or masking -- the 25%% gate.  Min of
    several runs: the minimum estimates machine speed, not machine load."""
    rng = np.random.default_rng(0)
    a = rng.integers(0, 256, (256, 4096), dtype=np.uint8)

    def work():
        acc = 0
        for _ in range(4):
            b = np.bitwise_xor(a, np.roll(a, 1, axis=0))
            acc += int(b[::17].sum())
        return acc

    work()  # warmup
    return min(
        _timeit(work, n=1) for _ in range(7)
    )


def check_regressions(baseline_path: str) -> int:
    """Rerun vs a committed baseline; nonzero exit on >25% throughput loss.

    Baseline figures are rescaled by the ratio of this machine's calibration
    workload to the baseline machine's (clamped to [0.5, 3]x) before the
    gate applies, so heterogeneous CI hardware does not fail spuriously."""
    with open(baseline_path) as f:
        base = json.load(f)
    cal_old = base.get(CALIBRATION_KEY, {}).get("us_per_call", 0.0)
    scale = 1.0
    if cal_old > 0:
        scale = min(3.0, max(0.5, calibration_us() / cal_old))
    failures, compared = [], 0
    for name, us, _ in ROWS:
        noscale = name.startswith(CHECK_NOSCALE_PREFIXES)
        old = base.get(name, {}).get("us_per_call", 0.0) * (
            1.0 if noscale else scale
        )
        if not name.startswith(CHECK_PREFIXES + CHECK_NOSCALE_PREFIXES) \
                or old < CHECK_MIN_US:
            continue
        compared += 1
        if us > old * CHECK_SLACK:
            failures.append(f"{name}: {us:.2f}us vs scaled baseline "
                            f"{old:.2f}us ({us / old:.2f}x > "
                            f"{CHECK_SLACK:.2f}x)")
    print(f"# --check: {compared} rows vs {baseline_path} "
          f"(machine-speed scale {scale:.2f}x), "
          f"{len(failures)} regressions", flush=True)
    for line in failures:
        print(f"# REGRESSION {line}", flush=True)
    return 1 if failures else 0


def main() -> None:
    global QUICK
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="small shapes / cheap subset for CI time budgets")
    ap.add_argument("--json", default=None,
                    help="machine-readable output path ('' to disable). "
                         "Defaults: --quick -> BENCH_PR10.json (the committed "
                         "baseline: the quick set carries the perf acceptance "
                         "figures), full -> BENCH_FULL.json, "
                         "--only -> disabled; each command maps to one file "
                         "so no sweep clobbers another's baseline")
    ap.add_argument("--check", metavar="BASELINE.json", default=None,
                    help="regression mode: rerun the --quick benches and exit "
                         "nonzero if any wall-clock row is >25%% slower than "
                         "the committed baseline; implies --quick and writes "
                         "no JSON")
    args = ap.parse_args()
    QUICK = args.quick or args.check is not None
    json_path = args.json
    if args.check is not None:
        json_path = ""
    elif json_path is None:
        if args.only:
            json_path = ""
        else:
            json_path = "BENCH_PR10.json" if args.quick else "BENCH_FULL.json"
    print("name,us_per_call,derived")
    for fn in (QUICK_SET if QUICK else ALL):
        if args.only and args.only not in fn.__name__:
            continue
        fn()
    if json_path:
        write_json(json_path)
    if args.check is not None:
        rc = check_regressions(args.check)
        if rc:
            # one retry: a sustained load spike can slow a whole sweep more
            # than the calibration workload predicts; a *real* regression
            # reproduces across two independent sweeps, a spike does not
            print("# --check: regressions flagged; remeasuring once to rule "
                  "out a load spike", flush=True)
            first = {name: us for name, us, _ in ROWS}
            ROWS.clear()
            for fn in QUICK_SET:
                fn()
            ROWS[:] = [
                (name, min(us, first.get(name, us)), derived)
                for name, us, derived in ROWS
            ]
            rc = check_regressions(args.check)
        raise SystemExit(rc)


if __name__ == "__main__":
    main()
