"""Integrity benchmark: scrub throughput, verify-on-read tax, repair storm.

Three virtual-time figures for the PR-10 end-to-end integrity layer
(DESIGN.md §15), all deterministic on the ZN540-calibrated device model so
the ``--check`` gate compares them unscaled:

* ``integrity/scrub_throughput`` -- device time booked by one paced scrub
  pass over a fully-written sealed array (bulk CRC32C verify, no faults);
  derived column converts to verified MiB/s of media;
* ``integrity/verify_read_overhead_p99`` -- foreground read p99 with
  ``verify_reads`` on, vs the same load with it off: the whole-read-path
  checksum tax (acceptance: <10%);
* ``integrity/repair_storm_p99`` -- foreground read p99 while the paced
  scrub actor concurrently detects and repairs a corruption storm (~2% of
  written blocks, one hit per stripe group so every fault is repairable
  at raid5 width); derived reports the repaired-block count.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _shift(load, t0: float):
    return [dataclasses.replace(r, t_us=r.t_us + t0) for r in load]


def _make_pipe(seed: int, verify: bool):
    from repro.core.array import ZapRaidConfig
    from repro.core.handlers import HandlerPipeline
    from repro.core.zns import ZnsConfig

    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=8,
                        chunk_blocks=1, logical_blocks=256,
                        gc_free_segments_low=1, verify_reads=verify)
    zns = ZnsConfig(n_zones=16, zone_cap_blocks=64, block_bytes=256)
    pipe = HandlerPipeline.build_timed(cfg, zns, seed=seed,
                                       flush_interval_us=200.0)
    rng = np.random.default_rng(seed)
    # two overwrite rounds: more than one sealed segment on the media, so
    # the scrub rows walk a multi-segment array, not a single zone set
    pipe.precondition(
        (lba, rng.integers(0, 256, (1, 256), dtype=np.uint8))
        for _ in range(2) for lba in range(256)
    )
    return pipe


def _read_load(n_ops: int):
    from repro.sim import TenantSpec, multi_tenant

    return multi_tenant([
        TenantSpec(name="reader", kind="uniform", n_ops=n_ops,
                   rate_iops=50_000, read_frac=1.0, seed=31),
    ], logical_blocks=256)


def _corrupt_per_group(arr, rng) -> int:
    """One bit-rot hit in every stripe group of every sealed segment,
    cycling the victim member: dense enough to be a storm (~2% of written
    blocks at this geometry), and exactly one loss per stripe so raid5
    repairs all of it."""
    from repro.core.segment import SegmentState

    n_bad = 0
    for rec in sorted(arr.segments.values(), key=lambda r: r.info.seg_id):
        info = rec.info
        if info.state != int(SegmentState.SEALED):
            continue
        ds = info.data_start()
        span = max(1, info.group_size) * info.chunk_blocks
        n_groups = -(-info.n_stripes * info.chunk_blocks // span)
        for g in range(n_groups):
            m = g % info.n_drives
            d = arr.drives[info.drive_ids[m]]
            zone = info.zone_ids[m]
            off = ds + g * span + int(rng.integers(0, span))
            if off >= int(d.wp[zone]):
                continue
            d.corrupt_bit_rot(zone, off, int(rng.integers(0, d.cfg.block_bytes)),
                              int(rng.integers(0, 8)))
            n_bad += 1
    return n_bad


def run_scrub(emit, quick: bool) -> None:
    n_ops = 300 if quick else 1000
    load = _read_load(n_ops)

    # -- scrub throughput over clean sealed media --------------------------
    pipe = _make_pipe(seed=9, verify=True)
    pipe.schedule_scrub(at=pipe.engine.now + 10.0, interval_us=20.0)
    pipe.drain()
    scrub_us = pipe.recorder.notes.get("scrub_device_us", 0.0)
    blocks = pipe.array.stats.integrity_scrub_blocks
    mib_s = blocks * 256 / max(scrub_us, 1e-9) * 1e6 / (1 << 20)
    emit("integrity/scrub_throughput", scrub_us,
         f"blocks={blocks}_{mib_s:.0f}MiB/s_verified")

    # -- verify-on-read tax ------------------------------------------------
    off = _make_pipe(seed=9, verify=False).replay(load).percentiles(op="R")
    on_pipe = _make_pipe(seed=9, verify=True)
    on = on_pipe.replay(load).percentiles(op="R")
    emit("integrity/verify_read_overhead_p99", on["p99"],
         f"p50={on['p50']:.1f}us_ratio="
         f"{on['p99'] / max(off['p99'], 1e-9):.3f}x_vs_unverified")

    # -- repair storm: scrub heals ~2% corruption under the read load -----
    pipe = _make_pipe(seed=9, verify=True)
    n_bad = _corrupt_per_group(pipe.array, np.random.default_rng(13))
    pipe.schedule_scrub(at=pipe.engine.now + 10.0, interval_us=50.0)
    storm = pipe.replay(_shift(load, pipe.engine.now)).percentiles(op="R")
    repaired = pipe.array.stats.integrity_blocks_repaired
    emit("integrity/repair_storm_p99", storm["p99"],
         f"corrupted={n_bad}_repaired={repaired}_ratio="
         f"{storm['p99'] / max(on['p99'], 1e-9):.2f}x_vs_clean")
