"""Degraded-write benchmark: the always-writable array (DESIGN.md §14).

Replays one open-loop write load on the timed pipeline in three array
states and reports virtual-time (ZN540-calibrated device model) latency
percentiles, so the cost of survivor-width commits and the re-widening
rebuild become tracked figures:

* ``degraded/write_p99_healthy``  -- full-width commits, all drives up;
* ``degraded/write_p99_degraded`` -- one drive failed: the same load lands
  on survivor-width stripe groups (k-1 data + m parity on the healthy
  drives), with degraded decodes for reads-modify paths that touch
  full-width history;
* ``degraded/rewiden_rebuild_us`` -- device time booked by the paced
  replace-and-rebuild actor *including* the final re-widening pass that
  relocates survivor-width groups back onto the full drive set;
* ``degraded/write_p99_rebuilt``  -- the load replayed after the rebuild:
  the tail returns to (near) the healthy figure.

All rows are virtual-time and deterministic, so the ``--check`` gate
compares them unscaled (no machine-speed rescale).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _shift(load, t0: float):
    """Re-base a request stream's arrival times onto the current virtual
    clock: replays on a pipe whose engine already advanced (fail-over,
    rebuild) would otherwise submit every op in the past and book the
    artificial backlog as latency."""
    return [dataclasses.replace(r, t_us=r.t_us + t0) for r in load]


def _make_pipe(seed: int):
    from repro.core.array import ZapRaidConfig
    from repro.core.handlers import HandlerPipeline
    from repro.core.zns import ZnsConfig

    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=8,
                        chunk_blocks=1, logical_blocks=256,
                        gc_free_segments_low=1)
    zns = ZnsConfig(n_zones=16, zone_cap_blocks=64, block_bytes=256)
    pipe = HandlerPipeline.build_timed(cfg, zns, seed=seed,
                                       flush_interval_us=200.0)
    rng = np.random.default_rng(seed)
    pipe.precondition(
        (lba, rng.integers(0, 256, (1, 256), dtype=np.uint8))
        for lba in range(256)
    )
    return pipe


def _write_load(n_ops: int):
    from repro.sim import TenantSpec, multi_tenant

    # ~50k IOPS of uniform overwrites: fast enough that group commits queue
    # behind the append channels, so width changes move the measured tail
    return multi_tenant([
        TenantSpec(name="writer", kind="uniform", n_ops=n_ops,
                   rate_iops=50_000, read_frac=0.0, seed=71),
    ], logical_blocks=256)


def run_degraded_write(emit, quick: bool) -> None:
    from repro.sim import LatencyRecorder

    n_ops = 300 if quick else 1000
    load = _write_load(n_ops)

    healthy = _make_pipe(seed=7).replay(load)
    h_w = healthy.percentiles(op="W")
    emit("degraded/write_p99_healthy", h_w["p99"],
         f"n={h_w['n']}_p50={h_w['p50']:.1f}us")

    pipe = _make_pipe(seed=7)
    pipe.array.fail_drive(1)
    degraded = pipe.replay(_shift(load, pipe.engine.now))
    d_w = degraded.percentiles(op="W")
    emit("degraded/write_p99_degraded", d_w["p99"],
         f"p50={d_w['p50']:.1f}us_ratio="
         f"{d_w['p99'] / max(h_w['p99'], 1e-9):.2f}x_vs_healthy")

    # paced replace-and-rebuild on the same (now mixed-width) array: the
    # rebuild_device_us note totals reconstruction + re-widening traffic
    before = degraded.notes.get("rebuild_device_us", 0.0)
    t0 = pipe.engine.now
    narrow = sum(
        1 for r in pipe.array.segments.values()
        if len(r.info.drive_ids) < pipe.array.cfg.n_drives
    )
    pipe.schedule_rebuild(1, at=pipe.engine.now + 10.0, interval_us=20.0)
    pipe.drain()
    rebuild_us = degraded.notes.get("rebuild_device_us", 0.0) - before
    emit("degraded/rewiden_rebuild_us", rebuild_us,
         f"virtual_elapsed={pipe.engine.now - t0:.0f}us"
         f"_narrow_segments_relocated={narrow}")

    # after the re-widening rebuild the tail returns to the healthy figure
    pipe.recorder = LatencyRecorder()
    rebuilt = pipe.replay(_shift(load, pipe.engine.now))
    r_w = rebuilt.percentiles(op="W")
    emit("degraded/write_p99_rebuilt", r_w["p99"],
         f"p50={r_w['p50']:.1f}us_ratio="
         f"{r_w['p99'] / max(h_w['p99'], 1e-9):.2f}x_vs_healthy")
