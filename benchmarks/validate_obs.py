"""CI validator for the obs-demo artifacts.

``make obs-demo`` writes ``out/trace.json`` (Chrome/Perfetto
``trace_event`` JSON) and ``out/metrics.json`` (metric time-series).
This script re-validates both files against the same schema checkers the
unit tests use -- trace-event field/nesting invariants, monotone
timestamps, non-decreasing counters -- plus a few artifact-level checks
(non-trivial event counts, the request/resource span families and the SLO
cap gauge actually present), so CI fails if the demo ever starts emitting
JSON a viewer would load but render wrong.

Run: PYTHONPATH=src python -m benchmarks.validate_obs [out_dir]
"""
from __future__ import annotations

import json
import os
import sys

from repro.obs import validate_metrics_series, validate_trace_events


def main(out_dir: str = "out") -> int:
    trace_path = os.path.join(out_dir, "trace.json")
    metrics_path = os.path.join(out_dir, "metrics.json")

    with open(trace_path) as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    validate_trace_events(events)
    phs = {e["ph"] for e in events}
    assert {"b", "e", "X", "M"} <= phs, f"span families missing: {phs}"
    assert len(events) > 100, f"suspiciously small trace ({len(events)})"
    names = {e["name"] for e in events}
    for required in ("io.request", "device.service", "zone_append"):
        assert required in names, f"missing span kind {required!r}"
    print(f"# {trace_path}: {len(events)} events OK "
          f"({len(names)} span kinds)")

    with open(metrics_path) as f:
        doc = json.load(f)
    validate_metrics_series(doc)
    series = doc["series"]
    assert len(series) > 10, f"suspiciously short series ({len(series)})"
    last = series[-1]
    for gauge in ("service/inflight", "class/ckpt/cap",
                  "array/gc_reserved_zones"):
        assert gauge in last["gauges"], f"missing gauge {gauge!r}"
    assert last["counters"].get("array/stripes_committed", 0) > 0
    print(f"# {metrics_path}: {len(series)} samples OK "
          f"({len(last['counters'])} counters, {len(last['gauges'])} gauges)")
    return 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:]))
