"""Group-based data layout invariants (paper §3.2)."""
import math

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.group_layout import CompactStripeTable, stripe_id_dtype
from repro.core.array import ZapRaidConfig, ZapRAIDArray
from repro.core.zns import ZnsConfig


@given(st.integers(2, 65536))
@settings(max_examples=60, deadline=None)
def test_stripe_id_byte_rounding(g):
    """Stripe IDs are byte-rounded exactly as the paper's prototype."""
    bits = max(1, math.ceil(math.log2(g)))
    nbytes = -(-bits // 8)
    assert stripe_id_dtype(g).itemsize == min(nbytes, 4) or nbytes > 4


def test_cst_memory_formula():
    """max memory = (k+m) * S * bytes_per_id (paper's formula, byte-rounded)."""
    for g, expected_itemsize in [(4, 1), (256, 1), (257, 2), (4096, 2)]:
        cst = CompactStripeTable(n_drives=4, n_stripes=1000, group_size=g)
        assert cst.memory_bytes() == 4 * 1000 * expected_itemsize


def test_degraded_query_bound_is_k_times_g():
    """A degraded read touches at most k*G CST entries (paper §3.2)."""
    g = 8
    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=g,
                        chunk_blocks=1, logical_blocks=128,
                        gc_free_segments_low=1)
    zns = ZnsConfig(n_zones=8, zone_cap_blocks=64, block_bytes=256)
    arr = ZapRAIDArray(cfg, zns)
    rng = np.random.default_rng(0)
    for lba in range(30):
        arr.write(lba, rng.integers(0, 256, (1, 256), dtype=np.uint8))
    arr.flush()
    arr.fail_drive(0)
    # pick an LBA whose block lives on the failed drive (forces decode)
    from repro.core.l2p import unpack_pba
    lba = next(
        l for l in range(30) if unpack_pba(arr.l2p.get(l))[1] == 0
    )
    rec = next(iter(arr.segments.values()))
    cst = rec.cst
    before = cst.entries_accessed
    arr.read(lba, 1)
    accessed = cst.entries_accessed - before
    k = 3
    assert 0 < accessed <= (k + 1) * g + 1  # k survivors searched + own entry


def test_out_of_order_placement_is_absorbed():
    """Chunks of one stripe land at different offsets across zones under the
    shuffled Zone-Append commit, yet reads resolve correctly."""
    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=8,
                        chunk_blocks=1, logical_blocks=128,
                        gc_free_segments_low=1, append_seed=7)
    zns = ZnsConfig(n_zones=8, zone_cap_blocks=64, block_bytes=256)
    arr = ZapRAIDArray(cfg, zns)
    rng = np.random.default_rng(1)
    ref = {}
    for lba in range(24):
        blk = rng.integers(0, 256, (1, 256), dtype=np.uint8)
        arr.write(lba, blk)
        ref[lba] = blk[0]
    arr.flush()
    rec = next(iter(arr.segments.values()))
    table = rec.cst.table[:, :8]  # first group
    # at least one drive must have a different stripe order than drive 0
    assert any(
        not np.array_equal(table[0], table[d]) for d in range(1, 4)
    ), "shuffle produced fully-aligned placement (seed too tame?)"
    assert all(np.array_equal(arr.read(l, 1)[0], v) for l, v in ref.items())


def test_g1_degenerates_to_zone_write():
    """G=1 must use the Zone Write path: no CST allocated."""
    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=1,
                        chunk_blocks=1, logical_blocks=128,
                        gc_free_segments_low=1)
    zns = ZnsConfig(n_zones=8, zone_cap_blocks=64, block_bytes=256)
    arr = ZapRAIDArray(cfg, zns)
    rng = np.random.default_rng(2)
    ref = {}
    for lba in range(16):
        blk = rng.integers(0, 256, (1, 256), dtype=np.uint8)
        arr.write(lba, blk)
        ref[lba] = blk[0]
    arr.flush()
    rec = next(iter(arr.segments.values()))
    assert rec.cst is None
    # static mapping: same stripe -> same offset on every drive
    arr.fail_drive(3)
    assert all(np.array_equal(arr.read(l, 1)[0], v) for l, v in ref.items())
