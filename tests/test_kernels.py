"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp/table oracles,
swept over shapes and dtypes, plus hypothesis property tests on GF(256)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.core import gf
from repro.kernels import ops, ref


# ---------------------------------------------------------------- GF field

@given(st.integers(0, 255), st.integers(0, 255), st.integers(0, 255))
@settings(max_examples=200, deadline=None)
def test_gf_field_axioms(a, b, c):
    m = gf.gf_mul
    assert m(a, b) == m(b, a)
    assert m(a, m(b, c)) == m(m(a, b), c)
    assert m(a, b ^ c) == m(a, b) ^ m(a, c)  # distributes over XOR
    if a:
        assert m(a, gf.gf_inv(a)) == 1


@given(st.integers(1, 12), st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_rs_generator_is_mds(k, m):
    """Every k x k submatrix of the systematic generator is invertible."""
    import itertools

    gen = gf.rs_encode_matrix(k, m)
    rows = list(range(k + m))
    count = 0
    for sub in itertools.combinations(rows, k):
        gf.gf_inv_matrix_np(gen[list(sub)])  # raises if singular
        count += 1
        if count > 20:
            break


@given(
    st.integers(0, 2**31 - 1),
    st.integers(0, 255),
)
@settings(max_examples=100, deadline=None)
def test_swar_gf_scale_matches_tables(word, coeff):
    packed = np.array([word], dtype=np.int32)
    got = gf.swar_gf_scale(packed, coeff)
    want_bytes = gf.gf_mul_np(
        packed.view(np.uint8), np.full(4, coeff, np.uint8)
    )
    assert np.array_equal(np.asarray(got, np.int32).view(np.uint8), want_bytes)


# ------------------------------------------------------------ parity kernels

@pytest.mark.parametrize("k", [2, 3, 5, 8])
@pytest.mark.parametrize("n", [128, 1024, 4096])
def test_parity_xor_shapes(k, n):
    rng = np.random.default_rng(k * n)
    x = jnp.asarray(rng.integers(-(2**31), 2**31, (k, n), dtype=np.int64), jnp.int32)
    got = ops.xor_parity(x, use_pallas=True, interpret=True)
    want = ref.parity_xor_ref(x)
    assert jnp.array_equal(got, want)
    assert np.array_equal(
        np.asarray(got), np.bitwise_xor.reduce(np.asarray(x), axis=0)
    )


def test_parity_xor_unaligned_lanes():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 2**31, (3, 20), dtype=np.int64), jnp.int32)
    got = ops.xor_parity(x, use_pallas=True, interpret=True)
    assert np.array_equal(np.asarray(got), np.bitwise_xor.reduce(np.asarray(x), 0))


@pytest.mark.parametrize("k,m", [(2, 1), (3, 1), (3, 2), (6, 2), (4, 3)])
@pytest.mark.parametrize("n_bytes", [512, 4096])
def test_gf256_matmul_vs_table_oracle(k, m, n_bytes):
    rng = np.random.default_rng(k * 7 + m)
    data = rng.integers(0, 256, (k, n_bytes), dtype=np.uint8)
    coeff = gf.rs_parity_matrix(k, m)
    want = gf.gf_matmul_np(coeff, data)
    packed = ops.pack_bytes(jnp.asarray(data))
    got = ops.rs_matmul(
        jnp.asarray(coeff, jnp.int32), packed, use_pallas=True, interpret=True
    )
    assert np.array_equal(np.asarray(ops.unpack_bytes(got)), want)


@given(
    st.integers(2, 6),  # k
    st.integers(1, 2),  # m
    st.randoms(use_true_random=False),
)
@settings(max_examples=25, deadline=None)
def test_rs_roundtrip_any_survivors(k, m, rnd):
    rng = np.random.default_rng(rnd.randint(0, 1 << 30))
    data = rng.integers(0, 256, (k, 256), dtype=np.uint8)
    packed = ops.pack_bytes(jnp.asarray(data))
    parity = ops.rs_encode(packed, m, use_pallas=True, interpret=True)
    code = jnp.concatenate([packed, parity], axis=0)
    all_rows = list(range(k + m))
    rnd.shuffle(all_rows)
    surv = tuple(sorted(all_rows[:k]))
    rec = ops.rs_decode(code[np.array(surv)], surv, k, m,
                        use_pallas=True, interpret=True)
    assert np.array_equal(np.asarray(ops.unpack_bytes(rec)), data)


# ------------------------------------------------------------------- SSD

@pytest.mark.parametrize("t,chunk", [(64, 16), (128, 128), (256, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_vs_ref(t, chunk, dtype):
    rng = np.random.default_rng(t + chunk)
    bh, p, n = 3, 8, 16
    x = jnp.asarray(rng.standard_normal((bh, t, p)), dtype)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (bh, t)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, (bh,)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((bh, t, n)), dtype)
    c = jnp.asarray(rng.standard_normal((bh, t, n)), dtype)
    y0, h0 = ref.ssd_scan_ref(x, dt, a, b, c)
    y1, h1 = ops.ssd_chunk_scan(x, dt, a, b, c, chunk=chunk,
                                use_pallas=True, interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1), atol=tol, rtol=tol)


def test_ssd_scan_state_continuation():
    """Scanning [first half] then [second half with carried state] must match
    one full scan -- the decode-from-prefill invariant."""
    rng = np.random.default_rng(5)
    bh, t, p, n = 2, 128, 4, 8
    x = jnp.asarray(rng.standard_normal((bh, t, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (bh, t)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, (bh,)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((bh, t, n)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((bh, t, n)), jnp.float32)
    y_full, h_full = ops.ssd_chunk_scan(x, dt, a, b, c, chunk=32)
    half = t // 2
    y1, h1 = ops.ssd_chunk_scan(x[:, :half], dt[:, :half], a, b[:, :half],
                                c[:, :half], chunk=32)
    y2, h2 = ops.ssd_chunk_scan(x[:, half:], dt[:, half:], a, b[:, half:],
                                c[:, half:], h1, chunk=32)
    np.testing.assert_allclose(np.asarray(y_full[:, half:]), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2),
                               atol=1e-4, rtol=1e-4)


def test_chunked_jnp_ssd_matches_ref():
    from repro.models.mamba2 import ssd_chunked

    rng = np.random.default_rng(11)
    bsz, t, h, p, n = 2, 96, 4, 8, 16
    x = jnp.asarray(rng.standard_normal((bsz, t, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (bsz, t, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((bsz, t, n)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((bsz, t, n)), jnp.float32)
    y, hf = ssd_chunked(x, dt, a, b, c, chunk=32)
    # reference: per (batch,head) sequential scan with shared b/c
    xr = x.transpose(0, 2, 1, 3).reshape(bsz * h, t, p)
    dtr = dt.transpose(0, 2, 1).reshape(bsz * h, t)
    ar = jnp.tile(a, bsz)
    br = jnp.repeat(b, h, axis=0)
    cr = jnp.repeat(c, h, axis=0)
    y_ref, h_ref = ref.ssd_scan_ref(xr, dtr, ar, br, cr)
    y_ref = y_ref.reshape(bsz, h, t, p).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4, rtol=2e-4)
