"""Discrete-event timed I/O engine: engine, timed devices, workloads, QoS.

Covers the PR-3 subsystem end to end:

* event-heap ordering and determinism;
* TimedDrive queueing discipline (one Zone Write in flight per zone,
  qd<=4 Zone Appends per zone, channel contention);
* ZNS satellite fixes (max_open_zones enforcement, replace() preserving
  lifetime counters);
* workload generation (MSR trace parsing, synthetic determinism);
* the timed pipeline (write/read roundtrip, latency recording);
* timing-driven Zone-Append disorder: same logical state as the RNG
  permutation path across RAID schemes, including after crash recovery;
* degraded reads under load showing tail inflation.
"""
import numpy as np
import pytest

from repro.core.array import ZapRaidConfig, ZapRAIDArray
from repro.core.handlers import HandlerPipeline
from repro.core.recovery import recover_array
from repro.core.zns import (
    CrashBudget,
    SimZnsDrive,
    TooManyOpenZones,
    ZnsConfig,
    ZoneState,
)
from repro.sim import (
    Engine,
    Request,
    ServiceModel,
    TenantSpec,
    TimedDrive,
    multi_tenant,
    parse_msr_trace,
    synthetic,
)


# ------------------------------------------------------------------- engine


def test_engine_orders_events_and_is_deterministic():
    eng = Engine()
    fired = []
    eng.at(5.0, fired.append, "c")
    eng.at(1.0, fired.append, "a")
    eng.at(1.0, fired.append, "b")  # same instant: scheduling order wins
    eng.after(0.5, fired.append, "first")
    assert eng.run() == 4
    assert fired == ["first", "a", "b", "c"]
    assert eng.now == 5.0
    eng.at(2.0, fired.append, "late")  # in the past: clamped to now
    eng.run()
    assert eng.now == 5.0 and fired[-1] == "late"


def test_engine_run_until():
    eng = Engine()
    out = []
    for t in (1.0, 2.0, 3.0):
        eng.at(t, out.append, t)
    assert eng.run(until=2.0) == 2
    assert out == [1.0, 2.0] and eng.pending() == 1


# -------------------------------------------------------------- timed drives


def _drive(seed=0, **svc):
    eng = Engine()
    cfg = ZnsConfig(n_zones=4, zone_cap_blocks=64, block_bytes=512)
    service = ServiceModel(block_bytes=512, **svc)
    return eng, TimedDrive(cfg, 0, engine=eng, service=service, seed=seed)


def test_zone_write_serializes_per_zone():
    eng, d = _drive(n_channels=8)
    t1 = d.book_zone_write(0, 1, 0.0)
    t2 = d.book_zone_write(0, 1, 0.0)   # same zone: must wait for t1
    t3 = d.book_zone_write(1, 1, 0.0)   # other zone: starts immediately
    assert t2 > t1
    assert t3 < t2  # inter-zone parallelism


def test_zone_append_qd_limit():
    eng, d = _drive(n_channels=16, jitter_sigma=0.0)
    done = [d.book_append(0, 1, 0.0) for _ in range(8)]
    # first 4 run concurrently; the 5th cannot start before one of them ends
    assert done[4] > min(done[:4])
    # in-flight never exceeds the qd: the completion times of 8 serial-ish
    # bookings must span at least two "waves" of service
    svc1 = d.service.zone_append_us(1, 1)
    assert max(done) > 1.5 * svc1


def test_channels_shared_between_reads_and_writes():
    eng, d = _drive(n_channels=1, jitter_sigma=0.0)
    t_w = d.book_zone_write(0, 1, 0.0)
    t_r = d.book_read(1, 0.0)
    assert t_r > t_w  # the single channel serializes the read behind the write


def test_timed_drive_media_matches_functional():
    eng, d = _drive()
    from repro.core.zns import OOB_DTYPE
    blocks = np.full((2, 512), 7, np.uint8)
    oobs = np.zeros(2, dtype=OOB_DTYPE)
    d.zone_write(0, 0, blocks, oobs)
    assert int(d.wp[0]) == 2
    off = d.zone_append_commit(0, blocks, oobs)
    assert off == 2 and int(d.wp[0]) == 4
    assert d.chunk_completion(0, 0) is not None
    assert d.chunk_completion(0, 2) is not None
    np.testing.assert_array_equal(d.read(0, 0, 2), blocks)


# -------------------------------------------------- ZNS satellites (PR 3)


def test_max_open_zones_enforced():
    cfg = ZnsConfig(n_zones=8, zone_cap_blocks=16, block_bytes=64, max_open_zones=2)
    d = SimZnsDrive(cfg, 0)
    from repro.core.zns import OOB_DTYPE
    blk = np.zeros((1, 64), np.uint8)
    oob = np.zeros(1, dtype=OOB_DTYPE)
    d.zone_write(0, 0, blk, oob)
    d.zone_append_begin(1)
    assert d.open_zone_count() == 2
    with pytest.raises(TooManyOpenZones):
        d.zone_write(2, 0, blk, oob)
    with pytest.raises(TooManyOpenZones):
        d.zone_append_begin(3)
    with pytest.raises(TooManyOpenZones):
        d.zone_append_commit(3, blk, oob)
    # sealing one frees a slot
    d.finish_zone(0)
    d.zone_write(2, 0, blk, oob)
    assert d.open_zone_count() == 2
    # writing into an already-open zone never trips the limit
    d.zone_write(2, 1, blk, oob)


def test_replace_preserves_lifetime_counters():
    cfg = ZnsConfig(n_zones=4, zone_cap_blocks=16, block_bytes=64)
    d = SimZnsDrive(cfg, 0)
    from repro.core.zns import OOB_DTYPE
    blk = np.full((1, 64), 3, np.uint8)
    oob = np.zeros(1, dtype=OOB_DTYPE)
    for _ in range(5):
        d.zone_write(0, int(d.wp[0]), blk, oob)
    d.reset_zone(0)
    assert (d.blocks_written, d.zone_resets) == (5, 1)
    d.fail()
    d.replace()
    assert not d.failed
    assert (d.blocks_written, d.zone_resets) == (5, 1)  # counters survive swap
    assert int(d.wp[0]) == 0 and d.state[0] == ZoneState.EMPTY
    assert not d.data.any()
    # budget identity is preserved too
    assert isinstance(d.budget, CrashBudget)


def test_replace_write_amp_accounting_spans_rebuild():
    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=4,
                        chunk_blocks=1, logical_blocks=64, gc_free_segments_low=1)
    zns = ZnsConfig(n_zones=8, zone_cap_blocks=32, block_bytes=128)
    arr = ZapRAIDArray(cfg, zns)
    rng = np.random.default_rng(0)
    for lba in range(48):
        arr.write(lba, rng.integers(0, 256, (1, 128), dtype=np.uint8))
    arr.flush()
    before = arr.drives[2].blocks_written
    assert before > 0
    arr.fail_drive(2)
    arr.rebuild_drive(2)
    # the rebuilt drive's counter kept its history and grew with the rebuild
    assert arr.drives[2].blocks_written > before


# ------------------------------------------------------------------ workload


def test_parse_msr_trace():
    text = "\n".join([
        "Timestamp,Hostname,DiskNumber,Type,Offset,Size,ResponseTime",
        "128166372003061629,src1,0,Write,8192,4096,100",
        "128166372003061529,src1,0,Read,0,1024,90",      # earlier ts
        "128166372003071629,src1,0,Write,1048576,8192,110",
        "garbage line",
    ])
    reqs = parse_msr_trace(text, block_bytes=4096, logical_blocks=64)
    assert len(reqs) == 3
    assert [r.op for r in reqs] == ["R", "W", "W"]  # sorted by time
    assert reqs[0].t_us == 0.0
    assert reqs[1].t_us == pytest.approx(10.0)      # 100 ticks = 10 us
    assert reqs[1].lba == 2 and reqs[1].n_blocks == 1
    assert reqs[2].n_blocks == 2
    assert all(r.lba + r.n_blocks <= 64 for r in reqs)


def test_synthetic_workloads_deterministic_and_bounded():
    for kind in ("seq", "uniform", "hotspot", "zipf"):
        spec = TenantSpec(name="t", kind=kind, n_ops=200, rate_iops=10_000,
                          read_frac=0.3, seed=5)
        a = synthetic(spec, logical_blocks=128)
        b = synthetic(spec, logical_blocks=128)
        assert a == b
        assert all(0 <= r.lba < 128 for r in a)
        assert all(a[i].t_us <= a[i + 1].t_us for i in range(len(a) - 1))


def test_bursty_arrivals_cluster():
    calm = synthetic(TenantSpec(name="c", n_ops=400, rate_iops=10_000, seed=1),
                     logical_blocks=64)
    burst = synthetic(TenantSpec(name="b", n_ops=400, rate_iops=10_000,
                                 burst_factor=4.0, seed=1), logical_blocks=64)
    def cv2(rs):  # squared coefficient of variation of inter-arrival gaps
        g = np.diff([r.t_us for r in rs])
        return np.var(g) / np.mean(g) ** 2

    # Poisson gaps have CV^2 ~ 1; on-off modulation pushes it well above
    assert cv2(calm) < 1.5 < cv2(burst)


def test_multi_tenant_merge():
    reqs = multi_tenant([
        TenantSpec(name="a", n_ops=50, rate_iops=5_000, seed=1),
        TenantSpec(name="b", n_ops=50, rate_iops=5_000, read_frac=1.0, seed=2),
    ], logical_blocks=64)
    assert len(reqs) == 100
    assert {r.tenant for r in reqs} == {"a", "b"}
    assert all(reqs[i].t_us <= reqs[i + 1].t_us for i in range(len(reqs) - 1))


# ------------------------------------------------------------ timed pipeline


def _timed_pipe(scheme="raid5", group_size=4, seed=0, **cfg_kw):
    cfg = ZapRaidConfig(scheme=scheme, n_drives=4, group_size=group_size,
                        chunk_blocks=1, logical_blocks=128,
                        gc_free_segments_low=1, **cfg_kw)
    zns = ZnsConfig(n_zones=8, zone_cap_blocks=64, block_bytes=256)
    return HandlerPipeline.build_timed(cfg, zns, seed=seed)


def test_timed_write_read_roundtrip_records_latency():
    pipe = _timed_pipe()
    rng = np.random.default_rng(0)
    ref = {}
    t = 0.0
    for lba in range(24):
        blk = rng.integers(0, 256, (1, 256), dtype=np.uint8)
        ref[lba] = blk[0].copy()
        t += 20.0
        pipe.submit_write(lba, blk, at=t)
    pipe.drain()
    got = {}
    for lba in range(24):
        pipe.submit_read(lba, 1, cb=lambda out, l=lba: got.__setitem__(l, out[0]),
                         at=t + 100.0 + lba)
    pipe.drain()
    assert all(np.array_equal(got[l], v) for l, v in ref.items())
    rec = pipe.recorder
    w = rec.percentiles(op="W")
    r = rec.percentiles(op="R")
    assert w["n"] == 24 and r["n"] == 24
    assert w["p99"] >= w["p50"] > 0
    assert r["p50"] > 50.0  # a NAND read costs real virtual time
    assert pipe.counters["dispatch"] == 48
    assert pipe.counters["encoding"] >= 8   # stripes committed
    assert pipe.counters["completion"] == 48


def test_timed_acks_follow_virtual_time():
    pipe = _timed_pipe()
    acks = []
    blk = np.ones((1, 256), np.uint8)
    for i in range(12):  # 4 full stripes (k=3) -> immediate group commits
        pipe.submit_write(i, blk, cb=acks.append, at=float(i))
    pipe.drain()
    assert len(acks) == 12
    assert all(a >= 0 for a in acks)
    # engine clock advanced beyond the last submission: device time is real
    assert pipe.engine.now > 11.0


def test_group_barrier_waits_under_backpressure():
    pipe = _timed_pipe(group_size=8)
    blk = np.ones((1, 256), np.uint8)
    # blast arrivals at t=0: consecutive groups must wait for one another
    for i in range(96):
        pipe.submit_write(i % 128, blk, at=0.0)
    pipe.drain()
    assert pipe.recorder.notes.get("group_barrier_wait_us", 0.0) > 0.0


def test_flush_tick_pads_stalled_stripes():
    pipe = _timed_pipe()
    blk = np.ones((1, 256), np.uint8)
    reqs = [Request(0.0, "w", "W", 5, 1)]  # a lone write: stripe never fills
    rec = pipe.replay(reqs, payload_fn=lambda r: blk)
    assert rec.percentiles(op="W")["n"] == 1
    # the ack came from the timeout-flush path, not from a stripe fill
    assert pipe.array.stats.padded_blocks > 0


# ------------------------------------- timing-driven Zone-Append disorder


def _write_workload(rng, n_ops, logical):
    ops = []
    for _ in range(n_ops):
        n = int(rng.integers(1, 3))
        lba = int(rng.integers(0, logical - n))
        ops.append((lba, rng.integers(0, 256, (n, 256), dtype=np.uint8)))
    return ops


@pytest.mark.parametrize("scheme", ["raid5", "raid4", "raid6", "raid01"])
def test_timed_disorder_consistent_with_rng_path(scheme):
    """Timing-driven completion order must yield the same *logical* state as
    the RNG-permutation fallback: identical read-back before and after crash
    recovery, even though physical placements (CST contents) differ."""
    rng = np.random.default_rng(42)
    ops = _write_workload(rng, 60, 128)
    ref = {}
    for lba, data in ops:
        for i in range(data.shape[0]):
            ref[lba + i] = data[i].copy()

    # timed path: disorder from device timing
    pipe = _timed_pipe(scheme=scheme, seed=9)
    t = 0.0
    for lba, data in ops:
        t += 15.0
        pipe.submit_write(lba, data, at=t)
    pipe.drain()
    timed_arr = pipe.array

    # RNG path: seeded permutation in the functional array
    cfg = ZapRaidConfig(scheme=scheme, n_drives=4, group_size=4,
                        chunk_blocks=1, logical_blocks=128,
                        gc_free_segments_low=1, append_order="rng")
    zns = ZnsConfig(n_zones=8, zone_cap_blocks=64, block_bytes=256)
    rng_arr = ZapRAIDArray(cfg, zns)
    for lba, data in ops:
        rng_arr.write(lba, data)
    rng_arr.flush()

    for arr in (timed_arr, rng_arr):
        for lba, want in ref.items():
            np.testing.assert_array_equal(arr.read(lba, 1)[0], want)

    # crash-recover both from their media: recovered state is bit-identical
    # to the reference (and hence across the two ordering paths)
    for arr in (timed_arr, rng_arr):
        rec = recover_array(arr.drives, arr.cfg, arr.zns_cfg)
        for lba, want in ref.items():
            np.testing.assert_array_equal(rec.read(lba, 1)[0], want)


def test_timed_disorder_degraded_reads():
    """CST built under timing-driven placement still decodes every chunk."""
    pipe = _timed_pipe(scheme="raid5", seed=4)
    rng = np.random.default_rng(3)
    ref = {}
    t = 0.0
    for lba in range(96):
        blk = rng.integers(0, 256, (1, 256), dtype=np.uint8)
        ref[lba] = blk[0].copy()
        t += 10.0
        pipe.submit_write(lba, blk, at=t)
    pipe.drain()
    pipe.array.fail_drive(2)
    for lba, want in ref.items():
        np.testing.assert_array_equal(pipe.array.read(lba, 1)[0], want)
    assert pipe.array.stats.degraded_reads > 0


# ------------------------------------------------------------- QoS scenarios


def test_degraded_read_under_load_inflates_tail():
    def run(fail):
        pipe = _timed_pipe(seed=13)
        rng = np.random.default_rng(1)
        pipe.precondition(
            (lba, rng.integers(0, 256, (1, 256), dtype=np.uint8))
            for lba in range(128)
        )
        if fail:
            pipe.array.fail_drive(1)
        load = synthetic(
            TenantSpec(name="r", kind="uniform", n_ops=300,
                       rate_iops=60_000, read_frac=1.0, seed=8),
            logical_blocks=128,
        )
        return pipe.replay(load).percentiles(op="R")

    healthy, degraded = run(False), run(True)
    assert degraded["p99"] > healthy["p99"]
    assert degraded["p50"] >= healthy["p50"]


def test_rebuild_under_load_books_device_time():
    pipe = _timed_pipe(seed=17)
    rng = np.random.default_rng(2)
    pipe.precondition(
        (lba, rng.integers(0, 256, (1, 256), dtype=np.uint8))
        for lba in range(128)
    )
    pipe.array.fail_drive(1)
    pipe.schedule_rebuild(1, at=30.0)
    load = synthetic(
        TenantSpec(name="r", kind="uniform", n_ops=120,
                   rate_iops=30_000, read_frac=1.0, seed=9),
        logical_blocks=128,
    )
    rec = pipe.replay(load)
    assert rec.notes.get("rebuild_device_us", 0.0) > 0.0
    assert not pipe.array.drives[1].failed
    # post-rebuild the array reads clean without degraded decodes
    got = pipe.array.read(0, 1)
    assert got.shape == (1, 256)
