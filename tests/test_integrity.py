"""End-to-end data integrity (DESIGN.md §15): per-block checksums,
silent-corruption fault injection, verify-on-read self-repair, the paced
scrub actor, and checksum-validated recovery.

The acceptance scenario from the PR: a scripted fault plan corrupting
over 1% of written blocks (mixed kinds, across raid4/5/6/01, including a
run with a concurrently failed drive) must end with every corruption
detected, the media bit-identical to a no-fault oracle after a scrub
pass, zero wrong bytes ever returned to a reader, and an unrepairable
double fault surfacing :class:`IntegrityError` instead of garbage.
"""
import json

import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core.array import IntegrityError, ZapRaidConfig, ZapRAIDArray
from repro.core.handlers import HandlerPipeline
from repro.core.recovery import recover_array
from repro.core.segment import (
    FooterError,
    footer_entries_per_block,
    footer_has_crc,
    pack_footer,
    unpack_footer,
)
from repro.core.zns import OOB_DTYPE, ZnsConfig
from repro.integrity import CRC_BYTES, crc32c, crc32c_many, crc32c_pack, verify_many
from repro.sim.faults import MEDIA_KINDS, FaultEvent, FaultPlan

BB = 256
SCHEMES = [("raid4", 4), ("raid5", 4), ("raid6", 6), ("raid01", 4)]


# --------------------------------------------------------------- checksum unit


def test_crc32c_known_vectors():
    # RFC 3720 / iSCSI check value for b"123456789"
    assert crc32c(b"123456789") == 0xE3069283
    assert crc32c(b"") == 0
    assert crc32c(bytes(32)) == 0x8A9136AA  # 32 zero bytes


def test_crc32c_many_matches_scalar():
    rng = np.random.default_rng(3)
    blocks = rng.integers(0, 256, (17, BB), dtype=np.uint8)
    many = crc32c_many(blocks)
    for i in range(blocks.shape[0]):
        assert int(many[i]) == crc32c(blocks[i].tobytes())
    packed = crc32c_pack(many)
    assert packed.shape == (17, CRC_BYTES)
    assert (packed.view("<u4").reshape(-1) == many).all()
    ok = verify_many(blocks, many)
    assert ok.all()
    blocks[5, 0] ^= 1
    assert not verify_many(blocks, many)[5]


# ------------------------------------------------------------ helpers


def _mk(scheme="raid5", n_drives=4, logical=128, zones=12, zone_cap=32,
        **kw):
    kw.setdefault("gc_free_segments_low", 1)
    kw.setdefault("verify_reads", True)
    cfg = ZapRaidConfig(scheme=scheme, n_drives=n_drives, group_size=4,
                        chunk_blocks=1, logical_blocks=logical, **kw)
    zns = ZnsConfig(n_zones=zones, zone_cap_blocks=zone_cap, block_bytes=BB)
    return ZapRAIDArray(cfg, zns), cfg, zns


def _fill(arr, seed=7):
    rng = np.random.default_rng(seed)
    ref = {}
    for lba in range(arr.cfg.logical_blocks):
        b = rng.integers(0, 256, (1, BB), dtype=np.uint8)
        arr.write(lba, b)
        ref[lba] = b[0].copy()
    arr.flush()
    arr._sync_pending()
    return ref


def _inject_mixed(arr, rng, frac=0.02, skip_failed=True):
    """Corrupt ~frac of every drive's written blocks with a kind mix,
    keeping every hit *repairable*: at most one data-region fault per
    stripe group (header/footer blocks regenerate independently, so they
    are unconstrained).  Returns the number of blocks hit.  The checksum
    store is never touched, so every hit is detectable."""
    zone_seg = {}  # (phys drive, zone) -> (SegmentInfo, member)
    for rec in arr.segments.values():
        info = rec.info
        for m in range(info.n_drives):
            zone_seg[(info.drive_ids[m], info.zone_ids[m])] = (info, m)
    hit_groups = set()  # (seg_id, group index) with a data fault already
    n_bad = 0
    for di, d in enumerate(arr.drives):
        if skip_failed and d.failed:
            continue
        flat = np.flatnonzero(d.written_mask().reshape(-1))
        n = max(2, int(flat.size * frac))
        take = rng.choice(flat, size=min(n, flat.size), replace=False)
        cap = d.cfg.zone_cap_blocks
        for i, t in enumerate(take):
            z, o = int(t // cap), int(t % cap)
            hit = zone_seg.get((di, z))
            if hit is not None:
                info, _ = hit
                ds = info.data_start()
                de = ds + info.n_stripes * info.chunk_blocks
                if ds <= o < de:
                    span = max(1, info.group_size) * info.chunk_blocks
                    key = (info.seg_id, (o - ds) // span)
                    if key in hit_groups:
                        continue  # second hit in a stripe group: skip
                    hit_groups.add(key)
            kind = i % 3
            if kind == 0:
                d.corrupt_bit_rot(z, o, byte=int(rng.integers(0, BB)),
                                  bit=int(rng.integers(0, 8)))
            elif kind == 1:
                d.mark_unreadable(z, o)
            else:
                src = int(rng.choice(flat))
                d.corrupt_misdirected_write(z, o, src // cap, src % cap)
            n_bad += 1
    return n_bad


def _sealed_zone_set(arr):
    from repro.core.segment import SegmentState
    out = set()
    for rec in arr.segments.values():
        if rec.info.state == int(SegmentState.SEALED):
            for m in range(rec.info.n_drives):
                out.add((rec.info.drive_ids[m], rec.info.zone_ids[m]))
    return out


def _assert_media_oracle(arr, oracle, sealed_only=True):
    sealed = _sealed_zone_set(arr)
    for di, d in enumerate(arr.drives):
        if d.failed:
            continue
        for z in range(d.cfg.n_zones):
            if sealed_only and (di, z) not in sealed:
                continue
            wp = int(d.wp[z])
            assert (d.data[z, :wp] == oracle[di][z, :wp]).all(), \
                f"drive {di} zone {z} differs from oracle"
            assert not d.unc[z, :wp].any(), f"UNC left on d{di} z{z}"


def _repairable_data_victims(arr, member=0, limit=3):
    """Data-region blocks of ``member`` whose chunk is still reconstructible
    from the surviving redundancy if that one block is lost."""
    from repro.core.segment import SegmentState
    out = []
    for rec in sorted(arr.segments.values(), key=lambda r: r.info.seg_id):
        info = rec.info
        if info.state != int(SegmentState.SEALED) or member >= info.n_drives:
            continue
        phys = info.drive_ids[member]
        if arr.drives[phys].failed:
            continue
        scheme = arr._scheme_for(info)
        c = info.chunk_blocks
        for chunk_idx in range(info.n_stripes):
            seq, members = arr._chunk_members(rec, member, chunk_idx)
            if scheme.mirror:
                role = scheme.drive_to_role(member, seq)
                twin = (role + scheme.k) % (2 * scheme.k)
                ok = any(scheme.drive_to_role(d, seq) == twin for d in members)
            else:
                ok = len(members) >= scheme.k
            if ok:
                out.append((phys, info.zone_ids[member],
                            info.data_start() + chunk_idx * c))
                if len(out) >= limit:
                    return out
    return out


# ------------------------------------------- acceptance: scrub vs oracle


@pytest.mark.parametrize("scheme,n", SCHEMES)
def test_scrub_restores_no_fault_oracle(scheme, n):
    """Mixed media faults on >1% of written blocks: one scrub pass detects
    every corruption, repairs in place, and leaves sealed media
    bit-identical to the pre-fault oracle; every read returns the
    reference bytes."""
    arr, _, _ = _mk(scheme, n_drives=n)
    ref = _fill(arr)
    oracle = [d.data.copy() for d in arr.drives]
    rng = np.random.default_rng(11)
    injected = _inject_mixed(arr, rng, frac=0.02)
    assert injected > 0
    assert sum(d.media_faults for d in arr.drives) == injected
    res = arr.scrub_once()
    assert res["repaired"] > 0
    assert arr.stats.integrity_scrub_passes == 1
    _assert_media_oracle(arr, oracle)
    for lba, want in ref.items():
        assert np.array_equal(arr.read(lba, 1)[0], want), f"lba {lba}"


@pytest.mark.parametrize("scheme,n", [("raid6", 6), ("raid01", 4)])
def test_scrub_with_concurrently_failed_drive(scheme, n):
    """Media faults land while a member drive is failed outright: scrub
    skips the dead member, heals the survivors (their redundancy still
    covers single media faults), and after rebuild the whole array reads
    the reference."""
    arr, _, _ = _mk(scheme, n_drives=n)
    ref = _fill(arr)
    arr.fail_drive(1)
    # with a member already out, only corrupt chunks whose remaining
    # redundancy still covers the hit (raid6: k survivors left; raid01:
    # the mirror twin is on a live drive) -- anything more is the
    # double-fault case tested separately
    victims = _repairable_data_victims(arr, member=0, limit=6)
    assert victims, "no repairable victim chunks found"
    n_bad = 0
    for phys, z, off in victims:
        arr.drives[phys].corrupt_bit_rot(z, off, byte=1, bit=7)
        n_bad += 1
    res = arr.scrub_once()
    assert res["skipped_members"] > 0
    assert res["repaired"] > 0
    for lba, want in ref.items():
        assert np.array_equal(arr.read(lba, 1)[0], want), f"lba {lba}"
    arr.rebuild_drive(1)
    arr.scrub_once()
    for lba, want in ref.items():
        assert np.array_equal(arr.read(lba, 1)[0], want)


def test_unrepairable_double_fault_raises_loudly():
    """Data + parity lost in one raid5 stripe: verify-on-read and scrub
    both surface IntegrityError -- wrong bytes are never returned."""
    arr, _, _ = _mk("raid5")
    ref = _fill(arr)
    # find one user block and corrupt every member's copy of its stripe
    lba = 7
    from repro.core.l2p import NO_PBA, unpack_pba
    pba = arr.l2p.get(lba)
    assert pba != int(NO_PBA)
    seg_id, member, off = unpack_pba(pba)
    rec = arr.segments[seg_id]
    info = rec.info
    c = info.chunk_blocks
    chunk_idx = (off - info.data_start()) // c
    arr.drives[info.drive_ids[member]].corrupt_bit_rot(
        info.zone_ids[member], off, byte=0, bit=0
    )
    # kill every survivor copy of that stripe too (data and parity)
    seq, members = arr._chunk_members(rec, member, int(chunk_idx))
    killed = 0
    for d, cidx in members.items():
        if killed >= 2:
            break  # m=1: two extra losses guarantee < k intact
        z = info.zone_ids[d]
        arr.drives[info.drive_ids[d]].mark_unreadable(
            z, info.data_start() + cidx * c
        )
        killed += 1
    with pytest.raises(IntegrityError):
        arr.read(lba, 1)
    with pytest.raises(IntegrityError):
        arr.scrub_segment(seg_id)
    # other stripes still read clean
    for other in range(20, 30):
        assert np.array_equal(arr.read(other, 1)[0], ref[other])


# ------------------------------------------------- verify-on-read + cache


def test_verify_on_read_repairs_in_place():
    """A corrupt block hit by a foreground read is detected, reconstructed
    through parity, rewritten in place, and the counters advance."""
    arr, _, _ = _mk("raid5")
    ref = _fill(arr)
    from repro.core.l2p import unpack_pba
    lba = 42
    seg_id, member, off = unpack_pba(arr.l2p.get(lba))
    info = arr.segments[seg_id].info
    d = arr.drives[info.drive_ids[member]]
    z = info.zone_ids[member]
    d.corrupt_bit_rot(z, off, byte=9, bit=3)
    crc_before = int(d.crc[z, off])
    got = arr.read(lba, 1)[0]
    assert np.array_equal(got, ref[lba])
    assert arr.stats.integrity_corruptions_detected >= 1
    assert arr.stats.integrity_blocks_repaired >= 1
    # media healed: a raw read now matches the checksum store again
    assert int(crc32c_many(d.read(z, off, 1))[0]) == crc_before
    # scalar path too
    d.mark_unreadable(z, off)
    got = arr._read_block(lba)
    assert np.array_equal(got, ref[lba])
    assert not d.unc[z, off]


def test_repair_refreshes_warm_cache():
    """Cache coherence with repair: resident copies are refreshed when
    their block is repaired, fills only ever carry verified bytes, and a
    warm cache never serves pre-repair garbage."""
    from repro.cache import CacheConfig, ZnsCacheTier

    arr, cfg, _ = _mk("raid5")
    cache = ZnsCacheTier(
        CacheConfig(n_zones=4, zone_cap_blocks=64, block_bytes=BB,
                    admit_threshold=1),
        cfg.logical_blocks,
    )
    arr.attach_cache(cache)
    ref = _fill(arr)
    # warm the cache with every lba (repeat so the admission sketch sees
    # the keys as reused), then corrupt media underneath the warm copies
    for _ in range(3):
        for lba in ref:
            arr.read(lba, 1)
    assert cache.resident_count() > 0
    rng = np.random.default_rng(5)
    _inject_mixed(arr, rng, frac=0.05)
    arr.scrub_once()
    # every resident copy equals the repaired (reference) bytes
    served_from_cache = 0
    for lba, want in ref.items():
        row = cache.lookup_one(lba << 1)
        if row is not None:
            served_from_cache += 1
            assert np.array_equal(row, want), f"stale cache row for {lba}"
        assert np.array_equal(arr.read(lba, 1)[0], want)
    assert served_from_cache > 0
    assert arr.stats.integrity_blocks_repaired > 0


# ------------------------------------------------- fault plan + timed actor


def test_probabilistic_media_mix_plan_shape():
    """One seeded plan drives drive-failure cycles AND a weighted media
    mix; kinds follow the weights, events stay inside the horizon, and
    the same seed reproduces the same plan."""
    mix = {"bit_rot": 3.0, "unreadable": 1.0, "misdirected_write": 1.0,
           "torn_write": 0.5}
    kw = dict(n_drives=4, horizon_us=200_000.0, mtbf_us=60_000.0,
              repair_after_us=5_000.0, seed=99, media_mix=mix,
              media_mtbf_us=1_500.0)
    plan = FaultPlan.probabilistic(**kw)
    plan2 = FaultPlan.probabilistic(**kw)
    assert [(e.t_us, e.kind, e.drive) for e in plan.events] == \
           [(e.t_us, e.kind, e.drive) for e in plan2.events]
    kinds = [e.kind for e in plan.events]
    assert "fail" in kinds and "rebuild" in kinds
    media = [k for k in kinds if k in MEDIA_KINDS]
    assert len(media) > 20
    assert media.count("bit_rot") > media.count("torn_write")
    assert all(0 <= e.t_us for e in plan.events)
    assert all(e.t_us < 200_000.0 + 5_000.0 for e in plan.events)
    with pytest.raises(ValueError):
        FaultPlan.probabilistic(n_drives=4, horizon_us=1e5, seed=1,
                                media_mix={"bogus": 1.0},
                                media_mtbf_us=100.0)
    with pytest.raises(ValueError):
        FaultPlan.probabilistic(n_drives=4, horizon_us=1e5, seed=1,
                                media_mix={"bit_rot": 1.0})


def _timed_pipe(scheme="raid5", seed=0, logical=128, zones=10, n_drives=4,
                **cfg_kw):
    cfg_kw.setdefault("verify_reads", True)
    cfg = ZapRaidConfig(scheme=scheme, n_drives=n_drives, group_size=4,
                        chunk_blocks=1, logical_blocks=logical,
                        gc_free_segments_low=1, **cfg_kw)
    zns = ZnsConfig(n_zones=zones, zone_cap_blocks=64, block_bytes=BB)
    return HandlerPipeline.build_timed(cfg, zns, seed=seed,
                                       flush_interval_us=200.0)


def test_timed_scrub_actor_heals_under_load():
    """Scripted media faults land mid-write-stream; the paced scrub actor
    walks the sealed segments on the virtual clock, books device time
    (``notes["scrub_device_us"]``), repairs everything it finds, and the
    drained array reads the reference."""
    pipe = _timed_pipe()
    # victims pinned to distinct stripe groups of zone 0 (group span 4,
    # data start 1) so no stripe ever takes two faults -- a raid5 stripe
    # with two losses is the separately-tested unrepairable case
    plan = FaultPlan.scripted([
        FaultEvent(t_us=t, kind=kind, drive=d, zone=0, off=off)
        for t, kind, d, off in [
            (900.0, "bit_rot", 0, 5), (1400.0, "unreadable", 2, 9),
            (1900.0, "bit_rot", 3, 13), (2400.0, "misdirected_write", 1, 17),
        ]
    ])
    inj = pipe.attach_faults(plan, seed=4)
    rng = np.random.default_rng(5)
    ref = {}
    t = 0.0
    for _ in range(4):
        for lba in range(0, 128, 2):
            blk = rng.integers(0, 256, (2, BB), dtype=np.uint8)
            pipe.submit_write(lba, blk, at=t)
            ref[lba], ref[lba + 1] = blk[0].copy(), blk[1].copy()
            t += 8.0
    pipe.schedule_scrub(at=t + 500.0, interval_us=50.0)
    pipe.drain()
    assert len(inj.log) > 0
    assert pipe.array.stats.integrity_scrub_passes >= 1
    assert pipe.recorder.notes.get("scrub_device_us", 0.0) > 0.0
    # faults on sealed media were repaired by the scrub (open-zone hits
    # are healed by verify-on-read when touched)
    for lba, want in ref.items():
        assert np.array_equal(pipe.array.read(lba, 1)[0], want), f"lba {lba}"


def test_timed_mixed_plan_failures_and_media():
    """The acceptance-style timed run: one probabilistic plan fires a
    drive failure/rebuild cycle and a media-fault mix over the same
    horizon -- media faults land *during* the outage, which is why the
    array is raid6 (a second loss per stripe must stay repairable);
    scrub + verify-on-read keep every read correct and no reader ever
    sees wrong bytes."""
    plan = FaultPlan.probabilistic(
        n_drives=5, horizon_us=3500.0, mtbf_us=1_500.0,
        repair_after_us=900.0, seed=21, rebuild_interval_us=30.0,
        media_mix={"bit_rot": 2.0, "unreadable": 1.0}, media_mtbf_us=400.0,
    )
    assert any(e.kind == "fail" for e in plan.events)
    assert any(e.kind in MEDIA_KINDS for e in plan.events)
    pipe = _timed_pipe("raid6", n_drives=5)
    inj = pipe.attach_faults(plan, seed=2)
    rng = np.random.default_rng(8)
    ref = {}
    t = 0.0
    for _ in range(4):
        for lba in range(0, 128, 2):
            blk = rng.integers(0, 256, (2, BB), dtype=np.uint8)
            pipe.submit_write(lba, blk, at=t)
            ref[lba], ref[lba + 1] = blk[0].copy(), blk[1].copy()
            t += 8.0
    pipe.drain()
    assert not any(d.failed for d in pipe.array.drives)
    pipe.array.scrub_once()
    fired = {k for _, k, _ in inj.log}
    assert fired & set(MEDIA_KINDS)
    for lba, want in ref.items():
        assert np.array_equal(pipe.array.read(lba, 1)[0], want), f"lba {lba}"


# ----------------------------------------------- recovery winner resolution


def test_recovery_corrupt_header_loses_to_intact_copy():
    """A rotted header replica must not decide segment geometry: the scan
    skips it (media checksum) and installs from an intact member."""
    for batched in (True, False):
        arr, cfg, zns = _mk("raid5", **{"batched": batched})
        ref = _fill(arr)
        rec = next(iter(arr.segments.values()))
        info = rec.info
        d = arr.drives[info.drive_ids[0]]
        d.corrupt_bit_rot(info.zone_ids[0], 0, byte=10, bit=1)  # header block
        arr2 = recover_array(arr.drives, cfg, zns)
        assert info.seg_id in arr2.segments, "segment lost to a rotted header"
        got = arr2.segments[info.seg_id].info
        assert got.zone_ids == info.zone_ids
        for lba, want in ref.items():
            assert np.array_equal(arr2.read(lba, 1)[0], want)


def test_recovery_corrupt_footer_falls_back_to_oob():
    """A sealed segment whose footer rotted on one member: recovery takes
    the OOB-area scan for that member instead of installing garbage
    mappings, and every winner still resolves correctly."""
    for batched in (True, False):
        arr, cfg, zns = _mk("raid5", **{"batched": batched})
        ref = _fill(arr)
        from repro.core.segment import SegmentState
        rec = next(r for r in arr.segments.values()
                   if r.info.state == int(SegmentState.SEALED))
        info = rec.info
        foot_start = info.data_start() + info.n_stripes * info.chunk_blocks
        d = arr.drives[info.drive_ids[1]]
        z = info.zone_ids[1]
        assert int(d.wp[z]) > foot_start
        d.corrupt_bit_rot(z, foot_start, byte=2, bit=5)
        arr2 = recover_array(arr.drives, cfg, zns)
        for lba, want in ref.items():
            assert np.array_equal(arr2.read(lba, 1)[0], want)


# ----------------------------------------------------- footer fuzz (hypothesis)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=60), st.randoms())
def test_footer_roundtrip_fuzz(n_entries, rnd):
    """pack/unpack roundtrips under truncation and corruption: equality on
    clean footers, FooterError (never garbage mappings) on truncated or
    checksum-failing ones."""
    assert footer_has_crc(BB)
    rng = np.random.default_rng(rnd.randint(0, 1 << 30))
    entries = np.zeros(n_entries, dtype=OOB_DTYPE)
    entries["lba"] = rng.integers(0, 1 << 40, n_entries).astype(np.uint64)
    entries["ts"] = rng.integers(0, 1 << 40, n_entries).astype(np.uint64)
    entries["stripe"] = rng.integers(0, 1 << 20, n_entries).astype(np.uint32)
    blocks = pack_footer(entries, BB)
    back = unpack_footer(blocks, n_entries, BB, strict=True)
    assert (back == entries).all()
    # truncation: drop the last block when entries spill past one block
    if blocks.shape[0] > 1:
        with pytest.raises(FooterError):
            unpack_footer(blocks[:-1], n_entries, BB, strict=False)
    # corruption in the entry area: strict unpack refuses
    epb = footer_entries_per_block(BB)
    bad = blocks.copy()
    byte = int(rng.integers(0, epb * 20))
    bad[int(rng.integers(0, bad.shape[0])), byte] ^= 0x40
    with pytest.raises(FooterError):
        unpack_footer(bad, n_entries, BB, strict=True)
    # blocks too narrow to hold even one entry row
    with pytest.raises(FooterError):
        unpack_footer(np.zeros((1, 16), np.uint8), 1, BB)


# --------------------------------------- ROADMAP: capacity-tight manual GC


def test_manual_gc_capacity_tight_keeps_restage_zone():
    """ROADMAP known issue: manual-GC configs (``gc_free_segments_low=0``)
    on capacity-tight geometry driven to the edge.  The PR 9 1-zone open
    floor must leave ``gc_once`` a restage destination: foreground opens
    stop with a loud RuntimeError instead of eating the last zone, and a
    manual GC pass still runs and frees space."""
    arr, _, _ = _mk("raid5", logical=96, zones=5, zone_cap=32,
                    gc_free_segments_low=0)
    assert arr.reserved_zones() == 1  # the manual-GC fallback floor
    rng = np.random.default_rng(1)
    blocked = False
    for i in range(2000):
        lba = int(rng.integers(0, 96))
        blk = rng.integers(0, 256, (1, BB), dtype=np.uint8)
        try:
            arr.write(lba, blk)
        except RuntimeError as e:
            assert "out of free zones" in str(e)
            blocked = True
            break
    assert blocked, "geometry never reached the capacity edge"
    # the floor kept a restage zone: manual GC can still make progress
    # (no deadlock opening its destination segment)
    freed = arr.gc_once()
    assert freed, "manual gc_once made no progress at the capacity edge"
    arr.write(0, rng.integers(0, 256, (1, BB), dtype=np.uint8))
    arr.flush()
