"""Per-architecture smoke tests (reduced configs, same family): one forward
and one train step on CPU asserting output shapes and no NaNs, plus
decode-vs-forward consistency for the cache/state machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.config import smoke
from repro.models.model import build_model
from repro.optim import adamw
from repro.train import steps as steps_mod


def make_batch(cfg, b=2, t=16):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, t)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, t)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            0.1 * rng.standard_normal((b, cfg.enc_len, cfg.d_model)), jnp.float32
        )
    if cfg.family == "vlm":
        batch["vis_embeds"] = jnp.asarray(
            0.1 * rng.standard_normal((b, cfg.vis_prefix_len, cfg.vis_embed_dim)),
            jnp.float32,
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = smoke(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2)
    _, train_step = steps_mod.make_train_step(cfg, opt_cfg)
    opt_state = steps_mod.init_opt_state(model, params, opt_cfg)
    p2, o2, metrics = jax.jit(train_step)(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params must actually change
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert delta > 0, f"{arch}: optimizer produced no update"


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_param_count_matches_config_formula(arch):
    cfg = smoke(get_config(arch))
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    predicted = cfg.param_count()
    assert abs(actual - predicted) / actual < 0.05, (
        f"{arch}: param_count() {predicted} vs actual {actual}"
    )


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen2.5-3b", "mamba2-1.3b",
                                  "zamba2-2.7b", "whisper-small",
                                  "llama4-scout-17b-a16e"])
def test_decode_matches_forward(arch):
    """prefill(t tokens) + decode_step x k must equal forward(t+k tokens).

    MoE archs need ample routing capacity here: capacity-dropping changes
    teacher-forced activations vs decode (where the single token always
    fits), which is expected behaviour, not a cache bug."""
    cfg = smoke(get_config(arch), capacity_factor=16.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    b, t, extra = 2, 12, 3
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, t + extra)), jnp.int32)
    batch = {"tokens": tokens[:, :t]}
    full = {"tokens": tokens}
    if cfg.family == "encdec":
        frames = jnp.asarray(
            0.1 * rng.standard_normal((b, cfg.enc_len, cfg.d_model)), jnp.float32
        )
        batch["frames"] = frames
        full["frames"] = frames
    if cfg.family == "vlm":
        vis = jnp.asarray(
            0.1 * rng.standard_normal((b, cfg.vis_prefix_len, cfg.vis_embed_dim)),
            jnp.float32,
        )
        batch["vis_embeds"] = vis
        full["vis_embeds"] = vis

    logits_pref, cache = jax.jit(model.prefill)(params, batch)

    # full-forward reference logits at the decoded positions
    full["labels"] = full["tokens"]
    x_logits = _forward_logits(model, cfg, params, full)

    # grow attention caches to t+extra capacity
    def grow(c):
        out = dict(c)
        for kname in ("k", "v", "ak", "av"):
            if kname in out:
                arr = out[kname]
                pad = [(0, 0)] * arr.ndim
                pad[2] = (0, extra)
                out[kname] = jnp.pad(arr, pad)
        return out

    cache = grow(cache)
    step = jax.jit(model.decode_step)
    logits = logits_pref
    for i in range(extra):
        np.testing.assert_allclose(
            np.asarray(logits[:, -1], np.float32),
            np.asarray(x_logits[:, t - 1 + i], np.float32),
            atol=2e-2, rtol=2e-2,
        )
        logits, cache = step(params, cache, tokens[:, t + i : t + i + 1])


def _forward_logits(model, cfg, params, batch):
    """Teacher-forced logits over the full sequence (loss path, pre-CE)."""
    import repro.models.model as mm
    import repro.models.layers as L

    if cfg.family in ("dense", "moe", "vlm"):
        x = model._inputs(params, batch)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x, _ = model._trunk(params, x, positions)
        if cfg.family == "vlm" and "vis_embeds" in batch:
            x = x[:, batch["vis_embeds"].shape[1]:, :]
        return model._logits(params, x)
    if cfg.family in ("ssm", "hybrid"):
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x, _, _ = model._trunk(params, x, positions)
        return jnp.einsum("btd,dv->btv", x, params["lm_head"])
    # encdec
    enc_out = model._encode(params, batch["frames"])
    ck, cv = model._cross_kv(params, enc_out)
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    def fwd(h, xs):
        p_layer, k, v = xs
        h2, _ = model._dec_layer(p_layer, h, positions, k, v)
        return h2, 0

    x, _ = jax.lax.scan(fwd, x, (params["dec_layers"], ck, cv))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return jnp.einsum("btd,dv->btv", x, params["embed"].T) * cfg.d_model ** -0.5


def test_blocked_attention_equals_dense():
    """Blocked causal attention must be exact vs the naive formulation."""
    from repro.models.layers import blocked_causal_attention

    rng = np.random.default_rng(3)
    b, t, h, kv, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, t, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, kv, hd)), jnp.float32)
    out_blocked = blocked_causal_attention(q, k, v, q_block=16)
    out_full = blocked_causal_attention(q, k, v, q_block=t)
    np.testing.assert_allclose(
        np.asarray(out_blocked), np.asarray(out_full), atol=1e-5, rtol=1e-5
    )


def test_moe_routing_conservation():
    """Every kept token's outputs are scaled by normalized top-k probs; with
    capacity ample, outputs must be finite and nonzero for all tokens."""
    from repro.models.layers import init_moe, moe_apply

    cfg = smoke(get_config("grok-1-314b"), n_experts=4, capacity_factor=4.0)
    p, _ = init_moe(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    y = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(jnp.mean(jnp.abs(y))) > 0
