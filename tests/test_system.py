"""End-to-end behaviour tests for the full system: a training run with
checkpoint/restart + lane failure, and dry-run spec resolution for every
architecture (reduced-size lower on the local device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.config import smoke
from repro.launch import train as train_mod


def test_end_to_end_training_with_failure_and_restart(capsys):
    losses = train_mod.run([
        "--arch", "qwen2.5-3b", "--steps", "6", "--ckpt-every", "2",
        "--fail-lane", "1", "--fail-at", "3", "--restart-at", "4",
        "--global-batch", "4", "--seq-len", "32",
    ])
    assert len(losses) >= 6
    assert all(np.isfinite(l) for l in losses)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_lower_on_local_mesh(arch):
    """Every architecture's train step lowers+compiles on the local mesh with
    the same sharding machinery the production dry-run uses."""
    from repro.distributed import sharding as sh
    from repro.optim import adamw
    from repro.train import steps as steps_mod

    cfg = smoke(get_config(arch))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    opt_cfg = adamw.AdamWConfig()
    model, train_step = steps_mod.make_train_step(cfg, opt_cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = sh.param_specs(params, model.axes(), mesh, fsdp=cfg.fsdp)
    opt = jax.eval_shape(
        lambda p: steps_mod.init_opt_state(model, p, opt_cfg), params
    )
    ospecs = adamw.state_specs(pspecs, params, mesh)
    b, t = 2, 16
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, t), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, t), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_len, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["vis_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vis_prefix_len, cfg.vis_embed_dim), jnp.float32
        )
    bspecs = {k: sh.data_spec(mesh, len(v.shape), batch_size=b) for k, v in batch.items()}
    fn = jax.jit(
        train_step,
        in_shardings=(sh.named(mesh, pspecs), sh.named(mesh, ospecs),
                      sh.named(mesh, bspecs)),
    )
    compiled = fn.lower(params, opt, batch).compile()
    assert compiled is not None
