"""Zero-copy background services: bit-identity of the batched GC and
crash-recovery pipelines with their scalar baselines.

The vectorized GC pipeline (cost-benefit victim scoring, one gather + OOB
read per drive, mask-resolved liveness, bulk arena restage) and the batched
recovery scanner (cross-zone header gather, vectorized stripe scan, lexsort
winner resolution, bulk L2P/validity install) must leave *exactly* the
media, OOB, write pointers, L2P, validity and stats the per-block scalar
paths produce -- across schemes, with mapping blocks in flight, with a
failed drive, and after a crash armed mid-GC.  See DESIGN.md §10.
"""
import numpy as np
import pytest

from repro.core.array import ZapRaidConfig, ZapRAIDArray
from repro.core.recovery import recover_array
from repro.core.segment import SegmentState
from repro.core.zns import DeviceCrashed, ZnsConfig

BB = 256
SCHEMES = [("raid4", 4), ("raid5", 4), ("raid6", 5), ("raid01", 4)]


def _mk(batched, scheme="raid5", n_drives=4, zones=6, logical=600, **kw):
    kw.setdefault("gc_free_segments_low", 2)
    cfg = ZapRaidConfig(scheme=scheme, n_drives=n_drives, group_size=8,
                        chunk_blocks=1, logical_blocks=logical,
                        batched=batched, **kw)
    zns = ZnsConfig(n_zones=zones, zone_cap_blocks=64, block_bytes=BB)
    return ZapRAIDArray(cfg, zns), cfg, zns


def _churn(arr, n_writes, logical, seed=7):
    """Sequential wrap-around overwrites: victims keep partial liveness, so
    GC genuinely moves blocks (not just reclaims fully-stale segments)."""
    rng = np.random.default_rng(seed)
    ref = {}
    for i in range(n_writes):
        lba = i % logical
        blk = rng.integers(0, 256, (1, BB), dtype=np.uint8)
        arr.write(lba, blk)
        ref[lba] = blk[0].copy()
    arr.flush()
    return ref


def _assert_state_identical(a1, a0):
    for d1, d0 in zip(a1.drives, a0.drives):
        assert np.array_equal(d1.data, d0.data)
        assert np.array_equal(d1.oob, d0.oob)
        assert np.array_equal(d1.wp, d0.wp)
    assert set(a1.segments) == set(a0.segments)
    for sid in a1.segments:
        assert np.array_equal(a1.segments[sid].valid, a0.segments[sid].valid)
        assert a1.segments[sid].valid_count == a0.segments[sid].valid_count
    if not a1.l2p.offload:
        assert np.array_equal(a1.l2p.flat, a0.l2p.flat)
    else:
        assert sorted(a1.l2p.resident) == sorted(a0.l2p.resident)
        for g in a1.l2p.resident:
            assert np.array_equal(a1.l2p.resident[g], a0.l2p.resident[g])
    assert a1.mapping_table == a0.mapping_table
    s1, s0 = a1.stats, a0.stats
    for f in ("host_blocks_written", "device_blocks_written",
              "stripes_committed", "padded_blocks", "gc_runs",
              "gc_blocks_moved", "meta_blocks_written", "degraded_reads",
              "recovery_blocks_read"):
        assert getattr(s1, f) == getattr(s0, f), f
    assert a1.ts_counter == a0.ts_counter


# ------------------------------------------------------------ GC identity

@pytest.mark.parametrize("scheme,n_drives", SCHEMES)
def test_gc_pipeline_identical_to_scalar(scheme, n_drives):
    """GC with real moves: batched collection + bulk restage vs the scalar
    per-block baseline leave identical media/OOB/L2P/validity/stats."""
    logical = 360 if scheme == "raid01" else 600
    n_writes = 1000 if scheme == "raid01" else 1400
    a1, *_ = _mk(True, scheme, n_drives, logical=logical)
    a0, *_ = _mk(False, scheme, n_drives, logical=logical)
    r1 = _churn(a1, n_writes, logical)
    r0 = _churn(a0, n_writes, logical)
    assert a1.stats.gc_runs > 0
    assert a1.stats.gc_blocks_moved > 0  # victims were partially live
    _assert_state_identical(a1, a0)
    for lba, want in r1.items():
        assert np.array_equal(a1.read(lba, 1)[0], want)
    assert r1.keys() == r0.keys()


@pytest.mark.parametrize("scheme,n_drives", SCHEMES)
def test_gc_degraded_collection_identical(scheme, n_drives):
    """With a failed drive, batched collection reconstructs the lost chunks
    through the fused whole-chunk decode and yields exactly the scalar
    per-block degraded-read collection (payloads, LBAs, order)."""
    logical = 360 if scheme == "raid01" else 600
    a1, *_ = _mk(True, scheme, n_drives, logical=logical,
                 gc_free_segments_low=0)  # no auto-GC: inspect collection
    a0, *_ = _mk(False, scheme, n_drives, logical=logical,
                 gc_free_segments_low=0)
    _churn(a1, logical + 200, logical)
    _churn(a0, logical + 200, logical)
    sealed1 = sorted(
        (r for r in a1.segments.values()
         if r.info.state == int(SegmentState.SEALED)),
        key=lambda r: r.info.seg_id,
    )
    sealed0 = sorted(
        (r for r in a0.segments.values()
         if r.info.state == int(SegmentState.SEALED)),
        key=lambda r: r.info.seg_id,
    )
    assert sealed1 and len(sealed1) == len(sealed0)
    moved_any = False
    for failed in range(n_drives):
        a1.drives[failed].failed = True
        a0.drives[failed].failed = True
        for rec1, rec0 in zip(sealed1, sealed0):
            got = a1._gc_collect_batched(rec1)
            want = a0._gc_collect_scalar(rec0)
            for g, w in zip(got, want):
                assert np.array_equal(g, w), (scheme, failed)
            moved_any = moved_any or got[0].size > 0
        a1.drives[failed].failed = False
        a0.drives[failed].failed = False
    assert moved_any  # the comparison exercised live blocks


def test_gc_moves_mapping_blocks_batched():
    """Satellite: mapping blocks (L2P offload) restage through the bulk
    append path under ``batched=True`` and stay bit-identical to scalar."""
    from repro.core.l2p import unpack_pba

    def run(batched):
        arr, *_ = _mk(batched, zones=12, logical=600,
                      gc_free_segments_low=0,
                      l2p_memory_limit_entries=128)
        rng = np.random.default_rng(7)
        for lba in range(600):  # fill every entry group once
            arr.write(lba, rng.integers(0, 256, (1, BB), dtype=np.uint8))
        arr.flush()
        for i in range(700):    # then churn only a hot range: the cold
            # groups' mapping blocks stay live inside future GC victims
            arr.write(i % 128, rng.integers(0, 256, (1, BB), dtype=np.uint8))
        arr.flush()
        meta_moved = 0
        for _ in range(4):
            victim = arr._gc_select_victim()
            if victim is None:
                break
            vid = victim.info.seg_id
            meta_moved += sum(
                1 for p in arr.mapping_table.values() if unpack_pba(p)[0] == vid
            )
            if not arr.gc_once():
                break
        return arr, meta_moved

    a1, m1 = run(True)
    a0, m0 = run(False)
    assert a1.stats.gc_runs > 0
    assert m1 > 0 and m1 == m0  # live mapping blocks actually moved
    _assert_state_identical(a1, a0)


def test_gc_cost_benefit_prefers_stale_victims():
    """The vectorized scorer picks a (mostly) stale victim over a hot one."""
    arr, *_ = _mk(True, zones=8, logical=600, gc_free_segments_low=0)
    _churn(arr, 1100, 600)
    sealed = [r for r in arr.segments.values()
              if r.info.state == int(SegmentState.SEALED)]
    assert len(sealed) >= 2
    victim = arr._gc_select_victim()
    assert victim is not None
    # no sealed segment with a strictly better (lower) utilization at
    # comparable-or-greater age should have been passed over entirely
    u_victim = victim.valid_count / victim.data_capacity()
    assert u_victim < 1.0
    fullest = max(sealed, key=lambda r: r.valid_count / r.data_capacity())
    if fullest.valid_count < fullest.data_capacity():
        u_full = fullest.valid_count / fullest.data_capacity()
        assert u_victim <= u_full + 1e-9


# ------------------------------------------------------ recovery identity

@pytest.mark.parametrize("scheme,n_drives", [("raid5", 4), ("raid6", 5)])
def test_recovery_scan_identical_to_scalar(scheme, n_drives):
    """Clean-shutdown recovery: batched header/OOB scans + lexsort winners +
    bulk install reproduce the scalar recovery bit for bit."""
    def run(batched):
        arr, cfg, zns = _mk(batched, scheme, n_drives)
        ref = _churn(arr, 1400, 600)
        arr2 = recover_array(arr.drives, cfg, zns)
        return arr2, ref

    a1, r1 = run(True)
    a0, r0 = run(False)
    _assert_state_identical(a1, a0)
    for lba, want in r1.items():
        assert np.array_equal(a1.read(lba, 1)[0], want)


def test_recovery_open_segment_scan_identical():
    """Stop mid-segment (no flush-to-seal): the OOB scan path dominates and
    must agree across modes, including stripes_written cursors."""
    def run(batched):
        arr, cfg, zns = _mk(batched, zones=8)
        rng = np.random.default_rng(23)
        for i in range(150):  # well short of sealing
            arr.write(i % 600, rng.integers(0, 256, (1, BB), dtype=np.uint8))
        arr.flush()
        return recover_array(arr.drives, cfg, zns), arr

    a1, _ = run(True)
    a0, _ = run(False)
    _assert_state_identical(a1, a0)
    for sid, ost in a1.open_segments.items():
        assert ost.info.stripes_written == \
            a0.open_segments[sid].info.stripes_written


def test_recovery_after_crash_identical():
    """Crash mid-workload: both modes recover to identical state."""
    def run(batched):
        arr, cfg, zns = _mk(batched)
        rng = np.random.default_rng(7)
        for i in range(1400):
            blk = rng.integers(0, 256, (1, BB), dtype=np.uint8)
            if i == 1180:
                arr.arm_crash(40)
            try:
                arr.write(i % 600, blk)
            except DeviceCrashed:
                break
        return recover_array(arr.drives, cfg, zns)

    a1 = run(True)
    a0 = run(False)
    _assert_state_identical(a1, a0)


def test_recovery_after_crash_armed_mid_gc():
    """The crash budget bites during a GC restage: half-moved survivors, a
    victim segment not yet reclaimed.  Recovery must converge to identical
    state in both modes and stay writable."""
    def run(batched):
        arr, cfg, zns = _mk(batched)
        crashed_in_gc = [False]
        orig = arr.gc_once

        def traced():
            try:
                return orig()
            except DeviceCrashed:
                crashed_in_gc[0] = True
                raise

        arr.gc_once = traced
        rng = np.random.default_rng(7)
        for i in range(1400):
            blk = rng.integers(0, 256, (1, BB), dtype=np.uint8)
            if i == 694:  # the next write triggers GC (seeded workload)
                arr.arm_crash(100)
            try:
                arr.write(i % 600, blk)
            except DeviceCrashed:
                break
        assert crashed_in_gc[0], "crash point must land inside gc_once"
        return recover_array(arr.drives, cfg, zns)

    a1 = run(True)
    a0 = run(False)
    _assert_state_identical(a1, a0)
    # still writable post-recovery
    blk = np.random.default_rng(1).integers(0, 256, (1, BB), dtype=np.uint8)
    a1.write(3, blk)
    a1.flush()
    assert np.array_equal(a1.read(3, 1)[0], blk[0])


def test_recovery_offload_identical():
    """L2P offload: mapping-block winners, stay-offloaded groups and the
    resident set agree across modes."""
    def run(batched):
        arr, cfg, zns = _mk(batched, zones=24, logical=512,
                            gc_free_segments_low=1,
                            l2p_memory_limit_entries=128)
        rng = np.random.default_rng(4)
        ref = {}
        for _ in range(800):
            lba = int(rng.integers(0, 512))
            blk = rng.integers(0, 256, (1, BB), dtype=np.uint8)
            arr.write(lba, blk)
            ref[lba] = blk[0].copy()
        arr.flush()
        return recover_array(arr.drives, cfg, zns), ref

    a1, r1 = run(True)
    a0, r0 = run(False)
    _assert_state_identical(a1, a0)
    for lba, want in r1.items():
        assert np.array_equal(a1.read(lba, 1)[0], want)


# --------------------------------------------- rebuild satellites / actors

def test_rebuild_scalar_open_segment_path():
    """Non-batched rebuild fallback covers the open-segment n_chunks path
    (per-chunk loop + hoisted scaffolding) and matches the batched rebuild."""
    def run(batched):
        arr, *_ = _mk(batched, zones=8)
        rng = np.random.default_rng(29)
        for i in range(220):  # leaves an open segment with stripes written
            arr.write(i % 600, rng.integers(0, 256, (1, BB), dtype=np.uint8))
        arr.flush()
        ost = next(iter(arr.open_segments.values()))
        assert 0 < ost.info.stripes_written < ost.info.n_stripes
        arr.fail_drive(1)
        arr.rebuild_drive(1)
        return arr

    a1 = run(True)
    a0 = run(False)
    for d1, d0 in zip(a1.drives, a0.drives):
        assert np.array_equal(d1.data, d0.data)
        assert np.array_equal(d1.oob, d0.oob)
        assert np.array_equal(d1.wp, d0.wp)


def test_timed_gc_actor_paces_background_cleaning():
    """The rate-limited GC actor cleans proactively on the virtual timeline:
    it books device time, runs at its watermark, and foreground write p99
    under GC pressure stays at or below the inline-GC-burst baseline."""
    from repro.core.handlers import HandlerPipeline
    from repro.sim import TenantSpec, multi_tenant

    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=8,
                        chunk_blocks=1, logical_blocks=360,
                        gc_free_segments_low=1)
    zns = ZnsConfig(n_zones=7, zone_cap_blocks=64, block_bytes=BB)

    def make_pipe():
        rng = np.random.default_rng(11)
        pipe = HandlerPipeline.build_timed(cfg, zns, seed=11)
        pipe.precondition(
            (i % 360, rng.integers(0, 256, (1, BB), dtype=np.uint8))
            for i in range(900)
        )
        return pipe

    load = multi_tenant([
        TenantSpec(name="writer", kind="seq", n_ops=400, rate_iops=50_000,
                   seed=41),
        TenantSpec(name="reader", kind="uniform", n_ops=200,
                   rate_iops=20_000, read_frac=1.0, seed=42),
    ], logical_blocks=360)

    pipe = make_pipe()
    inline = pipe.replay(load)
    p_inline = inline.percentiles(op="W")["p99"]

    pipe = make_pipe()
    pipe.schedule_gc(at=5.0, interval_us=300.0, n_ticks=100)
    actor = pipe.replay(load)
    assert actor.note_counts.get("gc_device_us", 0) > 0  # the actor ran
    assert actor.notes.get("gc_device_us", 0.0) > 0.0    # and booked I/O
    p_actor = actor.percentiles(op="W")["p99"]
    assert p_actor <= p_inline * 1.05  # proactive pacing never worse


def test_timed_paced_rebuild_routes_reads_and_drains():
    """Paced rebuild: sealed zones pending rebuild serve reads through
    reconstruction (no silent zero reads), and the actor drains the
    pending set while booking device time."""
    from repro.core.handlers import HandlerPipeline
    from repro.sim import TenantSpec, multi_tenant

    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=8,
                        chunk_blocks=1, logical_blocks=360,
                        gc_free_segments_low=1)
    zns = ZnsConfig(n_zones=7, zone_cap_blocks=64, block_bytes=BB)
    rng = np.random.default_rng(11)
    pipe = HandlerPipeline.build_timed(cfg, zns, seed=11)
    ref = {}
    writes = []
    for i in range(900):
        lba = i % 360
        blk = rng.integers(0, 256, (1, BB), dtype=np.uint8)
        writes.append((lba, blk))
        ref[lba] = blk[0].copy()
    pipe.precondition(writes)
    arr = pipe.array
    arr.fail_drive(1)
    pipe.schedule_rebuild(1, at=50.0, interval_us=400.0)
    load = multi_tenant([
        TenantSpec(name="reader", kind="uniform", n_ops=300,
                   rate_iops=30_000, read_frac=1.0, seed=31),
    ], logical_blocks=360)
    rec = pipe.replay(load)
    assert not arr._rebuild_pending  # actor drained every pending zone
    assert rec.notes.get("rebuild_device_us", 0.0) > 0.0
    # every read served during the paced rebuild returned correct data
    for (lba, out) in pipe.completed:
        assert np.array_equal(out[0], ref[lba]), lba
    # and the rebuilt drive serves correct data directly
    before = arr.stats.degraded_reads
    got = arr.read(0, 360)
    assert arr.stats.degraded_reads == before
    for lba, want in ref.items():
        assert np.array_equal(got[lba], want)
