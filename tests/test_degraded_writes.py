"""Always-writable degraded array: survivor-width stripes, re-widening
rebuild, crash recovery of mixed-width arrays, and the fault-injection
harness (see DESIGN.md §14).

With a drive failed the array keeps taking writes by opening new stripe
groups at survivor width on the healthy drives; rebuild re-widens those
groups back onto the full drive set.  The tests here pin:

* foreground writes, GC and reads all complete while degraded, across
  raid4/5/6/01;
* after replace + rebuild the array is logically identical to a
  never-failed oracle, and batched/scalar runs under the SAME fault
  schedule leave bit-identical media;
* ``recover_array`` on a crash armed while degraded or during a rebuild
  either recovers (survivor metadata synthesis, zone rewrite) or raises
  :class:`RecoveryError` -- never silently drops durable stripes;
* the :mod:`repro.sim.faults` harness injects failures mid-write, mid-GC
  and mid-checkpoint-save on the timed pipeline, service tier up
  throughout, and the post-rebuild state replays the no-failure oracle;
* the manual-GC escrow floor keeps one restage destination zone.
"""
import numpy as np
import pytest

from repro.core.array import ZapRaidConfig, ZapRAIDArray
from repro.core.handlers import HandlerPipeline
from repro.core.recovery import RecoveryError, recover_array
from repro.core.zns import DeviceCrashed, ZnsConfig
from repro.sim import FaultEvent, FaultPlan

BB = 256
SCHEMES = [("raid4", 4), ("raid5", 4), ("raid6", 5), ("raid01", 4)]


def _mk(batched, scheme="raid5", n_drives=4, zones=8, logical=360, **kw):
    kw.setdefault("gc_free_segments_low", 2)
    cfg = ZapRaidConfig(scheme=scheme, n_drives=n_drives, group_size=8,
                        chunk_blocks=1, logical_blocks=logical,
                        batched=batched, **kw)
    zns = ZnsConfig(n_zones=zones, zone_cap_blocks=64, block_bytes=BB)
    return ZapRAIDArray(cfg, zns), cfg, zns


def _write_phase(arr, ref, rng, n, logical, base=0):
    for i in range(n):
        lba = (base + i) % logical
        blk = rng.integers(0, 256, (1, BB), dtype=np.uint8)
        arr.write(lba, blk)
        ref[lba] = blk[0].copy()


def _check_all(arr, ref):
    for lba, want in ref.items():
        got = arr.read(lba, 1)[0]
        assert np.array_equal(got, want), f"lba {lba} mismatch"


def _assert_media_identical(a1, a0):
    for d1, d0 in zip(a1.drives, a0.drives):
        assert np.array_equal(d1.data, d0.data)
        assert np.array_equal(d1.oob, d0.oob)
        assert np.array_equal(d1.wp, d0.wp)
    assert set(a1.segments) == set(a0.segments)
    for sid in a1.segments:
        assert np.array_equal(a1.segments[sid].valid, a0.segments[sid].valid)
    assert np.array_equal(a1.l2p.flat, a0.l2p.flat)


# ------------------------------------------------- degraded writability


@pytest.mark.parametrize("scheme,n_drives", SCHEMES)
def test_degraded_writes_open_survivor_width_groups(scheme, n_drives):
    """With one drive failed, writes keep landing: new groups open at
    survivor width, reads decode both widths, and GC still runs."""
    arr, cfg, _ = _mk(True, scheme, n_drives)
    rng = np.random.default_rng(11)
    ref = {}
    _write_phase(arr, ref, rng, 300, cfg.logical_blocks)
    arr.flush()
    arr.fail_drive(1)
    assert any(d.failed for d in arr.drives)
    # the array stays writable: fresh data and overwrites of full-width LBAs
    _write_phase(arr, ref, rng, 150, cfg.logical_blocks, base=100)
    arr.flush()
    widths = {len(r.info.drive_ids) for r in arr.segments.values()}
    assert len(widths) > 1, "expected mixed-width segments while degraded"
    assert min(widths) < max(widths) <= cfg.n_drives
    _check_all(arr, ref)
    assert arr.stats.degraded_reads > 0
    # GC also completes while degraded (churn guarantees stale blocks)
    runs_before = arr.stats.gc_runs
    assert arr.gc_once()
    assert arr.stats.gc_runs > runs_before
    _check_all(arr, ref)


@pytest.mark.parametrize("scheme,n_drives", SCHEMES)
def test_rewiden_rebuild_matches_no_failure_oracle(scheme, n_drives):
    """fail -> degraded writes -> replace + rebuild: every LBA reads equal
    to a never-failed oracle run of the same writes, and no survivor-width
    segment survives the re-widening backfill."""
    # degraded mirrors write on a single pair: halve the live set so the
    # survivor pair's zones hold it with GC slack
    logical = 160 if scheme == "raid01" else 360
    n1, n2 = (120, 200) if scheme == "raid01" else (260, 420)

    def run(fail):
        arr, cfg, zns = _mk(True, scheme, n_drives, logical=logical)
        rng = np.random.default_rng(3)
        ref = {}
        _write_phase(arr, ref, rng, n1, cfg.logical_blocks)
        arr.flush()
        if fail:
            arr.fail_drive(2)
        _write_phase(arr, ref, rng, n2, cfg.logical_blocks, base=50)
        arr.flush()
        if fail:
            arr.rebuild_drive(2)
        return arr, cfg, zns, ref

    a_f, cfg, zns, ref_f = run(True)
    a_o, _, _, ref_o = run(False)
    assert ref_f.keys() == ref_o.keys()
    for lba in ref_f:
        assert np.array_equal(ref_f[lba], ref_o[lba])
        assert np.array_equal(a_f.read(lba, 1)[0], a_o.read(lba, 1)[0])
    # re-widening left no narrow groups behind and the drive is healthy
    assert not any(d.failed for d in a_f.drives)
    n_active = len(a_f._active_drive_ids())
    assert all(len(r.info.drive_ids) == n_active
               for r in a_f.segments.values())
    # recovery roundtrip of the mixed-history array is self-consistent
    a_r = recover_array(a_f.drives, cfg, zns)
    _check_all(a_r, ref_f)


@pytest.mark.parametrize("scheme,n_drives", SCHEMES)
def test_batched_vs_scalar_identity_under_fault_schedule(scheme, n_drives):
    """The batched write/GC/rebuild pipelines under the SAME fail/replace
    schedule leave media, OOB, wp and L2P bit-identical to scalar."""
    logical = 160 if scheme == "raid01" else 360
    n1, n2 = (120, 200) if scheme == "raid01" else (240, 400)

    def run(batched):
        arr, cfg, _ = _mk(batched, scheme, n_drives, logical=logical)
        rng = np.random.default_rng(5)
        ref = {}
        _write_phase(arr, ref, rng, n1, cfg.logical_blocks)
        arr.flush()
        arr.fail_drive(0)
        _write_phase(arr, ref, rng, n2, cfg.logical_blocks, base=30)
        arr.flush()
        arr.rebuild_drive(0)
        return arr, ref

    a1, r1 = run(True)
    a0, r0 = run(False)
    _assert_media_identical(a1, a0)
    _check_all(a1, r1)
    _check_all(a0, r0)


# ------------------------------------------------- crash recovery


def test_recover_crash_while_degraded():
    """Crash with a drive failed and survivor-width groups on media: the
    scanner skips the dead drive, synthesizes its OOB from parity, and the
    recovered array serves every LBA (degraded decode), then rebuilds."""
    def run(batched):
        arr, cfg, zns = _mk(batched)
        rng = np.random.default_rng(9)
        ref = {}
        _write_phase(arr, ref, rng, 260, cfg.logical_blocks)
        arr.flush()
        arr.fail_drive(1)
        _write_phase(arr, ref, rng, 300, cfg.logical_blocks, base=40)
        arr.flush()
        # crash: drop the in-memory array, recover from media alone
        a2 = recover_array(arr.drives, cfg, zns)
        return a2, ref, cfg, zns

    a1, ref, cfg, zns = run(True)
    a0, _, _, _ = run(False)
    _check_all(a1, ref)
    _check_all(a0, ref)
    assert a1.stats.degraded_reads > 0
    # still writable at survivor width post-recovery, and rebuildable
    blk = np.random.default_rng(1).integers(0, 256, (1, BB), dtype=np.uint8)
    a1.write(7, blk)
    a1.flush()
    ref[7] = blk[0].copy()
    a1.rebuild_drive(1)
    _check_all(a1, ref)


@pytest.mark.parametrize("batched", [True, False])
def test_recover_crash_armed_during_rebuild(batched):
    """Crash budget bites inside rebuild_drive: some member zones rewritten,
    one mid-zone, the rest untouched (wiped).  recover_array classifies the
    crashed-rebuild zones, rewrites them from survivors, and every LBA
    written before the crash reads back."""
    arr, cfg, zns = _mk(batched)
    rng = np.random.default_rng(13)
    ref = {}
    _write_phase(arr, ref, rng, 260, cfg.logical_blocks)
    arr.flush()
    arr.fail_drive(1)
    _write_phase(arr, ref, rng, 300, cfg.logical_blocks, base=40)
    arr.flush()
    arr.arm_crash(30)  # lands mid-way through the member-zone rewrites
    with pytest.raises(DeviceCrashed):
        arr.rebuild_drive(1)
    a2 = recover_array(arr.drives, cfg, zns)
    _check_all(a2, ref)
    # the finished recovery re-ran the re-widening pass: full width again
    assert not any(d.failed for d in a2.drives)
    a2.rebuild_drive(1)  # idempotent on an already-whole drive
    _check_all(a2, ref)


def test_recover_fails_loudly_on_two_wiped_zones():
    """Two member zones of one segment wiped (no header while others carry
    one) is beyond single-parity reconstruction bookkeeping: the scanner
    must raise RecoveryError, not silently drop the segment."""
    arr, cfg, zns = _mk(True)
    rng = np.random.default_rng(17)
    ref = {}
    _write_phase(arr, ref, rng, 300, cfg.logical_blocks)
    arr.flush()
    sealed = [r for r in arr.segments.values()
              if r.info.seg_id not in arr.open_segments]
    rec = sealed[0]
    for member in (0, 1):
        p = rec.info.drive_ids[member]
        arr.drives[p].reset_zone(rec.info.zone_ids[member])
    with pytest.raises(RecoveryError):
        recover_array(arr.drives, cfg, zns)


def test_recover_fails_loudly_on_wide_wp_spread():
    """A member write pointer more than one group span behind its peers in
    an unsealed segment only happens when a rebuild crashed mid-rewrite --
    the scanner raises instead of dropping the unattributable stripes."""
    arr, cfg, zns = _mk(True)
    rng = np.random.default_rng(19)
    for i in range(40):  # stay short of sealing: one open segment
        arr.write(i, rng.integers(0, 256, (1, BB), dtype=np.uint8))
    arr.flush()
    ost = next(iter(arr.open_segments.values()))
    info = ost.info
    member = 1
    p = info.drive_ids[member]
    z = info.zone_ids[member]
    d = arr.drives[p]
    # simulate the half-rewritten zone: same media, wp rolled back past one
    # group span (media beyond wp is never trusted by the scanner)
    span = info.group_size * info.chunk_blocks
    d.wp[z] = max(info.chunk_blocks, int(d.wp[z]) - (span + 2))
    with pytest.raises(RecoveryError):
        recover_array(arr.drives, cfg, zns)


# ------------------------------------------------- fault injection (timed)


def _timed_pipe(scheme="raid5", seed=0, logical=128, zones=8, **cfg_kw):
    n_drives = 5 if scheme == "raid6" else 4
    cfg = ZapRaidConfig(scheme=scheme, n_drives=n_drives, group_size=4,
                        chunk_blocks=1, logical_blocks=logical,
                        gc_free_segments_low=1, **cfg_kw)
    zns = ZnsConfig(n_zones=zones, zone_cap_blocks=64, block_bytes=BB)
    return HandlerPipeline.build_timed(cfg, zns, seed=seed,
                                       flush_interval_us=200.0)


def _timed_workload(pipe, *, rounds=3, seed=5):
    """Writes spanning the whole LBA range, paced so scheduled faults land
    mid-stream; returns the per-LBA reference and the end time."""
    logical = pipe.array.cfg.logical_blocks
    rng = np.random.default_rng(seed)
    ref = {}
    t = 0.0
    for _ in range(rounds):
        for lba in range(0, logical - 1, 2):
            blk = rng.integers(0, 256, (2, BB), dtype=np.uint8)
            pipe.submit_write(lba, blk, at=t)
            ref[lba] = blk[0].copy()
            ref[lba + 1] = blk[1].copy()
            t += 8.0
    return ref, t


@pytest.mark.parametrize("scheme", ["raid4", "raid5", "raid6", "raid01"])
def test_fault_injection_replays_no_failure_oracle(scheme):
    """Scripted fail + paced rebuild injected mid-write-stream (GC pressure
    live): after drain, every LBA reads equal to an identical run with no
    faults, and the injector log records what fired."""
    t_fail, t_fix = 700.0, 2600.0
    plan = FaultPlan.scripted([
        FaultEvent(t_us=t_fail, kind="fail", drive=1),
        FaultEvent(t_us=t_fix, kind="rebuild", drive=1, interval_us=25.0),
    ])

    def run(faulted):
        pipe = _timed_pipe(scheme)
        inj = pipe.attach_faults(plan) if faulted else None
        ref, _ = _timed_workload(pipe)
        pipe.drain()
        return pipe, inj, ref

    pf, inj, ref_f = run(True)
    po, _, ref_o = run(False)
    assert [(k, d) for _, k, d in inj.log] == [("fail", 1), ("rebuild", 1)]
    assert inj.log[0][0] == pytest.approx(t_fail)
    # the stream kept committing while degraded, then re-widened
    assert not any(d.failed for d in pf.array.drives)
    assert ref_f.keys() == ref_o.keys()
    for lba in ref_f:
        got_f = pf.array.read(lba, 1)[0]
        got_o = po.array.read(lba, 1)[0]
        assert np.array_equal(got_f, ref_f[lba]), f"faulted lba {lba}"
        assert np.array_equal(got_o, got_f), f"oracle divergence at {lba}"


def test_fault_injection_mid_gc_actor():
    """Failure fired while the background-GC actor is mid-campaign: both
    cleaning and foreground writes complete, reads verify."""
    pipe = _timed_pipe(zones=7, logical=96)
    pipe.schedule_gc(at=400.0, interval_us=150.0, n_ticks=60)
    plan = FaultPlan.scripted([
        FaultEvent(t_us=900.0, kind="fail", drive=2),
        FaultEvent(t_us=3600.0, kind="rebuild", drive=2),
    ])
    inj = pipe.attach_faults(plan)
    ref, _ = _timed_workload(pipe, rounds=8)
    pipe.drain()
    assert len(inj.log) == 2
    assert pipe.array.stats.gc_runs > 0
    for lba, want in ref.items():
        assert np.array_equal(pipe.array.read(lba, 1)[0], want)


def test_probabilistic_fault_plan_round_trips():
    """Seeded MTBF fail/repair cycles: each cycle replaces and re-widens, the
    log matches the plan, and the final array serves the whole LBA range."""
    plan = FaultPlan.probabilistic(
        n_drives=4, horizon_us=2500.0, mtbf_us=900.0,
        repair_after_us=600.0, seed=42, rebuild_interval_us=30.0,
    )
    assert plan.events, "seed must produce at least one fail/repair cycle"
    assert [e.kind for e in plan.events[:2]] == ["fail", "rebuild"]
    pipe = _timed_pipe()
    inj = pipe.attach_faults(plan)
    ref, _ = _timed_workload(pipe, rounds=3, seed=8)
    pipe.drain()
    assert len(inj.log) == len(plan.events)
    assert not any(d.failed for d in pipe.array.drives)
    for lba, want in ref.items():
        assert np.array_equal(pipe.array.read(lba, 1)[0], want)


def test_checkpoint_saves_commit_through_failure_and_rebuild():
    """Async checkpoint saves keep committing while a lane drive is failed
    and during the rebuild; every window restores bit-exact afterwards."""
    from repro.checkpoint.zapraid_ckpt import CheckpointConfig, CheckpointEngine
    from repro.service import BlockDeviceService, QosClass

    cfg = CheckpointConfig(group_size=4, chunk_blocks=1, block_bytes=256,
                           zone_cap_blocks=256, n_zones=16, keep_last=3)
    ckpt, pipe = CheckpointEngine.build_timed(cfg, 1024, seed=0,
                                              flush_interval_us=200.0)
    svc = BlockDeviceService(pipe, max_inflight=16)
    svc.register("ckpt", QosClass("ckpt", priority=2))

    def state(seed):
        rng = np.random.default_rng(seed)
        return {"w": rng.standard_normal(128).astype(np.float32),
                "b": rng.standard_normal(64).astype(np.float32)}

    s0, s1, s2 = state(1), state(2), state(3)
    t0 = ckpt.save_async(0, s0, service=svc)
    svc.drain()
    assert t0.done
    # fail a drive, then save mid-degraded: the stream must keep committing
    plan = FaultPlan.scripted([
        FaultEvent(t_us=pipe.engine.now + 10.0, kind="fail", drive=1),
    ])
    inj = pipe.attach_faults(plan)
    t1 = ckpt.save_async(1, s1, service=svc)
    svc.drain()
    assert t1.done and inj.log and inj.log[0][1] == "fail"
    assert any(d.failed for d in pipe.array.drives)
    # paced rebuild with another save racing it
    plan2 = FaultPlan.scripted([
        FaultEvent(t_us=pipe.engine.now + 20.0, kind="rebuild", drive=1,
                   interval_us=25.0),
    ])
    pipe.attach_faults(plan2)
    t2 = ckpt.save_async(2, s2, service=svc)
    svc.drain()
    assert t2.done
    assert not any(d.failed for d in pipe.array.drives)
    for idx, st in ((0, s0), (1, s1), (2, s2)):
        rt = ckpt.restore_async(idx, st, service=svc)
        svc.drain()
        assert rt.done
        for k in st:
            np.testing.assert_array_equal(np.asarray(rt.state[k]), st[k])


# ------------------------------------------------- escrow floor (manual GC)


def test_manual_gc_keeps_one_restage_destination_zone():
    """gc_free_segments_low == 0 (manual GC) at a handful-of-zones geometry:
    the write path refuses to consume the last free zone, so an explicit
    gc_once() always has a restage destination and un-wedges the array."""
    arr, cfg, zns = _mk(True, zones=5, logical=200, gc_free_segments_low=0)
    assert arr.reserved_zones() == 1
    # the manual-GC floor gates zone opens only; it never shifts the
    # free-segment arithmetic the GC watermarks see
    assert arr.free_segment_count() == arr._min_free_zones()
    rng = np.random.default_rng(23)
    ref = {}
    wedge_lba = None
    for i in range(2000):
        lba = i % 120  # churn a narrow range: victims stay reclaimable
        blk = rng.integers(0, 256, (1, BB), dtype=np.uint8)
        try:
            arr.write(lba, blk)
        except RuntimeError as e:
            assert "GC required" in str(e)
            wedge_lba = lba  # its block may be staged; value is ambiguous
            break
        ref[lba] = blk[0].copy()
    assert wedge_lba is not None, "workload must hit the reserved-zone floor"
    # manual GC succeeds because the escrow zone is still free
    assert arr.gc_once()
    blk0 = rng.integers(0, 256, (1, BB), dtype=np.uint8)
    arr.write(0, blk0)
    ref[0] = blk0[0].copy()
    arr.flush()
    for lba, want in ref.items():
        if lba == wedge_lba:
            continue
        assert np.array_equal(arr.read(lba, 1)[0], want)


def test_manual_gc_floor_skipped_on_tiny_geometry():
    """Below header+footer headroom the floor would make the array unusable
    from block zero: it stays off and reserved_zones() reports 0."""
    arr, _, _ = _mk(True, zones=2, logical=40, gc_free_segments_low=0)
    assert arr.reserved_zones() == 0


# ------------------------------------------------- observability hooks


def test_degraded_mode_gauge_and_narrow_commit_span():
    """Observe-only PR-9 hooks: the degraded_mode gauge tracks drive health
    and survivor-width commits emit stripe.commit_narrow spans."""
    from repro.obs import MetricsRegistry, standard_collector

    pipe = _timed_pipe()
    tracer = pipe.attach_obs()
    reg = MetricsRegistry()
    collect = standard_collector(pipe)
    collect(reg)
    assert reg.gauges["array/degraded_mode"] == 0.0
    plan = FaultPlan.scripted([FaultEvent(t_us=600.0, kind="fail", drive=1)])
    pipe.attach_faults(plan)
    ref, _ = _timed_workload(pipe, rounds=2)
    pipe.drain()
    collect(reg)
    assert reg.gauges["array/degraded_mode"] == 1.0
    names = {e["name"] for e in tracer.events}
    assert "stripe.commit_narrow" in names
