"""SPDK-style pipeline facade: event routing + completion callbacks."""
import numpy as np

from repro.core.array import ZapRaidConfig, ZapRAIDArray
from repro.core.handlers import HandlerPipeline
from repro.core.zns import ZnsConfig


def test_pipeline_write_read_roundtrip():
    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=4,
                        chunk_blocks=1, logical_blocks=128,
                        gc_free_segments_low=1)
    zns = ZnsConfig(n_zones=8, zone_cap_blocks=64, block_bytes=256)
    pipe = HandlerPipeline(ZapRAIDArray(cfg, zns))
    rng = np.random.default_rng(0)
    ref = {}
    acks = []
    for lba in range(24):
        blk = rng.integers(0, 256, (1, 256), dtype=np.uint8)
        ref[lba] = blk[0].copy()
        pipe.submit_write(lba, blk, cb=acks.append)
    pipe.drain()
    assert len(acks) == 24

    got = {}
    for lba in range(24):
        pipe.submit_read(lba, 1, cb=lambda out, l=lba: got.__setitem__(l, out[0]))
    pipe.drain()
    assert all(np.array_equal(got[l], v) for l, v in ref.items())
    assert pipe.counters["dispatch"] == 48
    assert pipe.counters["device_io"] >= 24
    assert pipe.counters["segment_state"] >= 1


def test_timed_pipeline_same_stages_on_engine():
    """Timed mode drives the same stage decomposition through the event
    engine: identical data plane, but completions carry virtual timestamps."""
    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=4,
                        chunk_blocks=1, logical_blocks=128,
                        gc_free_segments_low=1)
    zns = ZnsConfig(n_zones=8, zone_cap_blocks=64, block_bytes=256)
    pipe = HandlerPipeline.build_timed(cfg, zns, seed=1)
    rng = np.random.default_rng(0)
    ref = {}
    for lba in range(24):
        blk = rng.integers(0, 256, (1, 256), dtype=np.uint8)
        ref[lba] = blk[0].copy()
        pipe.submit_write(lba, blk, at=float(lba) * 10.0)
    pipe.drain()

    got = {}
    for lba in range(24):
        pipe.submit_read(lba, 1, cb=lambda out, l=lba: got.__setitem__(l, out[0]))
    pipe.drain()
    assert all(np.array_equal(got[l], v) for l, v in ref.items())
    assert pipe.counters["dispatch"] == 48
    assert pipe.counters["device_io"] >= 24
    # every request got a latency sample with real device time attached
    assert pipe.recorder.percentiles(op="W")["n"] == 24
    assert pipe.recorder.percentiles(op="R")["p50"] > 50.0
