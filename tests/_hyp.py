"""Hypothesis shim: real `hypothesis` when installed, deterministic fallback otherwise.

The tier-1 suite uses a small slice of the hypothesis API (``@given`` with
``st.integers``/``st.randoms``, ``@settings(max_examples, deadline)``).  When
the real package is available we re-export it untouched; otherwise the
fallback below replays a deterministic, seeded sweep of examples -- boundary
values first (all-min, all-max), then pseudo-random draws -- so property
tests still exercise the same code paths reproducibly in minimal containers.

Usage in tests::

    from _hyp import given, settings, st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import random

    HAVE_HYPOTHESIS = False

    _FALLBACK_SEED = 0x5A9D  # fixed: example sequences must be reproducible

    class _Strategy:
        def draw(self, rng: random.Random, mode: int):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, min_value: int, max_value: int):
            self.lo = min_value
            self.hi = max_value

        def draw(self, rng: random.Random, mode: int) -> int:
            if mode == 0:
                return self.lo
            if mode == 1:
                return self.hi
            return rng.randint(self.lo, self.hi)

    class _Randoms(_Strategy):
        def draw(self, rng: random.Random, mode: int) -> random.Random:
            return random.Random(rng.randint(0, 1 << 30))

    class _Strategies:
        """Namespace mirroring ``hypothesis.strategies`` for the used subset."""

        @staticmethod
        def integers(min_value: int, max_value: int) -> _Integers:
            return _Integers(min_value, max_value)

        @staticmethod
        def randoms(use_true_random: bool = False) -> _Randoms:
            return _Randoms()

    strategies = _Strategies()

    def settings(max_examples: int = 20, **_ignored):
        """Record the example budget for the fallback ``given`` to read."""

        def deco(fn):
            fn._hyp_max_examples = max_examples
            return fn

        return deco

    def given(*strats: _Strategy):
        def deco(fn):
            max_examples = getattr(fn, "_hyp_max_examples", 20)

            @functools.wraps(fn)
            def wrapper():
                rng = random.Random(_FALLBACK_SEED)
                for mode in range(max_examples):
                    fn(*[s.draw(rng, mode) for s in strats])

            # pytest introspects signatures through ``__wrapped__`` and would
            # mistake the property arguments for fixtures; hide the original.
            del wrapper.__wrapped__
            return wrapper

        return deco


st = strategies
