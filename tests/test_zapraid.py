"""System behaviour tests for the ZapRAID storage core: writes, reads,
degraded reads, full-drive recovery, crash consistency, GC, hybrid data
management, and L2P offloading -- including a hypothesis property test that
random workloads with random crash points never lose acknowledged data."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.array import ZapRaidConfig, ZapRAIDArray
from repro.core.recovery import recover_array
from repro.core.segment import solve_stripes_per_segment
from repro.core.zns import DeviceCrashed, ZnsConfig

BB = 256  # small blocks keep tests fast


def mk(scheme="raid5", n_drives=4, G=4, chunk=1, logical=256, zones=12,
       zone_cap=64, **kw):
    kw.setdefault("gc_free_segments_low", 1)
    cfg = ZapRaidConfig(
        scheme=scheme, n_drives=n_drives, group_size=G, chunk_blocks=chunk,
        logical_blocks=logical, **kw,
    )
    zns = ZnsConfig(n_zones=zones, zone_cap_blocks=zone_cap, block_bytes=BB)
    return ZapRAIDArray(cfg, zns), cfg, zns


def fill(arr, rng, n_writes, logical, ref=None, max_len=1):
    ref = {} if ref is None else ref
    for _ in range(n_writes):
        n = int(rng.integers(1, max_len + 1))
        lba = int(rng.integers(0, logical - n))
        blk = rng.integers(0, 256, size=(n, BB), dtype=np.uint8)
        arr.write(lba, blk)
        for j in range(n):
            ref[lba + j] = blk[j].copy()
    arr.flush()
    return ref


def check(arr, ref):
    return all(np.array_equal(arr.read(l, 1)[0], v) for l, v in ref.items())


# ------------------------------------------------------------ layout math

def test_paper_layout_arithmetic():
    """§3.1 example: ZN540 zone = 275,712 blocks, C=1 -> header 1, data
    274,366, footer 1,345."""
    s, foot = solve_stripes_per_segment(275712, 1, 4096)
    assert s == 274366
    assert foot == 1345
    assert 1 + s + foot == 275712


def test_small_zone_layout():
    """§3.6: 96 MiB zone (24,576 blocks), C=1 -> data 24,455, footer 120."""
    s, foot = solve_stripes_per_segment(24576, 1, 4096)
    assert 1 + s + foot <= 24576
    assert s == 24455 and foot == 120


# ------------------------------------------------------------- basic paths

@pytest.mark.parametrize("scheme", ["raid0", "raid01", "raid4", "raid5", "raid6"])
def test_write_read_all_schemes(scheme):
    rng = np.random.default_rng(1)
    arr, *_ = mk(scheme=scheme)
    ref = fill(arr, rng, 150, 256)
    assert check(arr, ref)


@pytest.mark.parametrize("scheme", ["raid01", "raid4", "raid5", "raid6"])
def test_degraded_read_single_failure(scheme):
    rng = np.random.default_rng(2)
    arr, *_ = mk(scheme=scheme)
    ref = fill(arr, rng, 150, 256)
    # raid01: data lives on drives 0..k-1, mirrors on k..; fail a data drive
    arr.fail_drive(0 if scheme == "raid01" else 2)
    assert check(arr, ref)
    assert arr.stats.degraded_reads > 0


def test_raid6_double_failure_and_rebuild():
    rng = np.random.default_rng(3)
    arr, *_ = mk(scheme="raid6")
    ref = fill(arr, rng, 150, 128, max_len=2)
    arr.fail_drive(0)
    arr.fail_drive(2)
    assert check(arr, ref)
    arr.rebuild_drive(0)
    arr.rebuild_drive(2)
    assert check(arr, ref)
    before = arr.stats.degraded_reads
    assert check(arr, ref)
    assert arr.stats.degraded_reads == before  # no degraded reads post-rebuild


def test_full_drive_recovery_then_crash_recovery():
    rng = np.random.default_rng(4)
    arr, cfg, zns = mk()
    ref = fill(arr, rng, 200, 256)
    arr.fail_drive(1)
    arr.rebuild_drive(1)
    arr2 = recover_array(arr.drives, cfg, zns)
    assert check(arr2, ref)


def test_overwrite_semantics_across_classes():
    """A later write must win even when an earlier write of the same LBA is
    still buffered in a Zone-Append group (issue-order vs commit-order)."""
    rng = np.random.default_rng(5)
    arr, *_ = mk(G=8, hybrid=True, n_small=2, n_large=2,
                 small_chunk_blocks=1, large_chunk_blocks=2)
    a = rng.integers(0, 256, (1, BB), dtype=np.uint8)
    b = rng.integers(0, 256, (2, BB), dtype=np.uint8)
    arr.write(7, a)       # small -> append group (buffered)
    arr.write(7, b[:1])   # another small write, same LBA: supersedes
    arr.write(2, b)       # unrelated large write
    arr.flush()
    assert np.array_equal(arr.read(7, 1)[0], b[0])
    assert np.array_equal(arr.read(2, 1)[0], b[0])
    assert np.array_equal(arr.read(3, 1)[0], b[1])


# ------------------------------------------------------------------ crash

def test_crash_never_loses_acked_data():
    rng = np.random.default_rng(6)
    arr, cfg, zns = mk(G=4)
    acked = {}
    for i in range(40):
        lba = int(rng.integers(0, 200))
        blk = rng.integers(0, 256, (1, BB), dtype=np.uint8)
        arr.write(lba, blk)
        arr.flush()
        acked[lba] = blk[0].copy()
    arr.arm_crash(int(rng.integers(1, 12)))
    try:
        for i in range(40):
            lba = int(rng.integers(0, 200))
            blk = rng.integers(0, 256, (1, BB), dtype=np.uint8)
            arr.write(lba, blk)
            arr.flush()
            acked[lba] = blk[0].copy()
    except DeviceCrashed:
        acked.pop(lba, None)  # the in-flight write was never acknowledged
    arr2 = recover_array(arr.drives, cfg, zns)
    assert check(arr2, acked)


@given(st.integers(0, 10_000), st.integers(1, 40))
@settings(max_examples=12, deadline=None)
def test_crash_property(seed, budget):
    """Property: for any workload and any crash point, acknowledged writes
    survive recovery and the array stays writable afterwards."""
    rng = np.random.default_rng(seed)
    arr, cfg, zns = mk(G=4, zones=16)
    acked = {}
    crashed = False
    lba = 0
    for i in range(30):
        if i == 10:
            arr.arm_crash(budget)
        lba = int(rng.integers(0, 200))
        blk = rng.integers(0, 256, (1, BB), dtype=np.uint8)
        try:
            arr.write(lba, blk)
            arr.flush()
        except DeviceCrashed:
            crashed = True
            break
        acked[lba] = blk[0].copy()
    arr2 = recover_array(arr.drives, cfg, zns)
    assert check(arr2, acked)
    # still writable post-recovery
    blk = rng.integers(0, 256, (1, BB), dtype=np.uint8)
    arr2.write(3, blk)
    arr2.flush()
    assert np.array_equal(arr2.read(3, 1)[0], blk[0])


def test_recovery_discards_headerless_segments():
    """Paper Case 2: a segment with some zones never written is discarded."""
    rng = np.random.default_rng(7)
    arr, cfg, zns = mk()
    ref = fill(arr, rng, 60, 256)
    # simulate crash exactly during segment creation: new segment with
    # header on only two drives
    arr.arm_crash(2)
    with pytest.raises(DeviceCrashed):
        arr._open_segment(0, 1, 4)
    arr2 = recover_array(arr.drives, cfg, zns)
    assert check(arr2, ref)


# ----------------------------------------------------------------- GC

def test_gc_reclaims_and_preserves():
    rng = np.random.default_rng(8)
    arr, cfg, zns = mk(logical=96, zones=6, gc_free_segments_low=2)
    ref = {}
    for _ in range(1500):
        lba = int(rng.integers(0, 96))
        blk = rng.integers(0, 256, (1, BB), dtype=np.uint8)
        arr.write(lba, blk)
        ref[lba] = blk[0].copy()
    arr.flush()
    assert arr.stats.gc_runs > 0
    assert check(arr, ref)
    arr2 = recover_array(arr.drives, cfg, zns)
    assert check(arr2, ref)


# --------------------------------------------------------------- hybrid

def test_hybrid_routing_and_recovery():
    rng = np.random.default_rng(9)
    arr, cfg, zns = mk(hybrid=True, n_small=2, n_large=2, G=4,
                       small_chunk_blocks=1, large_chunk_blocks=2,
                       zones=16)
    ref = fill(arr, rng, 400, 256, max_len=3)
    assert check(arr, ref)
    small = [arr.open_segments[s] for s in arr.small_ids]
    large = [arr.open_segments[s] for s in arr.large_ids]
    assert all(o.info.chunk_blocks == 1 for o in small)
    assert all(o.info.chunk_blocks == 2 for o in large)
    assert small[0].info.uses_append and not small[1].info.uses_append
    arr.fail_drive(1)
    assert check(arr, ref)
    arr.rebuild_drive(1)
    arr2 = recover_array(arr.drives, cfg, zns)
    assert check(arr2, ref)


# ---------------------------------------------------------- L2P offload

def test_l2p_offload_roundtrip_and_recovery():
    rng = np.random.default_rng(10)
    arr, cfg, zns = mk(logical=512, zones=24, l2p_memory_limit_entries=128)
    ref = fill(arr, rng, 900, 512)
    assert arr.l2p.evictions > 0
    assert arr.stats.meta_blocks_written > 0
    assert check(arr, ref)
    arr2 = recover_array(arr.drives, cfg, zns)
    assert check(arr2, ref)
    ref2 = fill(arr2, rng, 200, 512, ref=ref)
    assert check(arr2, ref2)


def test_l2p_memory_accounting():
    rng = np.random.default_rng(11)
    arr, *_ = mk(logical=512, zones=24, l2p_memory_limit_entries=128)
    fill(arr, rng, 600, 512)
    epg = arr.l2p.epg
    assert len(arr.l2p.resident) <= max(1, 128 // epg)
    assert arr.l2p.memory_bytes() <= 128 * 4


# ----------------------------------------------------------- accounting

def test_write_amplification_accounting():
    rng = np.random.default_rng(12)
    arr, *_ = mk(scheme="raid5")  # k=3, m=1
    fill(arr, rng, 300, 256)
    wa = arr.stats.write_amp()
    assert 4 / 3 - 0.05 <= wa <= 2.5  # parity >= 4/3; padding/meta above that
