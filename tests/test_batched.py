"""Batched (stripe-group) datapath: bit-identity with the per-stripe path.

The group-level codec (`encode_batch_np`/`decode_batch_np`), the batched
Pallas kernels behind it, and the array's `batched=True` datapath must all be
byte-for-byte equivalent to the per-stripe/per-block legacy path -- including
degraded decode for every surviving-role subset and non-multiple-of-128 lane
counts (the padding path).  See DESIGN.md §2-3.
"""
import itertools

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.array import ZapRaidConfig, ZapRAIDArray
from repro.core.l2p import NO_PBA, L2PTable, pack_pba, unpack_pba, unpack_pba_many
from repro.core.raid import (
    StripeCodec,
    decode_meta,
    decode_meta_batch,
    make_scheme,
    parity_oob,
    parity_oob_batch,
)
from repro.core.zns import ZnsConfig
from repro.kernels import ops, ref

BB = 256
SCHEMES = [("raid0", 4), ("raid01", 4), ("raid4", 4), ("raid5", 4), ("raid6", 5)]


def _codec(name, n_drives):
    return StripeCodec(make_scheme(name, n_drives), use_pallas=True, interpret=True)


def _mirror_ok(scheme, surv):
    """RAID-01 can only decode when every chunk has at least one copy left."""
    return len({r % scheme.k for r in surv}) == scheme.k


# ------------------------------------------------------------ kernel level

@pytest.mark.parametrize("s_count", [1, 3, 8])
@pytest.mark.parametrize("n", [128, 2048, 25])  # 25: unaligned lanes (padding)
def test_parity_xor_batch_matches_per_stripe(s_count, n):
    rng = np.random.default_rng(s_count * n)
    data = jnp.asarray(
        rng.integers(-(2**31), 2**31, (s_count, 4, n), dtype=np.int64), jnp.int32
    )
    got = ops.xor_parity_batch(data, use_pallas=True, interpret=True)
    per = jnp.stack([ops.xor_parity(data[s]) for s in range(s_count)])
    assert jnp.array_equal(got, per)
    assert np.array_equal(
        np.asarray(got), np.bitwise_xor.reduce(np.asarray(data), axis=1)
    )
    # jnp oracle path agrees too
    assert jnp.array_equal(
        ops.xor_parity_batch(data, use_pallas=False), got
    )


@pytest.mark.parametrize("k,m", [(2, 1), (3, 2), (4, 3)])
def test_gf256_matmul_batch_matches_per_stripe(k, m):
    rng = np.random.default_rng(k * 31 + m)
    data = jnp.asarray(
        rng.integers(-(2**31), 2**31, (5, k, 512), dtype=np.int64), jnp.int32
    )
    got = ops.rs_encode_batch(data, m, use_pallas=True, interpret=True)
    per = jnp.stack([ops.rs_encode(data[s], m) for s in range(5)])
    assert jnp.array_equal(got, per)
    assert jnp.array_equal(ops.rs_encode_batch(data, m, use_pallas=False), got)


def test_rs_decode_batch_roundtrip():
    rng = np.random.default_rng(7)
    k, m = 3, 2
    data = jnp.asarray(
        rng.integers(-(2**31), 2**31, (4, k, 256), dtype=np.int64), jnp.int32
    )
    parity = ops.rs_encode_batch(data, m)
    code = jnp.concatenate([data, parity], axis=1)
    for surv in itertools.combinations(range(k + m), k):
        rec = ops.rs_decode_batch(code[:, list(surv)], surv, k, m)
        assert jnp.array_equal(rec, data), surv


def test_batch_refs_match_kernels():
    rng = np.random.default_rng(8)
    data = jnp.asarray(
        rng.integers(-(2**31), 2**31, (3, 4, 384), dtype=np.int64), jnp.int32
    )
    assert jnp.array_equal(
        ref.parity_xor_batch_ref(data),
        jnp.stack([ref.parity_xor_ref(data[s]) for s in range(3)]),
    )
    coeff = jnp.asarray(np.array([[1, 2, 3, 4], [5, 6, 7, 8]]), jnp.int32)
    assert jnp.array_equal(
        ref.gf256_matmul_batch_ref(coeff, data),
        jnp.stack([ref.gf256_matmul_ref(coeff, data[s]) for s in range(3)]),
    )


# ------------------------------------------------------------- codec level

@pytest.mark.parametrize("scheme,n_drives", SCHEMES)
@pytest.mark.parametrize("nbytes", [512, 96])  # 96 bytes = 24 lanes: padding
def test_encode_batch_bit_identical(scheme, n_drives, nbytes):
    codec = _codec(scheme, n_drives)
    k = codec.scheme.k
    rng = np.random.default_rng(hash((scheme, nbytes)) % (1 << 31))
    for s_count in (1, 3, 7):  # non-power-of-two exercises batch padding
        data = rng.integers(0, 256, (s_count, k, nbytes), dtype=np.uint8)
        batch = codec.encode_batch_np(data)
        per = np.stack([codec.encode_np(data[s]) for s in range(s_count)])
        assert batch.shape == (s_count, codec.scheme.m, nbytes)
        assert np.array_equal(batch, per.reshape(batch.shape))


@pytest.mark.parametrize("scheme,n_drives", SCHEMES[1:])  # raid0 cannot decode
@pytest.mark.parametrize("nbytes", [512, 96])
def test_decode_batch_every_survivor_subset(scheme, n_drives, nbytes):
    codec = _codec(scheme, n_drives)
    sch = codec.scheme
    rng = np.random.default_rng(hash((scheme, nbytes, "d")) % (1 << 31))
    s_count = 4
    data = rng.integers(0, 256, (s_count, sch.k, nbytes), dtype=np.uint8)
    code = np.concatenate([data, codec.encode_batch_np(data)], axis=1)
    tested = 0
    for surv in itertools.combinations(range(sch.n), sch.k):
        if sch.mirror and not _mirror_ok(sch, surv):
            continue
        batch = codec.decode_batch_np(code[:, list(surv)], surv)
        per = np.stack(
            [codec.decode_np(code[s][list(surv)], surv) for s in range(s_count)]
        )
        assert np.array_equal(batch, per.reshape(batch.shape)), (scheme, surv)
        assert np.array_equal(batch.reshape(s_count, sch.k, nbytes), data), surv
        tested += 1
    assert tested > 1


@pytest.mark.parametrize("scheme,n_drives", SCHEMES[1:])
def test_oob_meta_batch_bit_identical(scheme, n_drives):
    codec = _codec(scheme, n_drives)
    sch = codec.scheme
    rng = np.random.default_rng(hash((scheme, "meta")) % (1 << 31))
    s_count, c = 5, 2
    lbas = rng.integers(0, 1 << 40, (s_count, sch.k, c)).astype(np.uint64)
    ts = rng.integers(0, 1 << 40, (s_count, sch.k, c)).astype(np.uint64)
    p_lba, p_ts = parity_oob_batch(codec, lbas, ts)
    for s in range(s_count):
        pl, pt = parity_oob(codec, lbas[s], ts[s])
        assert np.array_equal(p_lba[s], pl) and np.array_equal(p_ts[s], pt)
    # decode side: drop data role 0, keep the rest + first parity
    surv = tuple(range(1, sch.k)) + (sch.k,)
    if sch.mirror and not _mirror_ok(sch, surv):
        return
    full_lba = np.concatenate([lbas, p_lba], axis=1)
    full_ts = np.concatenate([ts, p_ts], axis=1)
    d_lba, d_ts = decode_meta_batch(
        codec, full_lba[:, list(surv)], full_ts[:, list(surv)], surv
    )
    for s in range(s_count):
        dl, dt = decode_meta(
            codec, full_lba[s][list(surv)], full_ts[s][list(surv)], surv
        )
        assert np.array_equal(d_lba[s], dl) and np.array_equal(d_ts[s], dt)
    assert np.array_equal(d_lba, lbas) and np.array_equal(d_ts, ts)


# ---------------------------------------------------------------- L2P level

@pytest.mark.parametrize("limit", [None, 64])
def test_l2p_get_set_many_equivalent(limit):
    written = {}

    def wcb(gid, entries):
        written[gid] = entries.copy()

    def rcb(gid):
        return written.get(gid)

    t = L2PTable(512, memory_limit_entries=limit,
                 write_mapping_block=wcb, read_mapping_block=rcb,
                 entries_per_group=32)
    rng = np.random.default_rng(0)
    lbas = rng.integers(0, 512, 200)
    pbas = np.array([pack_pba(int(l) % 7, int(l) % 4, int(l)) for l in lbas])
    t.set_many(lbas, pbas)
    got = t.get_many(lbas)
    want = np.array([t.get(int(l)) for l in lbas])
    assert np.array_equal(got, want)
    # later duplicates win, like a sequential set loop
    t.set_many(np.array([5, 5]), np.array([111, 222]))
    assert t.get(5) == 222
    # unmapped stays NO_PBA
    t2 = L2PTable(64, entries_per_group=32)
    assert np.all(t2.get_many(np.arange(64)) == int(NO_PBA))


def test_l2p_set_survives_clock_eviction_pressure():
    """A store into a just-faulted group must not be lost when the CLOCK hand
    would evict that very group (the faulting group is pinned)."""
    written = {}
    t = L2PTable(24, memory_limit_entries=4,
                 write_mapping_block=lambda g, e: written.__setitem__(g, e.copy()),
                 read_mapping_block=written.get,
                 entries_per_group=4)  # limit = 1 resident group
    for _ in range(3):  # pump gid 1's refbit so the sweep has to pass it twice
        t.get(4)
    t.set_many(np.array([0]), np.array([777]))
    assert t.get(0) == 777
    t.set(9, 555)  # scalar path under the same pressure
    assert t.get(9) == 555
    t.flush()


def test_unpack_pba_many_matches_scalar():
    pbas = np.array([pack_pba(s, d, o) for s, d, o in
                     [(0, 0, 0), (5, 3, 77), (4095, 15, 65535)]])
    segs, drives, offs = unpack_pba_many(pbas)
    for i, p in enumerate(pbas):
        s, d, o = unpack_pba(int(p))
        assert (segs[i], drives[i], offs[i]) == (s, d, o)


# ------------------------------------------------------------ system level

def _run_workload(batched, scheme="raid5", seed=3, n_writes=200, **kw):
    rng = np.random.default_rng(seed)
    cfg = ZapRaidConfig(scheme=scheme, n_drives=4, group_size=8, chunk_blocks=1,
                        logical_blocks=256, gc_free_segments_low=1,
                        batched=batched, **kw)
    zns = ZnsConfig(n_zones=12, zone_cap_blocks=64, block_bytes=BB)
    arr = ZapRAIDArray(cfg, zns)
    ref_data = {}
    for _ in range(n_writes):
        n = int(rng.integers(1, 4))
        lba = int(rng.integers(0, 256 - n))
        blk = rng.integers(0, 256, (n, BB), dtype=np.uint8)
        arr.write(lba, blk)
        for j in range(n):
            ref_data[lba + j] = blk[j].copy()
    arr.flush()
    return arr, ref_data


@pytest.mark.parametrize("scheme", ["raid0", "raid01", "raid5", "raid6"])
def test_batched_array_media_identical_to_legacy(scheme):
    """Same workload, batched vs legacy datapath -> identical drive media."""
    a1, ref1 = _run_workload(True, scheme)
    a0, ref0 = _run_workload(False, scheme)
    assert ref1.keys() == ref0.keys()
    for d1, d0 in zip(a1.drives, a0.drives):
        assert np.array_equal(d1.data, d0.data)
        assert np.array_equal(d1.oob, d0.oob)
        assert np.array_equal(d1.wp, d0.wp)


def test_batched_multiblock_read_matches_per_block():
    arr, ref_data = _run_workload(True)
    got = arr.read(0, 64)
    for i in range(64):
        want = ref_data.get(i, np.zeros(BB, np.uint8))
        assert np.array_equal(got[i], want), i


def test_batched_degraded_read_and_rebuild_media_identical():
    a1, ref1 = _run_workload(True)
    a0, _ = _run_workload(False)
    for a in (a1, a0):
        a.fail_drive(1)
    for lba, want in ref1.items():
        assert np.array_equal(a1.read(lba, 1)[0], want)
    a1.rebuild_drive(1)
    a0.rebuild_drive(1)
    for d1, d0 in zip(a1.drives, a0.drives):
        assert np.array_equal(d1.data, d0.data)
        assert np.array_equal(d1.oob, d0.oob)
    for lba, want in ref1.items():
        assert np.array_equal(a1.read(lba, 1)[0], want)


def test_batched_raid6_double_failure_rebuild():
    arr, ref_data = _run_workload(True, scheme="raid6", n_writes=150)
    arr.fail_drive(0)
    arr.fail_drive(2)
    for lba, want in ref_data.items():
        assert np.array_equal(arr.read(lba, 1)[0], want)
    arr.rebuild_drive(0)
    arr.rebuild_drive(2)
    before = arr.stats.degraded_reads
    for lba, want in ref_data.items():
        assert np.array_equal(arr.read(lba, 1)[0], want)
    assert arr.stats.degraded_reads == before


def test_batched_gc_preserves_logical_contents():
    rng = np.random.default_rng(9)
    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=8, chunk_blocks=1,
                        logical_blocks=96, gc_free_segments_low=2, batched=True)
    zns = ZnsConfig(n_zones=6, zone_cap_blocks=64, block_bytes=BB)
    arr = ZapRAIDArray(cfg, zns)
    ref_data = {}
    for _ in range(1200):
        lba = int(rng.integers(0, 96))
        blk = rng.integers(0, 256, (1, BB), dtype=np.uint8)
        arr.write(lba, blk)
        ref_data[lba] = blk[0].copy()
    arr.flush()
    assert arr.stats.gc_runs > 0
    for lba, want in ref_data.items():
        assert np.array_equal(arr.read(lba, 1)[0], want)


def test_batched_write_supersedes_buffered_duplicate():
    """A bulk append must cancel a still-buffered older copy of the same LBA."""
    arr, _ = _run_workload(True, n_writes=0)
    rng = np.random.default_rng(11)
    a = rng.integers(0, 256, (1, BB), dtype=np.uint8)
    b = rng.integers(0, 256, (3, BB), dtype=np.uint8)
    arr.write(7, a)        # buffered in the open append group
    arr.write(6, b)        # covers LBAs 6,7,8: supersedes the buffered 7
    arr.flush()
    assert np.array_equal(arr.read(7, 1)[0], b[1])
    assert np.array_equal(arr.read(6, 1)[0], b[0])
    assert np.array_equal(arr.read(8, 1)[0], b[2])
