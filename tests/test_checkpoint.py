"""ZapRAID checkpoint engine + on-device state parity: save/restore
roundtrips, degraded restore after lane loss, crash remount, restart
determinism, and erasure-coded optimizer-shard reconstruction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.state_parity import encode_shards, reconstruct_shard
from repro.checkpoint.zapraid_ckpt import CheckpointConfig, CheckpointEngine


def small_engine():
    return CheckpointEngine(
        CheckpointConfig(n_lanes=4, scheme="raid5", group_size=8,
                         block_bytes=512, zone_cap_blocks=256, n_zones=64,
                         chunk_blocks=2),
        logical_blocks=1 << 13,
    )


def mk_state(seed=0, n=3):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            f"w{i}": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
            for i in range(n)
        },
        "step": jnp.int32(seed),
        "m": {"w0": jnp.asarray(rng.standard_normal(64), jnp.bfloat16)},
    }


def trees_equal(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(
        np.array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))
        for x, y in zip(fa, fb)
    )


def test_save_restore_roundtrip():
    eng = small_engine()
    state = mk_state(1)
    eng.save(10, state)
    out = eng.restore(10, state)
    assert trees_equal(state, out)


def test_multiple_checkpoints_and_retirement():
    eng = small_engine()
    states = {s: mk_state(s) for s in (1, 2, 3, 4)}
    for s, st in states.items():
        eng.save(s, st)
    assert sorted(eng.catalog) == [3, 4]  # keep_last=2
    assert trees_equal(states[4], eng.restore(4, states[4]))


def test_degraded_restore_after_lane_loss():
    eng = small_engine()
    state = mk_state(7)
    eng.save(5, state)
    eng.fail_lane(2)
    out = eng.restore(5, state)  # no rebuild -- degraded reads decode
    assert trees_equal(state, out)
    assert eng.array.stats.degraded_reads > 0


def test_save_after_lane_loss_uses_hot_spare():
    eng = small_engine()
    eng.save(1, mk_state(1))
    eng.fail_lane(0)
    st2 = mk_state(2)
    eng.save(2, st2)  # must rebuild lane 0 first
    assert not eng.array.drives[0].failed
    assert trees_equal(st2, eng.restore(2, st2))


def test_crash_remount_recovers_catalog():
    eng = small_engine()
    st = mk_state(3)
    eng.save(42, st)
    eng2 = eng.crash_and_remount()
    assert 42 in eng2.catalog
    assert trees_equal(st, eng2.restore(42, st))


def test_log_structured_gc_under_many_saves():
    eng = small_engine()
    st = mk_state(0)
    for s in range(1, 14):
        eng.save(s, mk_state(s))
    last = max(eng.catalog)
    assert trees_equal(mk_state(last), eng.restore(last, st))
    assert eng.array.stats.device_blocks_written > 0


# ------------------------------------------------------ state parity (EC)

@pytest.mark.parametrize("m", [1, 2])
def test_optimizer_shard_reconstruction(m):
    k = 4
    rng = np.random.default_rng(0)
    shards = [
        {
            "m": jnp.asarray(rng.standard_normal((16, 8)), jnp.float32),
            "v": jnp.asarray(rng.standard_normal(33), jnp.float32),
        }
        for _ in range(k)
    ]
    parity = encode_shards(shards, m=m)
    lost = 2
    surviving = {r: shards[r] for r in range(k) if r != lost}
    rec = reconstruct_shard(lost, surviving, parity, k)
    assert trees_equal(rec, shards[lost])


def test_restart_determinism():
    """Restore + recompute must reproduce the original loss trajectory."""
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, batch_for_step
    from repro.models.config import smoke
    from repro.optim import adamw
    from repro.train import steps as steps_mod

    cfg = smoke(get_config("smollm-135m"))
    opt_cfg = adamw.AdamWConfig(warmup_steps=2)
    model, train_step = steps_mod.make_train_step(cfg, opt_cfg)
    train_step = jax.jit(train_step)
    params = model.init(jax.random.PRNGKey(0))
    opt = steps_mod.init_opt_state(model, params, opt_cfg)
    dc = DataConfig(4, 16, cfg.vocab)
    eng = small_engine()

    losses = []
    for step in range(6):
        batch = batch_for_step(dc, cfg, step)
        params, opt, m = train_step(params, opt, batch)
        losses.append(float(m["loss"]))
        if step == 2:
            eng.save(step, {"params": params, "opt": opt})

    restored = eng.restore(2, {"params": params, "opt": opt})
    p2 = jax.tree.map(jnp.asarray, restored["params"])
    o2 = jax.tree.map(jnp.asarray, restored["opt"])
    relosses = []
    for step in range(3, 6):
        batch = batch_for_step(dc, cfg, step)
        p2, o2, m = train_step(p2, o2, batch)
        relosses.append(float(m["loss"]))
    np.testing.assert_allclose(relosses, losses[3:], rtol=1e-5, atol=1e-6)
