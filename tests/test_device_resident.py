"""Device-resident zero-copy datapath: bit-identity with the legacy path.

The arena-staged, donated-encode, double-buffered group datapath (PR 4) must
leave *exactly* the media, OOB, write pointers, L2P and validity state the
per-block/per-stripe legacy path produces -- across schemes, for healthy
reads, degraded reads on every surviving-role set, rebuild, and GC.  The
vectorized L2P batch ops are property-tested against the scalar reference.
See DESIGN.md §9.
"""
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core.array import ZapRaidConfig, ZapRAIDArray
from repro.core.l2p import NO_PBA, L2PTable, pack_pba, pack_pba_many, unpack_pba
from repro.core.zns import ZnsConfig

BB = 256
SCHEMES = [("raid4", 4), ("raid5", 4), ("raid6", 5), ("raid01", 4)]


def _mk(batched, scheme="raid5", n_drives=4, overlap=True, **kw):
    cfg = ZapRaidConfig(scheme=scheme, n_drives=n_drives, group_size=8,
                        chunk_blocks=1, logical_blocks=256,
                        gc_free_segments_low=1, batched=batched,
                        overlap=overlap, **kw)
    zns = ZnsConfig(n_zones=12, zone_cap_blocks=64, block_bytes=BB)
    return ZapRAIDArray(cfg, zns)


def _workload(arr, seed=3, n_writes=200, flush_every=0):
    """Mixed-size random writes; optional mid-stream flushes exercise the
    partial-group pad-in-place path.  Returns the logical reference image."""
    rng = np.random.default_rng(seed)
    ref = {}
    for i in range(n_writes):
        n = int(rng.integers(1, 4))
        lba = int(rng.integers(0, 256 - n))
        blk = rng.integers(0, 256, (n, BB), dtype=np.uint8)
        arr.write(lba, blk)
        for j in range(n):
            ref[lba + j] = blk[j].copy()
        if flush_every and (i + 1) % flush_every == 0:
            arr.flush()
    arr.flush()
    return ref


def _assert_media_equal(a1, a0):
    for d1, d0 in zip(a1.drives, a0.drives):
        assert np.array_equal(d1.data, d0.data)
        assert np.array_equal(d1.oob, d0.oob)
        assert np.array_equal(d1.wp, d0.wp)


# ----------------------------------------------------- write-path identity

@pytest.mark.parametrize("scheme,n_drives", SCHEMES)
def test_device_resident_media_identical_to_legacy(scheme, n_drives):
    a1 = _mk(True, scheme, n_drives)
    a0 = _mk(False, scheme, n_drives)
    r1 = _workload(a1)
    r0 = _workload(a0)
    assert r1.keys() == r0.keys()
    _assert_media_equal(a1, a0)


@pytest.mark.parametrize("scheme,n_drives", [("raid5", 4), ("raid6", 5)])
def test_partial_group_flush_identical(scheme, n_drives):
    """Frequent flushes: pad-in-place partial groups, every pow2 bucket."""
    a1 = _mk(True, scheme, n_drives)
    a0 = _mk(False, scheme, n_drives)
    _workload(a1, seed=7, n_writes=120, flush_every=5)
    _workload(a0, seed=7, n_writes=120, flush_every=5)
    _assert_media_equal(a1, a0)
    assert a1.stats.padded_blocks == a0.stats.padded_blocks


def test_overlap_invisible():
    """Double-buffered commits change nothing observable on the media."""
    a1 = _mk(True, overlap=True)
    a0 = _mk(True, overlap=False)
    _workload(a1, seed=11)
    _workload(a0, seed=11)
    _assert_media_equal(a1, a0)


def test_overlap_defers_and_syncs_on_read():
    """A filled group stays pending until a sync point; reads force it."""
    arr = _mk(True, overlap=True)
    rng = np.random.default_rng(5)
    blk = rng.integers(0, 256, (3 * 8, BB), dtype=np.uint8)  # k*G: one group
    arr.write(0, blk)
    assert arr._pending_group is not None  # group full, commit deferred
    got = arr.read(0, 8)  # sync point: read-your-writes
    assert arr._pending_group is None
    assert np.array_equal(got, blk[:8])


def test_arm_crash_lands_pending_group_first():
    """arm_crash must not let the budget bite a pre-arming deferred group."""
    arr = _mk(True, overlap=True)
    rng = np.random.default_rng(6)
    blk = rng.integers(0, 256, (3 * 8, BB), dtype=np.uint8)
    arr.write(0, blk)
    assert arr._pending_group is not None
    arr.arm_crash(0)  # sync happens before the budget arms
    assert arr._pending_group is None
    arr.disarm_crash()
    assert np.array_equal(arr.read(0, 8), blk[:8])


# ------------------------------------------------------ read-path identity

@pytest.mark.parametrize("scheme,n_drives", SCHEMES)
def test_degraded_reads_every_surviving_role_set(scheme, n_drives):
    """Fail each drive in turn: with parity rotation every failure exercises
    a different mix of surviving-role sets through the fused decode."""
    a1 = _mk(True, scheme, n_drives)
    ref = _workload(a1, seed=13)
    lbas = sorted(ref)
    want = np.stack([ref[l] for l in lbas])
    for failed in range(n_drives):
        a1.drives[failed].failed = True
        got = np.stack([a1.read(l, 1)[0] for l in lbas])       # scalar path
        assert np.array_equal(got, want), (scheme, failed)
        got_b = a1.read(0, 256)                                # batched path
        for i, l in enumerate(lbas):
            assert np.array_equal(got_b[l], ref[l]), (scheme, failed, l)
        a1.drives[failed].failed = False


@pytest.mark.parametrize("scheme,n_drives", SCHEMES)
def test_rebuild_identical_to_legacy(scheme, n_drives):
    a1 = _mk(True, scheme, n_drives)
    a0 = _mk(False, scheme, n_drives)
    ref = _workload(a1, seed=17)
    _workload(a0, seed=17)
    for a in (a1, a0):
        a.fail_drive(1)
        a.rebuild_drive(1)
    _assert_media_equal(a1, a0)
    for lba, want in ref.items():
        assert np.array_equal(a1.read(lba, 1)[0], want)


def test_gc_identical_to_legacy():
    """Overwrite-heavy workload forces GC in both modes -> same media."""
    def run(batched):
        cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=8,
                            chunk_blocks=1, logical_blocks=96,
                            gc_free_segments_low=2, batched=batched)
        zns = ZnsConfig(n_zones=6, zone_cap_blocks=64, block_bytes=BB)
        arr = ZapRAIDArray(cfg, zns)
        rng = np.random.default_rng(19)
        ref = {}
        for _ in range(900):
            lba = int(rng.integers(0, 96))
            blk = rng.integers(0, 256, (1, BB), dtype=np.uint8)
            arr.write(lba, blk)
            ref[lba] = blk[0].copy()
        arr.flush()
        return arr, ref

    a1, r1 = run(True)
    a0, r0 = run(False)
    assert a1.stats.gc_runs > 0 and a1.stats.gc_runs == a0.stats.gc_runs
    _assert_media_equal(a1, a0)
    for lba, want in r1.items():
        assert np.array_equal(a1.read(lba, 1)[0], want)


def test_copy_counters_count_groups_not_stripes():
    """The device-resident path's transfer count scales with *groups*."""
    arr = _mk(True)
    rng = np.random.default_rng(23)
    arr.write(0, rng.integers(0, 256, (3 * 8 * 4, BB), dtype=np.uint8))
    arr.flush()
    groups = arr.stats.stripes_committed / arr.cfg.group_size
    # payload encode + OOB-meta encode per group, nothing per stripe
    assert arr.stats.h2d_copies <= 2 * groups + 2
    assert arr.stats.h2d_bytes > 0 and arr.stats.d2h_bytes > 0


def test_timed_pipeline_reports_encode_sync():
    """Timed mode threads encode completions into the latency recorder."""
    from repro.core.handlers import HandlerPipeline
    from repro.sim import Request

    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=8,
                        chunk_blocks=1, logical_blocks=256,
                        gc_free_segments_low=1)
    zns = ZnsConfig(n_zones=12, zone_cap_blocks=64, block_bytes=BB)
    pipe = HandlerPipeline.build_timed(cfg, zns, seed=3)
    rng = np.random.default_rng(29)
    reqs = [Request(float(i) * 10.0, "t", "W", int(rng.integers(0, 250)), 1)
            for i in range(64)]
    rec = pipe.replay(reqs, payload_fn=lambda r: rng.integers(
        0, 256, (r.n_blocks, BB), dtype=np.uint8))
    assert rec.note_counts.get("encode_sync_us", 0) >= 1  # groups encoded
    assert rec.notes.get("encode_sync_us", 0.0) >= 0.0


# ------------------------------------------------------- L2P property test

@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=120), st.randoms())
def test_l2p_batch_ops_match_scalar_reference(limit, rnd):
    """get_many/set_many (bitmap CLOCK) vs a scalar get/set shadow table."""
    written_v, written_s = {}, {}

    def mk(store):
        return L2PTable(
            480, memory_limit_entries=limit,
            write_mapping_block=lambda g, e: store.__setitem__(g, e.copy()),
            read_mapping_block=lambda g: store.get(g),
            entries_per_group=32,
        )

    vec, ref = mk(written_v), mk(written_s)
    for _ in range(30):
        n = rnd.randint(1, 24)
        lbas = np.array([rnd.randrange(480) for _ in range(n)], dtype=np.int64)
        if rnd.random() < 0.6:
            pbas = np.array(
                [pack_pba(rnd.randrange(64), rnd.randrange(4), rnd.randrange(100))
                 for _ in range(n)], dtype=np.int64)
            vec.set_many(lbas, pbas)
            for l, p in zip(lbas, pbas):  # scalar shadow, same order
                ref.set(int(l), int(p))
        else:
            got = vec.get_many(lbas)
            want = np.array([ref.get(int(l)) for l in lbas])
            assert np.array_equal(got, want)
    vec.flush()
    ref.flush()
    final_v = vec.get_many(np.arange(480))
    final_s = np.array([ref.get(i) for i in range(480)])
    assert np.array_equal(final_v, final_s)
    assert vec.memory_bytes() == len(vec.resident) * 32 * 4  # accounting exact


def test_pack_pba_many_matches_scalar():
    drv = np.array([0, 3, 15])
    off = np.array([0, 77, 65535])
    got = pack_pba_many(9, drv, off)
    for i in range(3):
        assert int(got[i]) == pack_pba(9, int(drv[i]), int(off[i]))
        assert unpack_pba(int(got[i])) == (9, int(drv[i]), int(off[i]))
