"""Async block-device service tier (PR 6).

Covers:

* token-bucket arithmetic on the virtual clock;
* submission/completion ordering: acks fire at device-completion times,
  the shared CQ collects every finished request, read payloads round-trip;
* bit-identity: the same workload through the service vs direct pipeline
  calls leaves identical media, OOB, and read-back;
* QoS: strict-priority isolation of a latency tenant under an aggressor
  (p99 separation vs FIFO), EDF ordering within a class, admission
  rejection at the queue cap, token-bucket shaping;
* closed-loop driver: the window bounds outstanding requests;
* the drained-queue flush fix: a lone service write completes from
  ``engine.run()`` alone via self-re-arming timeout-flush ticks;
* per-tenant queue-wait vs service-time stage accounting;
* async checkpoint save/restore through the service, including the
  degraded-lane restore path and manifest-after-extents crash ordering.
"""
import numpy as np
import pytest

from repro.core.array import ZapRaidConfig
from repro.core.handlers import HandlerPipeline
from repro.core.zns import ZnsConfig
from repro.service import (
    DONE,
    LATENCY,
    REJECTED,
    BlockDeviceService,
    ClosedLoopClient,
    QosClass,
    TokenBucket,
)
from repro.sim import TenantSpec, multi_tenant, synthetic


def _timed_pipe(scheme="raid5", group_size=4, seed=0, logical_blocks=128,
                **cfg_kw):
    cfg = ZapRaidConfig(scheme=scheme, n_drives=4, group_size=group_size,
                        chunk_blocks=1, logical_blocks=logical_blocks,
                        gc_free_segments_low=1, **cfg_kw)
    zns = ZnsConfig(n_zones=8, zone_cap_blocks=64, block_bytes=256)
    return HandlerPipeline.build_timed(cfg, zns, seed=seed,
                                       flush_interval_us=200.0)


def _precondition(pipe, n_blocks, seed=1):
    rng = np.random.default_rng(seed)
    pipe.precondition(
        (lba, rng.integers(0, 256, (1, 256), dtype=np.uint8))
        for lba in range(n_blocks)
    )


# ------------------------------------------------------------- token bucket


def test_token_bucket_arithmetic():
    tb = TokenBucket(rate_iops=10_000.0, burst=4, t0=0.0)  # 1 token / 100us
    assert tb.peek(0.0) == 4.0
    for _ in range(4):
        assert tb.take(0.0)
    assert not tb.take(0.0)
    assert tb.next_ready(0.0) == pytest.approx(100.0)
    assert tb.peek(50.0) == pytest.approx(0.5)
    assert tb.take(100.0)
    # refill caps at burst
    assert tb.peek(1e9) == 4.0


# ------------------------------------------- submission/completion ordering


def test_acks_fire_at_device_times_and_cq_collects():
    pipe = _timed_pipe()
    svc = BlockDeviceService(pipe, max_inflight=64)
    svc.register("t", QosClass("t"))
    rng = np.random.default_rng(0)
    ref, done = {}, []
    t = 0.0
    for lba in range(24):
        blk = rng.integers(0, 256, (1, 256), dtype=np.uint8)
        ref[lba] = blk[0].copy()
        t += 20.0
        svc.submit_write("t", lba, blk, at=t, cb=done.append)
    svc.drain()
    assert len(done) == 24 and all(r.status == DONE for r in done)
    # acks fire on the virtual timeline, strictly after submission, and the
    # engine clock advanced to the last device completion
    assert all(r.t_done > r.t_submit for r in done)
    assert pipe.engine.now >= max(r.t_done for r in done)

    got = {}
    for lba in range(24):
        svc.submit_read("t", lba, 1,
                        cb=lambda r, l=lba: got.__setitem__(l, r.result[0]))
    svc.drain()
    assert all(np.array_equal(got[l], v) for l, v in ref.items())
    # every completion (48) went through the shared CQ in completion order
    reaped = svc.cq.drain()
    assert len(reaped) == 48 and svc.cq.pushed == 48
    assert all(reaped[i].t_done <= reaped[i + 1].t_done
               for i in range(len(reaped) - 1))
    assert len(svc.cq) == 0


def test_service_media_bit_identical_to_direct_calls():
    """The service is a pure scheduling layer: an identical workload through
    it vs direct pipeline calls must leave identical drive media, OOB, write
    pointers, and read-back."""
    rng = np.random.default_rng(7)
    ops = [(int(rng.integers(0, 120)),
            rng.integers(0, 256, (1, 256), dtype=np.uint8))
           for _ in range(48)]  # 48 blocks = 4 exactly-full groups (k=3)

    direct = _timed_pipe(seed=3)
    t = 0.0
    for lba, data in ops:
        t += 15.0
        direct.submit_write(lba, data, at=t)
    direct.drain()

    served = _timed_pipe(seed=3)
    svc = BlockDeviceService(served, max_inflight=64)
    svc.register("t", QosClass("t"))
    t = 0.0
    for lba, data in ops:
        t += 15.0
        svc.submit_write("t", lba, data, at=t)
    svc.drain()

    for d1, d2 in zip(direct.array.drives, served.array.drives):
        np.testing.assert_array_equal(d1.data, d2.data)
        np.testing.assert_array_equal(d1.oob, d2.oob)
        np.testing.assert_array_equal(d1.wp, d2.wp)
    ref = {}
    for lba, data in ops:
        ref[lba] = data[0]
    for lba, want in ref.items():
        np.testing.assert_array_equal(direct.array.read(lba, 1)[0], want)
        np.testing.assert_array_equal(served.array.read(lba, 1)[0], want)


# ----------------------------------------------------------------- QoS


def _victim_p99(policy):
    pipe = _timed_pipe(seed=5)
    _precondition(pipe, 128)
    svc = BlockDeviceService(pipe, max_inflight=8, policy=policy)
    svc.register("victim", LATENCY)
    svc.register("aggr", QosClass("ckpt", priority=2, max_inflight=4))
    for i in range(60):
        svc.submit_read("victim", (i * 7) % 128, at=50.0 * i)
    aggr = synthetic(
        TenantSpec(name="aggr", kind="uniform", n_ops=300, n_blocks=4,
                   arrival="closed", window=64, seed=2),
        120,
    )
    ClosedLoopClient(svc, "aggr", aggr, window=64).start(0.0)
    svc.drain()
    return svc.recorder.percentiles(op="R", tenant="victim")["p99"]


def test_qos_isolates_latency_tenant_from_aggressor():
    p99_fifo = _victim_p99("fifo")
    p99_qos = _victim_p99("qos")
    assert p99_qos * 2.0 <= p99_fifo


def test_edf_orders_within_priority_class():
    pipe = _timed_pipe(seed=1)
    _precondition(pipe, 64)
    svc = BlockDeviceService(pipe, max_inflight=1, policy="qos")
    svc.register("slack", QosClass("slack", priority=1, deadline_us=50_000.0))
    svc.register("tight", QosClass("tight", priority=1, deadline_us=100.0))
    blocker = svc.submit_read("slack", 0, at=0.0)
    # both arrive while the single slot is occupied; EDF must pick "tight"
    late = svc.submit_read("slack", 1, at=1.0)
    soon = svc.submit_read("tight", 2, at=2.0)
    svc.drain()
    assert blocker.t_dispatch < soon.t_dispatch < late.t_dispatch


def test_admission_rejects_past_queue_cap():
    pipe = _timed_pipe(seed=2)
    _precondition(pipe, 64)
    svc = BlockDeviceService(pipe, max_inflight=1)
    svc.register("t", QosClass("t", queue_cap=3))
    done = []
    for i in range(10):
        svc.submit_read("t", i, at=0.0, cb=done.append)
    svc.drain()
    ten = svc.tenants["t"]
    assert ten.rejected > 0 and ten.accepted + ten.rejected == 10
    assert ten.completed == ten.accepted
    statuses = {r.status for r in done}
    assert statuses == {DONE, REJECTED}
    # rejections complete through the CQ too, like an NVMe error completion
    assert svc.cq.pushed == 10
    # rejected requests never got device time and are excluded from stats
    assert svc.recorder.percentiles(op="R", tenant="t")["n"] == ten.accepted


def test_token_bucket_paces_dispatch():
    pipe = _timed_pipe(seed=3)
    _precondition(pipe, 64)
    svc = BlockDeviceService(pipe, max_inflight=64)
    svc.register("t", QosClass("t", rate_iops=10_000.0, burst=2))
    done = []
    for i in range(12):
        svc.submit_read("t", i, at=0.0, cb=done.append)
    svc.drain()
    assert len(done) == 12
    # burst of 2 up front, then one dispatch per 100us -- even with an idle
    # device the service must self-wake at refill instants
    disp = sorted(r.t_dispatch for r in done)
    assert disp[-1] - disp[0] >= 900.0
    assert svc.recorder.percentiles(op="R", tenant="t")["n"] == 12


# ----------------------------------------------------------- closed loop


def test_closed_loop_bounds_outstanding_window():
    pipe = _timed_pipe(seed=4)
    _precondition(pipe, 128)
    svc = BlockDeviceService(pipe, max_inflight=64)
    svc.register("t", QosClass("t"))
    reqs = synthetic(
        TenantSpec(name="t", kind="uniform", n_ops=50, read_frac=1.0,
                   arrival="closed", window=3, seed=6),
        128,
    )
    assert all(r.t_us == 0.0 for r in reqs)
    client = ClosedLoopClient(svc, "t", reqs, window=3)
    client.start(0.0)
    svc.drain()
    assert client.done() and client.completed == 50
    # no more than `window` requests ever overlap in [t_submit, t_done)
    spans = sorted((s.t_submit, s.t_done) for s in svc.recorder.samples)
    for t0, _ in spans:
        live = sum(1 for a, b in spans if a <= t0 < b)
        assert live <= 3


def test_multi_tenant_rejects_closed_loop_specs():
    with pytest.raises(ValueError, match="ClosedLoopClient"):
        multi_tenant([TenantSpec(name="c", arrival="closed")], 64)
    with pytest.raises(ValueError, match="arrival"):
        synthetic(TenantSpec(name="c", arrival="bogus"), 64)


# ------------------------------------------------- flush-tick interaction


def test_drained_submission_queue_still_flushes_partial_stripe():
    """Satellite fix: a lone service write (stripe never fills) must commit
    from ``engine.run()`` alone -- the timeout-flush tick re-arms itself
    while the service holds live work, with no drain() quiesce loop."""
    pipe = _timed_pipe()
    svc = BlockDeviceService(pipe, max_inflight=8)
    svc.register("t", QosClass("t"))
    done = []
    svc.submit_write("t", 5, np.ones((1, 256), np.uint8), at=0.0,
                     cb=done.append)
    pipe.engine.run()  # deliberately NOT svc.drain()
    assert len(done) == 1 and done[0].status == DONE
    assert pipe.array.stats.padded_blocks > 0
    # and the tick chain died with the work: the engine has quiesced
    assert pipe.engine.run() == 0


# ------------------------------------------------------------- stats


def test_per_tenant_stage_breakdown():
    pipe = _timed_pipe(seed=8)
    _precondition(pipe, 64)
    svc = BlockDeviceService(pipe, max_inflight=2)
    svc.register("a", LATENCY)
    svc.register("b", QosClass("b", priority=2))
    for i in range(20):
        svc.submit_read("a", i % 64, at=float(i))
        svc.submit_read("b", (i * 3) % 64, at=float(i))
    svc.drain()
    summ = svc.recorder.summary()
    for t in ("a", "b"):
        stages = summ["tenants"][t]["stage_means_us"]
        assert stages["queue_wait_us"] >= 0.0
        assert stages["service_us"] > 0.0
    # the background class queued strictly longer than the priority class
    a = summ["tenants"]["a"]["stage_means_us"]["queue_wait_us"]
    b = summ["tenants"]["b"]["stage_means_us"]["queue_wait_us"]
    assert b > a


# ------------------------------------------------------- async checkpoints


def _ckpt_service(seed=0):
    from repro.checkpoint.zapraid_ckpt import CheckpointConfig, CheckpointEngine

    cfg = CheckpointConfig(group_size=4, chunk_blocks=1, block_bytes=256,
                           zone_cap_blocks=256, n_zones=16)
    ckpt, pipe = CheckpointEngine.build_timed(
        cfg, 1024, seed=seed, flush_interval_us=200.0
    )
    svc = BlockDeviceService(pipe, max_inflight=16)
    svc.register("ckpt", QosClass("ckpt", priority=2))
    return ckpt, pipe, svc


def _state(seed):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal(128).astype(np.float32),
        "b": rng.standard_normal(64).astype(np.float32),
    }


def test_checkpoint_async_roundtrip_and_crash_ordering():
    ckpt, pipe, svc = _ckpt_service()
    s0, s1 = _state(1), _state(2)
    t0 = ckpt.save_async(0, s0, service=svc)
    svc.drain()
    t1 = ckpt.save_async(1, s1, service=svc)
    svc.drain()
    assert t0.done and t1.done and t1.t_done > t1.t_issue

    # crash ordering: the manifest write (at lba_base) was only submitted
    # after every leaf extent had acked
    reqs = svc.cq.drain()
    for ticket in (t0, t1):
        manifest = [r for r in reqs if r.op == "W" and r.lba == ckpt.lba_base
                    and abs(r.t_done - ticket.t_done) < 1e-9]
        assert len(manifest) == 1
        leaves = [r for r in reqs if r.op == "W" and r.lba != ckpt.lba_base
                  and r.t_submit <= manifest[0].t_submit]
        assert manifest[0].t_submit >= max(l.t_done for l in leaves)

    rt = ckpt.restore_async(1, s1, service=svc)
    svc.drain()
    assert rt.done and rt.n_extents == 2
    for k in s1:
        np.testing.assert_array_equal(np.asarray(rt.state[k]), s1[k])


def test_checkpoint_async_restore_degraded():
    ckpt, pipe, svc = _ckpt_service(seed=9)
    s0 = _state(3)
    ckpt.save_async(0, s0, service=svc)
    svc.drain()
    ckpt.fail_lane(1)
    rt = ckpt.restore_async(0, s0, service=svc)
    svc.drain()
    assert rt.done
    for k in s0:
        np.testing.assert_array_equal(np.asarray(rt.state[k]), s0[k])
    assert pipe.array.stats.degraded_reads > 0


def test_checkpoint_windows_share_one_array():
    from repro.checkpoint.zapraid_ckpt import (
        MANIFEST_LBAS,
        CheckpointConfig,
        CheckpointEngine,
    )

    cfg = CheckpointConfig(group_size=4, chunk_blocks=1, block_bytes=256,
                           zone_cap_blocks=256, n_zones=16)
    pipe = HandlerPipeline.build_timed(cfg.zap_cfg(1024), cfg.zns_cfg(),
                                       seed=0, flush_interval_us=200.0)
    svc = BlockDeviceService(pipe, max_inflight=16)
    span = MANIFEST_LBAS + 256
    engines, states, tickets = [], [], []
    for j in range(2):
        svc.register(f"job{j}", QosClass(f"job{j}", priority=2))
        engines.append(CheckpointEngine(cfg, 1024, array=pipe.array,
                                        lba_base=j * span, lba_span=span))
        states.append(_state(10 + j))
    for j, (eng, st) in enumerate(zip(engines, states)):
        tickets.append(eng.save_async(0, st, service=svc, tenant=f"job{j}"))
    svc.drain()
    assert all(t.done for t in tickets)
    # interleaved tenants, disjoint windows: each restores its own state
    for j, (eng, st) in enumerate(zip(engines, states)):
        rt = eng.restore_async(0, st, service=svc, tenant=f"job{j}")
        svc.drain()
        for k in st:
            np.testing.assert_array_equal(np.asarray(rt.state[k]), st[k])
