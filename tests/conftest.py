"""Make ``src/`` and this directory importable regardless of invocation cwd.

Keeps the tier-1 command (``PYTHONPATH=src python -m pytest``) working while
also letting a bare ``pytest`` run find both ``repro`` and the ``_hyp``
hypothesis shim.
"""
import sys
from pathlib import Path

_HERE = Path(__file__).resolve().parent
for p in (str(_HERE), str(_HERE.parent / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)
