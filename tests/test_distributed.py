"""Sharding resolver, elastic runtime, compression, and perfmodel trends."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.core import perfmodel as pm
from repro.distributed import compression as comp
from repro.distributed import sharding as sh
from repro.distributed.elastic import ElasticRuntime, GroupCommitScheduler


def small_mesh():
    # 1 real device: mesh (1,1) exercises the resolution logic paths
    return jax.make_mesh((1, 1), ("data", "model"))


def test_resolve_spec_divisibility_guard():
    mesh = small_mesh()
    rules = {"heads": "model", "embed": None, None: None}
    spec = sh.resolve_spec((9, 64), ("heads", "embed"), rules, mesh)
    assert spec == P("model", None)  # 9 % 1 == 0 on a 1-wide axis


def test_resolve_spec_single_use_per_axis():
    mesh = small_mesh()
    rules = {"experts": "model", "ff": "model", None: None}
    spec = sh.resolve_spec((8, 128, 256), ("experts", None, "ff"), rules, mesh)
    assert spec == P("model", None, None)  # ff falls through: axis used


# ------------------------------------------------------------- elastic

def test_remesh_after_failures():
    rt = ElasticRuntime(n_hosts=32, chips_per_host=16, model_parallel=16)
    assert rt.plan_mesh() == (32, 16)
    plan = rt.on_failure([3, 7])
    assert plan["mesh"] == (16, 16)  # largest pow2 data axis from 30 hosts
    assert plan["healthy_hosts"] == 30
    rt.on_join(3)
    assert rt.plan_mesh() == (16, 16)


def test_group_commit_beats_per_step_barrier():
    """The paper's G-sweep reproduced for gradient commits: larger commit
    groups amortize straggler stalls (saturating), G=1 is the barrier."""
    sched = GroupCommitScheduler(n_workers=64, straggle_p=0.05,
                                 straggle_factor=5.0, seed=3)
    res = {g: sched.simulate(steps=256, group_size=g) for g in (1, 4, 16, 64)}
    assert res[1].speedup == pytest.approx(1.0, abs=1e-6)
    assert res[4].speedup > 1.05
    assert res[16].speedup > res[4].speedup
    assert res[64].speedup >= res[16].speedup * 0.95  # saturation allowed
    # CST-analogue metadata grows like G log2 G
    assert sched.commit_table_bits(16) == 64 * 16 * 4


# ---------------------------------------------------------- compression

@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_compression_error_feedback_accumulates(kind):
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal(512) * 1e-3, jnp.float32)}
    r = comp.init_residual(g)
    total_c = jnp.zeros(512)
    for _ in range(8):
        c, r = comp.apply_compression(g, r, kind)
        total_c = total_c + c["w"]
    # error feedback: accumulated compressed updates approach the true sum
    want = 8 * np.asarray(g["w"])
    got = np.asarray(total_c) + np.asarray(r["w"])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_int8_compression_bounded_error():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal(1024), jnp.float32)
    c = comp.compress_int8(g)
    scale = float(jnp.max(jnp.abs(g))) / 127
    assert float(jnp.max(jnp.abs(c - g))) <= scale * 0.5 + 1e-6


# ----------------------------------------------------------- perfmodel

def test_perfmodel_reproduces_paper_trends():
    # (i) Zone Append beats Zone Write for 4K writes on one open zone
    assert pm.zone_append_tput(4, qd=4, n_zones=1) > pm.zone_write_tput(4, 1) * 1.4
    # (ii) Zone Write scales with open zones; Zone Append degrades past 2
    assert pm.zone_write_tput(4, 6) > pm.zone_write_tput(4, 1) * 2
    assert pm.zone_append_tput(4, 4, 6) < pm.zone_append_tput(4, 4, 2)
    # (iii) 16K: both saturate the zone
    assert abs(pm.zone_write_tput(16, 1) - 1050.0) < 1e-6
    # (iv) G-sweep: monotone rise then saturation (paper Fig. 8)
    t = [pm.zapraid_write_perf(k=3, m=1, chunk_kib=4, group_size=g).throughput_mib_s
         for g in (1, 4, 64, 256, 1024)]
    assert t[1] > t[0] and t[2] > t[1] and t[3] >= t[2] * 0.99
    assert t[4] < t[3] * 1.05  # saturated
    # (v) headline gain: ZapRAID vs ZoneWrite-Only ~ +72.8% at 4K
    za = pm.zapraid_write_perf(k=3, m=1, chunk_kib=4, group_size=256)
    zw = pm.zapraid_write_perf(k=3, m=1, chunk_kib=4, group_size=1, use_append=False)
    gain = za.throughput_mib_s / zw.throughput_mib_s - 1
    assert 0.55 < gain < 0.95
    # (vi) degraded read latency grows with G (query overhead, Fig. 8b)
    d1 = pm.degraded_read_latency_us(k=3, chunk_kib=4, group_size=256)
    d2 = pm.degraded_read_latency_us(k=3, chunk_kib=4, group_size=4096)
    assert d2 > d1


def test_hybrid_perf_best_of_both():
    """Hybrid >= max(pure-ZA-small, pure-ZW) for a 75/25 mixed workload."""
    hybrid = pm.hybrid_write_perf(k=3, m=1, cs_kib=8, cl_kib=16,
                                  n_small=1, n_large=3, frac_small=0.75,
                                  group_size=256)
    zw_only = pm.hybrid_write_perf(k=3, m=1, cs_kib=8, cl_kib=16,
                                   n_small=1, n_large=3, frac_small=0.75,
                                   group_size=1)
    assert hybrid.throughput_mib_s >= zw_only.throughput_mib_s
