"""Tests for the observability layer (``repro.obs``): trace-event JSON
schema and async-span nesting, metric registry/sampler monotonicity under
GC and rebuild, the load-bearing bit-identity of tracing-on vs tracing-off
runs across every RAID level (media, OOB, and L2P), the windowed-percentile
helper shared with the SLO monitor, the GC reserved-zone auto-size, and the
SLO monitor's dynamic-admission loop (shrink under pressure, restore once
the tail recovers, measurably better serving p99)."""
import math

import numpy as np
import pytest

from repro.core.array import ZapRaidConfig
from repro.core.handlers import HandlerPipeline
from repro.core.zns import ZnsConfig
from repro.obs import (
    Histogram,
    MetricsRegistry,
    MetricsSampler,
    Tracer,
    standard_collector,
    validate_metrics_series,
    validate_trace_events,
)
from repro.service import BlockDeviceService, ClosedLoopClient, QosClass
from repro.service.scenario import checkpoint_under_serving, read_qd_sweep
from repro.sim import TenantSpec, synthetic
from repro.sim.stats import LatencyRecorder

BB = 256
SCHEMES = ("raid4", "raid5", "raid6", "raid01")

SLO_KW = dict(window_us=1500.0, interval_us=250.0, min_samples=8)


def _timed_pipe(scheme="raid5", seed=0, logical_blocks=128, zones=8,
                zone_cap=64, **cfg_kw):
    n_drives = 5 if scheme == "raid6" else 4
    cfg = ZapRaidConfig(scheme=scheme, n_drives=n_drives, group_size=4,
                        chunk_blocks=1, logical_blocks=logical_blocks,
                        gc_free_segments_low=1, **cfg_kw)
    zns = ZnsConfig(n_zones=zones, zone_cap_blocks=zone_cap, block_bytes=BB)
    return HandlerPipeline.build_timed(cfg, zns, seed=seed,
                                       flush_interval_us=200.0)


def _precondition(pipe, n_blocks, seed=1):
    rng = np.random.default_rng(seed)
    pipe.precondition(
        (lba, rng.integers(0, 256, (1, BB), dtype=np.uint8))
        for lba in range(n_blocks)
    )


def _workload(pipe, *, rounds=2, reads=48, fail=False, seed=5):
    """Deterministic timed write/read mix, optionally with a drive failure
    mid-stream and a paced rebuild -- reads after the failure sweep the
    whole LBA range so degraded decodes are guaranteed to occur."""
    logical = pipe.array.cfg.logical_blocks
    rng = np.random.default_rng(seed)
    t = 0.0
    for _ in range(rounds):
        for lba in range(0, logical - 2, 2):
            pipe.submit_write(
                lba, rng.integers(0, 256, (2, BB), dtype=np.uint8), at=t)
            t += 8.0
    for i in range(reads):
        pipe.submit_read((i * 5) % (logical - 3), 3, at=t)
        t += 10.0
    if fail:
        pipe.schedule_drive_failure(1, t + 50.0)
        for i in range(reads):
            pipe.submit_read((i * 7) % (logical - 2), 2,
                             at=t + 100.0 + 12.0 * i)
        pipe.schedule_rebuild(1, t + 100.0 + 14.0 * reads, interval_us=40.0)
    pipe.drain()


# ---------------------------------------------------------------- units


def test_histogram_buckets():
    h = Histogram()
    for v in (0.5, 1.0, 3.0, 1000.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["n"] == 4
    assert snap["total"] == pytest.approx(1004.5)
    assert snap["counts"][0] == 1          # < 1us
    assert sum(snap["counts"]) == 4


def test_registry_snapshot_and_clear():
    reg = MetricsRegistry()
    reg.inc("a", 2.0)
    reg.inc("a")
    reg.set("g", 7)
    reg.observe("h", 12.0)
    snap = reg.snapshot()
    assert snap["counters"]["a"] == 3.0
    assert snap["gauges"]["g"] == 7.0
    assert snap["histograms"]["h"]["n"] == 1
    reg.clear()
    assert not reg.counters and not reg.gauges and not reg.histograms


def test_validate_metrics_series_catches_regressions():
    good = {"series": [
        {"t_us": 0.0, "counters": {"c": 1.0}, "gauges": {}},
        {"t_us": 5.0, "counters": {"c": 2.0}, "gauges": {"g": 1.0}},
    ]}
    validate_metrics_series(good)
    with pytest.raises(AssertionError, match="decreased"):
        validate_metrics_series({"series": [
            {"t_us": 0.0, "counters": {"c": 2.0}, "gauges": {}},
            {"t_us": 5.0, "counters": {"c": 1.0}, "gauges": {}},
        ]})
    with pytest.raises(AssertionError, match="monotone"):
        validate_metrics_series({"series": [
            {"t_us": 5.0, "counters": {}, "gauges": {}},
            {"t_us": 0.0, "counters": {}, "gauges": {}},
        ]})


def test_validate_trace_events_catches_mis_nesting():
    tr = Tracer()
    tr.req_begin(1, "io.request", 0.0)
    tr.req_begin(1, "sq.wait", 1.0)
    tr.req_end(1, "sq.wait", 2.0)
    tr.req_end(1, "io.request", 3.0)
    validate_trace_events(tr.to_trace_events())
    # unclosed span
    tr2 = Tracer()
    tr2.req_begin(1, "io.request", 0.0)
    with pytest.raises(AssertionError, match="unclosed"):
        validate_trace_events(tr2.to_trace_events())
    # crossed begin/end names
    tr3 = Tracer()
    tr3.req_begin(1, "a", 0.0)
    tr3.req_begin(1, "b", 1.0)
    tr3.req_end(1, "a", 2.0)
    tr3.req_end(1, "b", 3.0)
    with pytest.raises(AssertionError, match="mis-nested"):
        validate_trace_events(tr3.to_trace_events())


def test_tracer_lane_packing_separates_overlaps():
    tr = Tracer()
    tr.span("drive0", "read", 0.0, 10.0)
    tr.span("drive0", "read", 5.0, 15.0)   # overlaps -> second lane
    tr.span("drive0", "read", 12.0, 20.0)  # fits back in lane 0
    events = tr.to_trace_events()
    validate_trace_events(events)
    xs = [e for e in events if e["ph"] == "X"]
    tids = sorted(e["tid"] for e in xs)
    assert len(set(tids)) == 2             # two lanes, third span reuses one
    names = {e["tid"]: e["args"]["name"] for e in events if e["ph"] == "M"
             and e["name"] == "thread_name"}
    assert "drive0" in names.values() and "drive0 #1" in names.values()


def test_windowed_percentiles_and_empty_guard():
    rec = LatencyRecorder()
    for i in range(100):
        rec.record("t", "R", float(i), float(i) + 10.0 + i)
    full = rec.percentiles(op="R")
    assert full["n"] == 100
    win = rec.windowed_percentiles(0.0, 60.0, op="R", tenant="t")
    assert 0 < win["n"] < 100
    assert win["p99"] <= full["p99"]
    empty = rec.windowed_percentiles(1e6, 2e6, op="R")
    assert empty["n"] == 0
    assert math.isnan(empty["p99"]) and math.isnan(empty["mean"])
    # whole-run empty guard too (pre-obs this raised on np.percentile([]))
    assert LatencyRecorder().percentiles()["n"] == 0


# ------------------------------------------------------- trace from a run


def test_trace_schema_names_and_bounds():
    pipe = _timed_pipe(logical_blocks=96)
    _precondition(pipe, 96)
    tracer = pipe.attach_obs()
    _workload(pipe, rounds=2, fail=True)
    events = tracer.to_trace_events()
    validate_trace_events(events)
    assert tracer.dropped == 0
    names = {e["name"] for e in events}
    # device channel spans, background passes, degraded decode all present
    assert {"zone_append", "read"} <= names
    assert "degraded.decode" in names
    assert {"rebuild.full", "rebuild.segment"} & names
    # bookings may outlive the last processed event (drain-time flush), so
    # the bound is the device-time watermark, not the event clock
    t_end = max(pipe.engine.now, pipe.engine.io_watermark)
    for e in events:
        assert 0.0 <= e["ts"] <= t_end
        if e["ph"] == "X":
            assert e["ts"] + e["dur"] <= t_end + 1e-6


def test_request_spans_through_service():
    n_ops = 64
    pipe = _timed_pipe(logical_blocks=96)
    _precondition(pipe, 96)
    tracer = pipe.attach_obs()
    svc = BlockDeviceService(pipe, max_inflight=2, policy="qos")
    svc.tracer = tracer
    svc.register("t", QosClass("t"))
    reqs = synthetic(
        TenantSpec(name="t", kind="uniform", n_ops=n_ops, read_frac=0.5,
                   arrival="closed", window=8, seed=3),
        96,
    )
    client = ClosedLoopClient(svc, "t", reqs, window=8)
    client.start(0.0)
    svc.drain()
    assert client.done() and client.rejected == 0
    events = tracer.to_trace_events()
    validate_trace_events(events)
    begins = [e for e in events if e["ph"] == "b"]
    by_name = {}
    for e in begins:
        by_name.setdefault(e["name"], []).append(e)
    assert len(by_name["io.request"]) == n_ops
    assert len(by_name["device.service"]) == n_ops
    # window (2) < client QD (8) forces submission-queue waits
    assert by_name.get("sq.wait")
    dispatches = [e for e in events if e["ph"] == "n"
                  and e["name"] == "qos.dispatch"]
    assert dispatches and all("klass" in e["args"] for e in dispatches)
    # every io.request root carries tenant/op identity
    assert all(e["args"].get("tenant") == "t"
               for e in by_name["io.request"])


# ------------------------------------------- metrics under GC and rebuild


def test_metrics_monotone_under_gc_and_rebuild():
    pipe = _timed_pipe(logical_blocks=128, zones=6)
    _precondition(pipe, 128)
    reg = MetricsRegistry()
    sampler = MetricsSampler(pipe.engine, reg, standard_collector(pipe),
                             interval_us=25.0)
    sampler.start(0.0)
    pipe.schedule_gc(10.0, 100.0, n_ticks=50)
    _workload(pipe, rounds=6, fail=True)
    assert pipe.array.stats.gc_runs > 0          # pressure actually built
    assert len(sampler.series) > 10
    validate_metrics_series({"series": sampler.series})
    last = sampler.series[-1]
    assert last["counters"]["array/stripes_committed"] > 0
    assert "array/gc_reserved_zones" in last["gauges"]
    assert any(r["counters"].get("array/gc_blocks_moved", 0) > 0
               for r in sampler.series)
    # zone-state gauges cover every drive
    for d in pipe.array.drives:
        assert f"drive{d.drive_id}/zones_open" in last["gauges"]


def test_sampler_does_not_keep_engine_alive():
    pipe = _timed_pipe(logical_blocks=64)
    sampler = MetricsSampler(pipe.engine, MetricsRegistry(),
                             standard_collector(pipe), interval_us=10.0)
    sampler.start(0.0)
    pipe.drain()
    n = len(sampler.series)
    assert pipe.engine.pending() == 0            # no self-sustaining ticks
    pipe.drain()
    assert len(sampler.series) == n


# ------------------------------------------------------ bit-identity gate


@pytest.mark.parametrize("scheme", SCHEMES)
def test_tracing_is_observe_only(scheme):
    """Tracing+metrics on vs off: media, OOB, L2P, and the virtual clock
    must be bit-identical -- the obs layer may never book device time."""
    results = []
    for obs in (False, True):
        pipe = _timed_pipe(scheme=scheme, logical_blocks=96)
        _precondition(pipe, 96)
        if obs:
            pipe.attach_obs()
            sampler = MetricsSampler(
                pipe.engine, MetricsRegistry(), standard_collector(pipe),
                interval_us=20.0)
            sampler.start(0.0)
        _workload(pipe, rounds=2, fail=True)
        results.append(pipe)
    off, on = results
    assert off.engine.now == on.engine.now
    assert np.array_equal(off.array.l2p.flat, on.array.l2p.flat)
    for d0, d1 in zip(off.array.drives, on.array.drives):
        assert np.array_equal(d0.data, d1.data)
        assert np.array_equal(d0.oob, d1.oob)
        assert np.array_equal(d0.wp, d1.wp)
        assert np.array_equal(d0.state, d1.state)


def test_qd_sweep_rows_identical_with_obs():
    kw = dict(qds=(4,), n_ops=48, logical_blocks=1024, seed=0)
    assert read_qd_sweep(obs=False, **kw) == read_qd_sweep(obs=True, **kw)


# ------------------------------------------------------ escrow auto-size


def test_gc_escrow_auto_sizes_from_geometry():
    pipe = _timed_pipe(logical_blocks=96, zones=16)
    arr = pipe.array
    auto = len(arr.cfg.chunk_sizes())
    assert arr.reserved_zones() == 0             # roomy array: no escrow
    base_free = arr.free_segment_count()
    # drain free zones until the array is near-full -> escrow kicks in
    while min(len(fz) for fz in arr.free_zones) > \
            auto + arr.cfg.gc_free_segments_low + 1:
        for fz in arr.free_zones:
            fz.pop()
    assert arr.reserved_zones() == auto
    assert arr.free_segment_count() < base_free
    # an explicit setting always wins, roomy or not
    pipe2 = _timed_pipe(logical_blocks=96, zones=16, gc_reserved_zones=2)
    assert pipe2.array.reserved_zones() == 2


# ------------------------------------------------------------ SLO monitor


def test_slo_monitor_shrinks_and_restores():
    res = checkpoint_under_serving(
        policy="qos", seed=0, restore_check=False,
        slo_objective_us=200.0, slo_kwargs=dict(SLO_KW),
        sampler_interval_us=100.0,
    )
    s = res["slo"]
    assert s["n_shrinks"] > 0, s
    assert s["n_restores"] > 0, s
    assert 1 <= s["min_cap"] < s["default_cap"]
    assert s["final_cap"] <= s["default_cap"]
    assert res["slo_actions"]
    # the sampler saw the actuated cap move below the default
    caps = [r["gauges"].get("class/ckpt/cap") for r in res["metrics_series"]]
    assert any(c is not None and c < s["default_cap"] for c in caps)
    validate_metrics_series({"series": res["metrics_series"]})


def test_slo_monitor_recovers_serving_p99():
    static = checkpoint_under_serving(policy="qos", seed=0,
                                      restore_check=False)
    dyn = checkpoint_under_serving(
        policy="qos", seed=0, restore_check=False,
        slo_objective_us=150.0, slo_kwargs=dict(SLO_KW),
    )
    assert static["serve_p99_us"] > 150.0        # pressure exists to relieve
    assert dyn["serve_p99_us"] < static["serve_p99_us"]
    assert dyn["slo"]["n_shrinks"] > 0
    # checkpoint traffic still completes, just slower
    assert dyn["ckpt_save_max_us"] >= static["ckpt_save_max_us"]
