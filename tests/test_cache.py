"""Tests for the ZNS-aware cache tier (``repro.cache``): sketch admission,
zone-granular eviction, and -- the load-bearing property -- bit-identity of
cached vs uncached reads across every RAID level through overwrites,
degraded reads, GC relocation, and full-drive rebuild.  Also covers the
L2P mapping-block cache, the mapping-staging refcount regression, the GC
reserved-zone escrow, and the timed fast path (cache hits at cache-device
latency, dispatcher bypass)."""
import numpy as np
import pytest

from repro.cache import (
    CacheConfig,
    FrequencySketch,
    ZnsCacheTier,
    meta_key,
    user_key,
)
from repro.core.array import ZapRaidConfig, ZapRAIDArray
from repro.core.zns import ZnsConfig

BB = 256  # small blocks keep tests fast

SCHEMES = ("raid4", "raid5", "raid6", "raid01")


def mk(scheme="raid5", logical=256, zones=12, zone_cap=64, *, cache=True,
       cache_zones=4, cache_zone_cap=32, **kw):
    n_drives = 5 if scheme == "raid6" else 4
    kw.setdefault("gc_free_segments_low", 1)
    cfg = ZapRaidConfig(
        scheme=scheme, n_drives=n_drives, group_size=4, chunk_blocks=1,
        logical_blocks=logical, **kw,
    )
    zns = ZnsConfig(n_zones=zones, zone_cap_blocks=zone_cap, block_bytes=BB)
    arr = ZapRAIDArray(cfg, zns)
    if cache:
        arr.attach_cache(ZnsCacheTier(
            CacheConfig(n_zones=cache_zones, zone_cap_blocks=cache_zone_cap,
                        block_bytes=BB),
            logical,
        ))
    return arr


def fill(arr, rng, n_writes, logical, max_len=4):
    for _ in range(n_writes):
        n = int(rng.integers(1, max_len + 1))
        lba = int(rng.integers(0, logical - n))
        arr.write(lba, rng.integers(0, 256, (n, BB), dtype=np.uint8))
    arr.flush()


# ------------------------------------------------------------ sketch unit

def test_sketch_counts_and_decay():
    sk = FrequencySketch(width=256, n_hashes=4, decay_every=None)
    keys = np.arange(10, dtype=np.int64)
    assert (sk.estimate(keys) == 0).all()
    sk.add(keys)
    sk.add(keys[:5])
    est = sk.estimate(keys)
    assert (est[:5] >= 2).all() and (est[5:] >= 1).all()
    # count-min never undercounts
    assert (est[5:] <= est[:5]).all() or True  # collisions only inflate
    sk.clear()
    assert (sk.estimate(keys) == 0).all()


def test_sketch_halving_decay():
    sk = FrequencySketch(width=64, n_hashes=2, decay_every=32)
    k = np.array([7], dtype=np.int64)
    for _ in range(16):
        sk.add(k)
    before = int(sk.estimate(k)[0])
    # push enough distinct keys through to trip the halving decay
    sk.add(np.arange(100, 200, dtype=np.int64))
    assert int(sk.estimate(k)[0]) < before


# -------------------------------------------------------------- tier unit

def test_fill_lookup_refresh_invalidate():
    tier = ZnsCacheTier(CacheConfig(n_zones=2, zone_cap_blocks=8,
                                    block_bytes=BB), 64)
    rng = np.random.default_rng(0)
    keys = np.array([user_key(3), user_key(9), meta_key(1)], dtype=np.int64)
    blocks = rng.integers(0, 256, (3, BB), dtype=np.uint8)
    tier.fill_many(keys, blocks, force=True)
    hit, rows = tier.lookup_many(keys)
    assert hit.all() and np.array_equal(rows, blocks)
    assert tier.resident_count() == 3
    # refresh updates in place, non-resident keys ignored (no write-allocate)
    nb = rng.integers(0, 256, (2, BB), dtype=np.uint8)
    tier.refresh_many(np.array([user_key(3), user_key(50)]), nb)
    assert np.array_equal(tier.lookup_one(user_key(3)), nb[0])
    assert tier.lookup_one(user_key(50)) is None
    assert tier.resident_count() == 3
    # invalidate drops the mapping
    tier.invalidate_one(user_key(9))
    assert tier.lookup_one(user_key(9)) is None
    assert tier.stats.invalidations == 1


def test_admission_gate_blocks_one_touch_scan():
    tier = ZnsCacheTier(CacheConfig(n_zones=2, zone_cap_blocks=8,
                                    block_bytes=BB, admit_threshold=2), 256)
    rng = np.random.default_rng(1)
    keys = (np.arange(8, dtype=np.int64) << 1)
    blocks = rng.integers(0, 256, (8, BB), dtype=np.uint8)
    # no prior misses recorded: a one-touch fill is rejected wholesale
    tier.fill_many(keys, blocks)
    assert tier.resident_count() == 0
    assert tier.stats.rejects == 8
    # two recorded misses clear the threshold
    tier.lookup_many(keys)
    tier.lookup_many(keys)
    tier.fill_many(keys, blocks)
    assert tier.resident_count() == 8
    # force bypasses the gate entirely
    k2 = np.array([user_key(100)], dtype=np.int64)
    tier.fill_many(k2, blocks[:1], force=True)
    assert tier.contains_many(k2).all()


def test_zone_eviction_prefers_unreferenced_and_clears_clock():
    cap = 4
    tier = ZnsCacheTier(CacheConfig(n_zones=3, zone_cap_blocks=cap,
                                    block_bytes=BB), 256)
    rng = np.random.default_rng(2)
    blk = lambda n: rng.integers(0, 256, (n, BB), dtype=np.uint8)
    k = lambda lo: (np.arange(lo, lo + cap, dtype=np.int64) << 1)
    tier.fill_many(k(0), blk(cap), force=True)     # zone 0
    tier.fill_many(k(10), blk(cap), force=True)    # zone 1
    tier.fill_many(k(20), blk(cap), force=True)    # zone 2
    # reference zones 1 and 2; zone 0 stays cold
    tier.lookup_many(k(10))
    tier.lookup_many(k(20))
    tier.fill_many(k(30), blk(cap), force=True)    # forces an eviction
    assert tier.stats.zone_resets == 1
    assert not tier.contains_many(k(0)).any()      # cold zone was the victim
    assert tier.contains_many(k(10)).all()
    assert tier.contains_many(k(20)).all()
    # the reset was one clock tick: every ref bit cleared
    assert int(tier.ref.sum()) == cap  # only the fresh fills hold grace refs


def test_contains_run():
    tier = ZnsCacheTier(CacheConfig(n_zones=2, zone_cap_blocks=8,
                                    block_bytes=BB), 64)
    rng = np.random.default_rng(3)
    tier.fill_many(np.arange(4, 8, dtype=np.int64) << 1,
                   rng.integers(0, 256, (4, BB), dtype=np.uint8), force=True)
    assert tier.contains_run(4, 4)
    assert tier.contains_run(5, 2)
    assert not tier.contains_run(3, 2)
    assert not tier.contains_run(7, 2)
    # stats untouched by the side-effect-free probe
    assert tier.stats.hits == 0 and tier.stats.misses == 0


# --------------------------------------------- cached vs uncached identity

@pytest.mark.parametrize("scheme", SCHEMES)
def test_cached_reads_bit_identical(scheme):
    """The tentpole property: with a cache attached, every read -- healthy,
    after overwrites, degraded with any single drive failed, after GC
    relocation, and after rebuild -- returns byte-for-byte what the
    uncached array returns."""
    a = mk(scheme, cache=True)
    b = mk(scheme, cache=False)
    rng_a, rng_b = (np.random.default_rng(7) for _ in range(2))
    fill(a, rng_a, 80, 256)
    fill(b, rng_b, 80, 256)
    rng = np.random.default_rng(11)

    def sample(n, tag):
        for _ in range(n):
            lba = int(rng.integers(0, 250))
            nb = int(rng.integers(1, 5))
            ra, rb = a.read(lba, nb), b.read(lba, nb)
            assert np.array_equal(ra, rb), f"{scheme}/{tag} @{lba}+{nb}"

    sample(40, "healthy")
    assert a.stats.cache_hits > 0  # the warm cache is actually serving reads
    # degraded identity for every possible failed drive (warm + cold fills)
    for d in range(a.cfg.n_drives):
        a.fail_drive(d)
        b.fail_drive(d)
        sample(15, f"degraded_d{d}")
        a.rebuild_drive(d)
        b.rebuild_drive(d)
    sample(15, "post_rebuild")
    # overwrite coherence: committed writes must supersede cached copies
    for _ in range(20):
        lba = int(rng.integers(0, 250))
        data = rng.integers(0, 256, (2, BB), dtype=np.uint8)
        a.write(lba, data)
        b.write(lba, data)
    a.flush()
    b.flush()
    sample(30, "after_overwrite")
    # GC relocation moves physical copies; logical cache keys stay valid
    for arr in (a, b):
        for _ in range(3):
            if not arr.gc_once():
                break
    sample(30, "after_gc")
    assert not a._meta_staging and not a._meta_refs


def test_cache_degraded_fill_then_hit():
    """A degraded read's reconstructed payload is admitted like any other
    fill and later served from cache, still bit-identical."""
    a = mk("raid5", cache=True)
    rng = np.random.default_rng(13)
    fill(a, rng, 60, 256)
    a.fail_drive(1)
    want = [a.read(lba, 2).copy() for lba in (5, 50, 105)]
    for _ in range(2):  # clear the admission threshold
        for lba in (5, 50, 105):
            a.read(lba, 2)
    h0 = a.stats.cache_hits
    got = [a.read(lba, 2) for lba in (5, 50, 105)]
    assert a.stats.cache_hits > h0
    for w, g in zip(want, got):
        assert np.array_equal(w, g)


# ------------------------------------------------- L2P mapping-block cache

def test_l2p_mapping_cache_serves_fault_ins():
    """With the L2P offloading, CLOCK evictions spill group images into the
    cache and later fault-ins hit it instead of the media; reads stay
    identical to an uncached offloaded array."""
    a = mk("raid5", logical=2048, zones=32, cache_zones=8, cache_zone_cap=64,
           l2p_memory_limit_entries=256)
    b = mk("raid5", logical=2048, zones=32, cache=False,
           l2p_memory_limit_entries=256)
    rng_a, rng_b = (np.random.default_rng(17) for _ in range(2))
    for arr, rng in ((a, rng_a), (b, rng_b)):
        for base in range(0, 2048, 64):
            arr.write(base, rng.integers(0, 256, (64, BB), dtype=np.uint8))
        arr.flush()
    assert a.stats.l2p_cache_offloads > 0
    rng = np.random.default_rng(19)
    for _ in range(120):
        lba = int(rng.integers(0, 2044))
        assert np.array_equal(a.read(lba, 4), b.read(lba, 4))
    s = a.stats
    assert s.l2p_cache_hits > 0
    assert s.l2p_cache_hits + s.l2p_cache_misses > 0
    # every cached fault-in skipped a media read; both arrays agree on state
    assert a.l2p.misses == b.l2p.misses


def test_meta_staging_drains_after_flush():
    """Regression: committed mapping blocks must release their host staging
    copy (the refcount replaces a timestamp match broken by stripe-commit
    re-stamping) -- otherwise staging grows without bound and shadows both
    the media and the cache forever."""
    arr = mk("raid5", logical=1024, zones=24, l2p_memory_limit_entries=128)
    rng = np.random.default_rng(23)
    for base in range(0, 1024, 32):
        arr.write(base, rng.integers(0, 256, (32, BB), dtype=np.uint8))
    for _ in range(30):
        arr.write(int(rng.integers(0, 1000)),
                  rng.integers(0, 256, (4, BB), dtype=np.uint8))
    arr.flush()
    assert arr.stats.meta_blocks_written > 0
    assert arr._meta_staging == {}
    assert arr._meta_refs == {}
    assert arr._pending_meta == []


# --------------------------------------------------- GC reserved-zone escrow

def test_gc_escrow_accounting():
    """Foreground segment opens refuse to dip below the escrow floor; a GC
    pass (``_gc_active``) may consume it; ``free_segment_count`` hides the
    reserve from foreground watermarks."""
    arr = mk("raid5", cache=False, gc_reserved_zones=1)
    base = min(len(fz) for fz in arr.free_zones)
    assert arr.free_segment_count() == base - 1
    # drain every drive's free list down to exactly the escrowed zone
    for fz in arr.free_zones:
        del fz[:-1]
    assert arr.free_segment_count() == 0
    with pytest.raises(RuntimeError, match="out of free zones"):
        arr._open_segment(0, 1, 4)
    # GC restage may take the reserve
    arr._gc_active = True
    assert arr.free_segment_count() == 1
    arr._open_segment(0, 1, 4)  # does not raise
    arr._gc_active = False


def test_gc_escrow_high_utilization_churn():
    """Sustained overwrite churn at tight zone budget completes with the
    escrow configured: GC always has a restage destination."""
    arr = mk("raid5", cache=False, logical=96, zones=6, gc_reserved_zones=1,
             gc_free_segments_low=2)
    rng = np.random.default_rng(29)
    ref = {}
    for _ in range(900):
        lba = int(rng.integers(0, 96))
        blk = rng.integers(0, 256, (1, BB), dtype=np.uint8)
        arr.write(lba, blk)
        ref[lba] = blk[0].copy()
    arr.flush()
    assert arr.stats.gc_runs > 0
    assert not arr._gc_active  # the escrow window closed cleanly
    for lba, want in ref.items():
        assert np.array_equal(arr.read(lba, 1)[0], want)


# ------------------------------------------------------------- timed path

def _timed_pipe(logical=256, cache=True):
    from repro.core.handlers import HandlerPipeline

    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=8,
                        chunk_blocks=1, logical_blocks=logical,
                        gc_free_segments_low=1)
    zns = ZnsConfig(n_zones=16, zone_cap_blocks=64, block_bytes=BB)
    pipe = HandlerPipeline.build_timed(cfg, zns, seed=5)
    if cache:
        pipe.attach_cache(ZnsCacheTier(
            CacheConfig(n_zones=4, zone_cap_blocks=64, block_bytes=BB),
            logical,
        ))
    rng = np.random.default_rng(5)
    pipe.precondition(
        (lba, rng.integers(0, 256, (1, BB), dtype=np.uint8))
        for lba in range(logical)
    )
    return pipe


def test_timed_cache_hits_complete_at_cache_latency():
    pipe = _timed_pipe()
    # warm outside the measured timeline (two passes clear admission)
    for _ in range(2):
        pipe.array.read(0, 32)
    pipe.precondition(())
    pipe.submit_read(0, 32, at=0.0)
    pipe.drain()
    warm_p50 = pipe.recorder.percentiles(op="R")["p50"]

    cold = _timed_pipe()
    cold.submit_read(0, 32, at=0.0)
    cold.drain()
    cold_p50 = cold.recorder.percentiles(op="R")["p50"]
    # a full-hit read completes at cache-device latency, well under NAND
    assert warm_p50 < cold_p50 / 2, (warm_p50, cold_p50)
    assert pipe.array.cache.stats.hits >= 32


def test_dispatcher_bypasses_cache_hits():
    from repro.service.dispatcher import BlockDeviceService
    from repro.service.qos import LATENCY

    pipe = _timed_pipe()
    for _ in range(2):
        pipe.array.read(10, 8)
    pipe.precondition(())
    svc = BlockDeviceService(pipe, max_inflight=1, policy="qos")
    svc.register("t", LATENCY)
    # resident run bypasses the queue even with the window saturated
    r_hit = svc.submit_read("t", 10, 8, at=0.0)
    r_miss = svc.submit_read("t", 100, 8, at=0.0)
    svc.drain()
    assert r_hit.ok() and r_miss.ok()
    assert r_hit.bypass and not r_miss.bypass
    assert svc.cache_bypasses == 1
    assert svc.summary()["cache_bypasses"] == 1
    assert r_hit.latency_us < r_miss.latency_us
    # bit-identity through the service path
    assert np.array_equal(r_hit.result, pipe.array.read(10, 8))


def test_degraded_read_cache_scenario_warm_beats_cold():
    """The acceptance figure: warm-cache degraded p99 at least 2x lower
    than cold for the same seeded stream (virtual time, deterministic)."""
    from repro.service.scenario import degraded_read_cache

    cold = degraded_read_cache(warm=False, n_ops=200)
    warm = degraded_read_cache(warm=True, n_ops=200)
    assert warm["hit_rate"] > cold["hit_rate"]
    assert warm["cache_bypasses"] > 0
    assert warm["p99_us"] * 2 <= cold["p99_us"], (warm, cold)
