PYTHONPATH := src
PY := PYTHONPATH=$(PYTHONPATH) python

.PHONY: test test-fast bench bench-quick bench-check serve-demo cache-demo obs-demo degraded-demo scrub-demo

# Tier-1 verify: the whole suite, stop on first failure.
test:
	$(PY) -m pytest -x -q

# Skip the slow system/checkpoint suites during iteration.
test-fast:
	$(PY) -m pytest -x -q --ignore=tests/test_system.py --ignore=tests/test_checkpoint.py

# Full benchmark sweep; writes BENCH_FULL.json (gitignored) next to the CSV.
bench:
	$(PY) -m benchmarks.run

# Cheap subset with small shapes for CI time budgets; rewrites the committed
# BENCH_PR10.json baseline (the quick set carries the perf acceptance figures).
bench-quick:
	$(PY) -m benchmarks.run --quick

# CI regression gate: rerun the quick set, fail on >25% wall-clock regression
# against the committed baseline (writes no JSON).
bench-check:
	$(PY) -m benchmarks.run --check BENCH_PR10.json

# Checkpoint-traffic-under-serving demo: many training jobs stream saves
# through the async block service while latency-class reads run alongside;
# prints the per-tenant QoS-vs-FIFO tail comparison.
serve-demo:
	$(PY) -m repro.launch.serve --storage-sim --policy both

# Warm-cache degraded-read demo: the ZNS cache tier absorbing the hot set
# after a drive failure; prints the warm-vs-cold p50/p99 comparison.
cache-demo:
	$(PY) examples/warm_cache_degraded.py

# Observability demo: checkpoint-under-serving with span tracing, the
# metrics sampler, and the SLO admission controller; writes a
# Perfetto-loadable out/trace.json plus out/metrics.json (schema-validated)
# and prints the static-vs-SLO serving-p99 comparison.
obs-demo:
	$(PY) examples/trace_and_metrics.py

# Always-writable degraded-array demo: fault injection kills a drive
# mid-write-stream, survivor-width stripe groups keep the array writable,
# and the paced rebuild re-widens them; prints the p50/p99 comparison and
# verifies the data round trip.
degraded-demo:
	$(PY) examples/degraded_writes.py

# End-to-end integrity demo: a probabilistic media-fault mix (bit rot,
# torn/misdirected writes, unreadable sectors) lands under a live write
# stream; the paced scrub actor detects every hit against the per-block
# CRC32C lane and repairs in place; writes out/scrub_metrics.json with
# nonzero integrity/blocks_repaired (asserted, and checked again by CI).
scrub-demo:
	$(PY) examples/scrub_repair.py
