"""Metrics registry + periodic sampler actor for the timed stack.

A :class:`MetricsRegistry` holds three instrument kinds:

* **counters** -- monotone totals (``inc``): committed stripes, GC blocks
  moved, cache hits, zone resets;
* **gauges** -- point-in-time levels (``set``): open zones per drive,
  staging-arena and cache occupancy, per-tenant queue depth, in-flight
  window usage, token-bucket levels, the GC escrow;
* **histograms** -- log2-bucketed distributions (``observe``): per-sample
  latencies the SLO monitor has already windowed.

The :class:`MetricsSampler` is an engine actor in the mold of the
pipeline's self-re-arming flush tick: every ``interval_us`` of *virtual*
time it runs its collector (a plain callable that reads simulator state
into the registry) and appends one row to ``series`` -- the time-series
JSON exported next to the ``BENCH_*`` rows.  It re-arms only while the
pipeline/service reports outstanding work, so an idle engine schedules no
events and a run's event count stays bounded.  Sampling is observe-only:
collectors read state, never book device time, so the virtual timeline is
bit-identical with and without a sampler attached.

:func:`standard_collector` wires the catalog the obs layer ships: zone
states per drive, arena/cache occupancy, per-tenant service levels,
GC/rebuild progress, token buckets, and the reserved-zone escrow.
"""
from __future__ import annotations

import json
import math
from collections import defaultdict
from typing import Callable, Optional

_HIST_BUCKETS = 24   # log2 buckets: [1, 2), [2, 4), ... us


class Histogram:
    """Power-of-two-bucketed value distribution (microseconds)."""

    def __init__(self, n_buckets: int = _HIST_BUCKETS):
        self.counts = [0] * n_buckets
        self.n = 0
        self.total = 0.0

    def observe(self, v: float) -> None:
        b = 0 if v < 1.0 else min(len(self.counts) - 1, int(math.log2(v)) + 1)
        self.counts[b] += 1
        self.n += 1
        self.total += v

    def snapshot(self) -> dict:
        return {"n": self.n, "total": self.total, "counts": list(self.counts)}


class MetricsRegistry:
    def __init__(self):
        self.counters: dict[str, float] = defaultdict(float)
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, Histogram] = {}

    def inc(self, name: str, v: float = 1.0) -> None:
        self.counters[name] += v

    def set(self, name: str, v: float) -> None:
        self.gauges[name] = float(v)

    def observe(self, name: str, v: float) -> None:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram()
        h.observe(v)

    def snapshot(self) -> dict:
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {k: h.snapshot() for k, h in self.histograms.items()},
        }

    def clear(self) -> None:
        self.counters.clear()
        self.gauges.clear()
        self.histograms.clear()


class MetricsSampler:
    """Self-re-arming engine actor recording one registry row per tick."""

    def __init__(
        self,
        engine,
        registry: MetricsRegistry,
        collect: Callable[[MetricsRegistry], None],
        *,
        interval_us: float = 50.0,
        busy_fn: Optional[Callable[[], bool]] = None,
        max_samples: int = 100_000,
    ):
        self.engine = engine
        self.registry = registry
        self.collect = collect
        self.interval_us = interval_us
        self.busy_fn = busy_fn
        self.max_samples = max_samples
        self.series: list[dict] = []
        self._armed = False
        self._stopped = False

    def start(self, at: float = 0.0) -> None:
        self._stopped = False
        if not self._armed:
            self._armed = True
            self.engine.at(max(at, self.engine.now), self._tick)

    def stop(self) -> None:
        self._stopped = True

    def sample_once(self) -> dict:
        """One collector pass + series row at the current virtual time."""
        self.collect(self.registry)
        row = {
            "t_us": self.engine.now,
            "counters": dict(self.registry.counters),
            "gauges": dict(self.registry.gauges),
        }
        if len(self.series) < self.max_samples:
            self.series.append(row)
        return row

    def _tick(self) -> None:
        self._armed = False
        if self._stopped:
            return
        self.sample_once()
        # Re-arm while the tracked workload (busy_fn) is live -- or, absent
        # a busy signal, while *anything else* is still scheduled: the
        # sampler then stops exactly when the simulation goes idle and
        # never keeps the engine alive on its own.
        busy = self.busy_fn() if self.busy_fn is not None else False
        if busy or self.engine.pending():
            self._armed = True
            self.engine.after(self.interval_us, self._tick)

    def clear(self) -> None:
        self.series.clear()
        self.registry.clear()

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({
                "interval_us": self.interval_us,
                "series": self.series,
                "histograms": {
                    k: h.snapshot() for k, h in self.registry.histograms.items()
                },
            }, f)
            f.write("\n")


def validate_metrics_series(doc: dict) -> None:
    """Schema check for an exported metrics time-series document."""
    assert isinstance(doc.get("series"), list), "missing series"
    t_prev = -math.inf
    prev_counters: dict[str, float] = {}
    for row in doc["series"]:
        assert isinstance(row.get("t_us"), (int, float)), row
        assert row["t_us"] >= t_prev, "time-series not monotone in t_us"
        t_prev = row["t_us"]
        assert isinstance(row.get("counters"), dict), row
        assert isinstance(row.get("gauges"), dict), row
        for k, v in row["counters"].items():
            assert v >= prev_counters.get(k, 0.0), f"counter {k} decreased"
        prev_counters.update(row["counters"])


def standard_collector(pipe, svc=None) -> Callable[[MetricsRegistry], None]:
    """The stock metric catalog over a timed pipeline (+ optional service).

    Samples, per tick: zone states and reset totals per drive, staging
    buffer and arena occupancy, cache occupancy/hit counters, GC and
    rebuild progress, the reserved-zone escrow level, and -- when a
    :class:`~repro.service.dispatcher.BlockDeviceService` is given --
    per-tenant queue depth, in-flight window usage, and token levels.
    """
    from repro.core.zns import ZoneState

    arr = pipe.array

    def collect(reg: MetricsRegistry) -> None:
        for d in arr.drives:
            p = f"drive{d.drive_id}"
            st = d.state
            reg.set(f"{p}/zones_empty", int((st == int(ZoneState.EMPTY)).sum()))
            reg.set(f"{p}/zones_open", int((st == int(ZoneState.OPEN)).sum()))
            reg.set(f"{p}/zones_full", int((st == int(ZoneState.FULL)).sum()))
            reg.set(f"{p}/zones_offline",
                    int((st == int(ZoneState.OFFLINE)).sum()))
            reg.counters[f"{p}/zone_resets"] = float(d.zone_resets)
            reg.counters[f"{p}/blocks_written"] = float(d.blocks_written)
            busy = getattr(d, "busy_us", None)
            if busy is not None:
                reg.counters[f"{p}/busy_us"] = max(
                    busy, reg.counters.get(f"{p}/busy_us", 0.0))
        reg.set("array/staged_blocks", len(arr._buffered))
        reg.set("array/open_segments", len(arr.open_segments))
        reg.set("array/free_segments", arr.free_segment_count())
        reg.set("array/gc_reserved_zones", arr.reserved_zones())
        reg.counters["array/stripes_committed"] = float(
            arr.stats.stripes_committed)
        reg.counters["array/gc_runs"] = float(arr.stats.gc_runs)
        reg.counters["array/gc_blocks_moved"] = float(arr.stats.gc_blocks_moved)
        reg.set("array/rebuild_pending_zones", len(arr._rebuild_pending))
        # end-to-end integrity: detections/repairs are monotone counters,
        # the media-fault total comes from the drives' own hooks so a CI
        # gate can assert injected == detected after a scrub pass
        reg.counters["integrity/corruptions_detected"] = float(
            arr.stats.integrity_corruptions_detected)
        reg.counters["integrity/unreadable_hits"] = float(
            arr.stats.integrity_unreadable_hits)
        reg.counters["integrity/blocks_repaired"] = float(
            arr.stats.integrity_blocks_repaired)
        reg.counters["integrity/scrub_passes"] = float(
            arr.stats.integrity_scrub_passes)
        reg.counters["integrity/scrub_blocks"] = float(
            arr.stats.integrity_scrub_blocks)
        # max-folded like busy_us: a drive replacement mid-run must not
        # make the fleet-wide total step backwards
        reg.counters["integrity/media_faults_injected"] = max(
            float(sum(d.media_faults for d in arr.drives)),
            reg.counters.get("integrity/media_faults_injected", 0.0))
        # 1.0 while any member drive is failed: SLO monitors and dashboards
        # can separate degraded-width commits from healthy-path latency
        reg.set("array/degraded_mode",
                float(any(d.failed for d in arr.drives)))
        cache = arr.cache
        if cache is not None:
            reg.set("cache/resident_blocks", cache.resident_count())
            reg.counters["cache/hits"] = float(cache.stats.hits)
            reg.counters["cache/misses"] = float(cache.stats.misses)
            reg.counters["cache/zone_resets"] = float(cache.stats.zone_resets)
        if svc is not None:
            now = svc.engine.now
            reg.set("service/inflight", svc.inflight)
            reg.set("service/window", svc.max_inflight)
            for name, ten in svc.tenants.items():
                tp = f"tenant/{name}"
                reg.set(f"{tp}/queue_depth", ten.queue_depth())
                reg.set(f"{tp}/inflight", ten.inflight)
                reg.counters[f"{tp}/completed"] = float(ten.completed)
                reg.counters[f"{tp}/rejected"] = float(ten.rejected)
                if ten.bucket is not None:
                    reg.set(f"{tp}/tokens", ten.bucket.peek(now))
            for cls, n in svc._class_inflight.items():
                reg.set(f"class/{cls}/inflight", n)
                cap = svc.class_caps.get(cls)
                if cap is not None:
                    reg.set(f"class/{cls}/cap", cap)

    return collect
