"""SLO monitor: windowed-p99 observation driving dynamic admission.

ROADMAP item 5's last gap: the QoS dispatcher's per-class in-flight
shares are static (frozen into :class:`~repro.service.qos.QosClass`), so
a checkpoint burst sized for the average case still inflates the serving
tenant's tail when drives are slow, GC runs, or a drive is down.  The
monitor closes the loop:

* every ``interval_us`` of virtual time it computes the observed p99 of
  the protected tenant over the trailing ``window_us`` of completions
  (:meth:`repro.sim.stats.LatencyRecorder.windowed_percentiles` -- the
  shared, empty-safe helper);
* if that p99 drifts past ``objective_p99_us``, it *halves* the target
  class's effective in-flight cap (``BlockDeviceService.class_caps``, a
  dispatcher-level override of the frozen class default) down to
  ``floor``;
* once the observed p99 sits back under ``restore_frac * objective``,
  the cap is restored one slot per tick -- multiplicative decrease,
  additive increase, the classic congestion-control shape, so recovery
  is fast and re-admission is gentle.

The monitor is an observe-and-actuate engine actor: it reads the sample
stream (never books device time) and writes exactly one knob.  With no
monitor constructed, ``class_caps`` stays empty and the dispatcher's
behavior is bit-identical to the static policy.
"""
from __future__ import annotations

import math
from typing import Optional


class SloMonitor:
    """Windowed-p99 feedback controller over a ``BlockDeviceService``."""

    def __init__(
        self,
        service,
        tenant: str,
        objective_p99_us: float,
        *,
        klass: str = "ckpt",
        op: str = "R",
        window_us: float = 2_000.0,
        interval_us: float = 500.0,
        min_samples: int = 12,
        floor: int = 1,
        restore_frac: float = 0.7,
        registry=None,
    ):
        self.service = service
        self.engine = service.engine
        self.tenant = tenant
        self.objective_p99_us = objective_p99_us
        self.klass = klass
        self.op = op
        self.window_us = window_us
        self.interval_us = interval_us
        self.min_samples = min_samples
        self.floor = max(1, floor)
        self.restore_frac = restore_frac
        self.registry = registry
        self.default_cap: Optional[int] = None   # resolved at first tick
        self.history: list[dict] = []     # one row per tick
        self.actions: list[dict] = []     # one row per cap change
        self._armed = False
        self._stopped = False

    # -- lifecycle ----------------------------------------------------------

    def start(self, at: float = 0.0) -> None:
        self._stopped = False
        if not self._armed:
            self._armed = True
            self.engine.at(max(at, self.engine.now), self._tick)

    def stop(self) -> None:
        self._stopped = True

    # -- controller ---------------------------------------------------------

    def _resolve_default_cap(self) -> int:
        if self.default_cap is None:
            for ten in self.service.tenants.values():
                if ten.qos.name == self.klass:
                    self.default_cap = ten.qos.max_inflight or \
                        self.service.max_inflight
                    break
            else:
                self.default_cap = self.service.max_inflight
        return self.default_cap

    def current_cap(self) -> int:
        return self.service.class_caps.get(self.klass,
                                           self._resolve_default_cap())

    def _set_cap(self, new: int, p99: float, n: int) -> None:
        self.service.class_caps[self.klass] = new
        self.actions.append({
            "t_us": self.engine.now, "cap": new, "p99_us": p99, "n": n,
        })
        # a freed/shrunk window changes who is eligible right now
        self.service._pump()

    def _tick(self) -> None:
        self._armed = False
        if self._stopped:
            return
        now = self.engine.now
        pct = self.service.recorder.windowed_percentiles(
            now - self.window_us, now, op=self.op, tenant=self.tenant
        )
        cap = self.current_cap()
        default = self._resolve_default_cap()
        p99 = pct["p99"]
        if pct["n"] >= self.min_samples and not math.isnan(p99):
            if p99 > self.objective_p99_us and cap > self.floor:
                self._set_cap(max(self.floor, cap // 2), p99, pct["n"])
            elif p99 < self.restore_frac * self.objective_p99_us \
                    and cap < default:
                self._set_cap(cap + 1, p99, pct["n"])
        self.history.append({
            "t_us": now, "n": pct["n"], "p99_us": p99,
            "cap": self.current_cap(),
        })
        if self.registry is not None:
            self.registry.set(f"slo/{self.tenant}/window_p99_us",
                              0.0 if math.isnan(p99) else p99)
            self.registry.set(f"slo/{self.klass}/cap", self.current_cap())
            if not math.isnan(p99):
                self.registry.observe(f"slo/{self.tenant}/p99_us", p99)
        if self.service._live > 0:
            self._armed = True
            self.engine.after(self.interval_us, self._tick)

    # -- reporting ----------------------------------------------------------

    def summary(self) -> dict:
        caps = [a["cap"] for a in self.actions]
        return {
            "objective_p99_us": self.objective_p99_us,
            "default_cap": self._resolve_default_cap(),
            "final_cap": self.current_cap(),
            "min_cap": min(caps) if caps else self._resolve_default_cap(),
            "n_shrinks": sum(
                1 for a, b in zip([self._resolve_default_cap()] + caps, caps)
                if b < a
            ),
            "n_restores": sum(
                1 for a, b in zip([self._resolve_default_cap()] + caps, caps)
                if b > a
            ),
            "ticks": len(self.history),
        }
