"""Span tracing on the virtual clock (Chrome/Perfetto ``trace_event`` JSON).

The timed stack already *knows* every interval a request spends somewhere
-- submission-queue wait, QoS dispatch, cache probe, group commit barrier,
per-drive channel service, degraded decode -- because those intervals are
exactly the bookings the discrete-event engine computes.  The tracer turns
them into a `trace_event`_ JSON file a tail request can be opened in
(``chrome://tracing`` or https://ui.perfetto.dev).

Two span families map onto the two shapes the format offers:

* **request-scoped spans** -- one async-nestable track per
  :class:`~repro.service.request.IoRequest` (``ph: "b"/"e"/"n"`` events
  keyed by the request's service-wide ``seq``).  Requests overlap freely
  in virtual time, so they cannot share a synchronous thread track;
  async ids give every request its own nested lane
  (``io.request`` > ``sq.wait`` / ``device.service``, with
  ``qos.dispatch`` / ``cache.bypass`` / ``admission.reject`` instants).
* **resource-scoped spans** -- complete events (``ph: "X"``) on named
  tracks (``drive0``..``driveN``, ``cache-dev``, ``array``): Zone
  Write / Zone Append / read channel service, commit-barrier waits, GC
  and rebuild passes, degraded decodes.  Tracks are materialized as
  threads of one synthetic process; export greedily packs overlapping
  spans of a track into lanes (``drive0``, ``drive0 #1``, ...) so the
  viewer never renders mis-nested slices.

Timestamps are the engine's virtual microseconds verbatim -- the
``trace_event`` ``ts`` unit -- so the viewer's ruler *is* the simulated
timeline.  The tracer is observe-only: it never books device time, never
touches the engine, and every hook site guards on ``tracer is None``
(the default), so tracing-off runs execute the exact same instruction
stream as before the hooks existed.
"""
from __future__ import annotations

import json
from typing import Optional

TRACE_PID = 1
_LANES_PER_TRACK = 64   # tid stride reserved per resource track


class Tracer:
    """Collects virtual-time spans; exports Chrome ``trace_event`` JSON."""

    def __init__(self, engine=None, *, max_events: int = 500_000):
        self.engine = engine
        self.max_events = max_events
        self.events: list[dict] = []   # resource X-spans + request async events
        self.dropped = 0
        self._tracks: dict[str, int] = {}   # track name -> base tid

    # -- recording ----------------------------------------------------------

    def _room(self) -> bool:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return False
        return True

    def _track_tid(self, track: str) -> int:
        tid = self._tracks.get(track)
        if tid is None:
            tid = len(self._tracks) * _LANES_PER_TRACK
            self._tracks[track] = tid
        return tid

    def span(self, track: str, name: str, t0: float, t1: float,
             cat: str = "device", **args) -> None:
        """Record a completed span ``[t0, t1]`` on a resource track."""
        if not self._room():
            return
        self.events.append({
            "name": name, "cat": cat, "ph": "X",
            "ts": float(t0), "dur": max(0.0, float(t1) - float(t0)),
            "pid": TRACE_PID, "tid": self._track_tid(track),
            "args": args,
        })

    def instant(self, track: str, name: str, t: float,
                cat: str = "mark", **args) -> None:
        if not self._room():
            return
        self.events.append({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": float(t), "pid": TRACE_PID,
            "tid": self._track_tid(track), "args": args,
        })

    # request-scoped async-nestable spans (id = IoRequest.seq)

    def _req(self, ph: str, rid: int, name: str, t: float, args: dict) -> None:
        if not self._room():
            return
        self.events.append({
            "name": name, "cat": "request", "ph": ph,
            "ts": float(t), "pid": TRACE_PID, "tid": 0,
            "id": f"req{rid}", "args": args,
        })

    def req_begin(self, rid: int, name: str, t: float, **args) -> None:
        self._req("b", rid, name, t, args)

    def req_end(self, rid: int, name: str, t: float, **args) -> None:
        self._req("e", rid, name, t, args)

    def req_instant(self, rid: int, name: str, t: float, **args) -> None:
        self._req("n", rid, name, t, args)

    def clear(self) -> None:
        """Discard everything recorded so far (see ``precondition``)."""
        self.events.clear()
        self.dropped = 0

    # -- export -------------------------------------------------------------

    def _packed_lanes(self) -> tuple[list[dict], dict[int, str]]:
        """Assign overlapping X-spans of each track to disjoint lanes.

        Returns the event list with lane-adjusted tids plus the tid ->
        display-name map for the thread_name metadata records."""
        names: dict[int, str] = {}
        by_track: dict[int, list[dict]] = {}
        out: list[dict] = []
        for ev in self.events:
            if ev["ph"] == "X":
                by_track.setdefault(ev["tid"], []).append(ev)
            else:
                out.append(ev)
        track_of = {tid: name for name, tid in self._tracks.items()}
        for base, spans in by_track.items():
            spans.sort(key=lambda e: (e["ts"], -e["dur"]))
            lane_free: list[float] = []
            for ev in spans:
                for lane, t_free in enumerate(lane_free):
                    if ev["ts"] >= t_free - 1e-9:
                        break
                else:
                    lane = len(lane_free)
                    lane_free.append(0.0)
                lane = min(lane, _LANES_PER_TRACK - 1)
                lane_free[lane] = ev["ts"] + ev["dur"]
                ev = dict(ev, tid=base + lane)
                tname = track_of.get(base, f"track{base}")
                names[ev["tid"]] = tname if lane == 0 else f"{tname} #{lane}"
                out.append(ev)
        return out, names

    def to_trace_events(self) -> list[dict]:
        """The full ``traceEvents`` list, metadata records included."""
        events, names = self._packed_lanes()
        meta = [{
            "name": "process_name", "ph": "M", "pid": TRACE_PID, "tid": 0,
            "ts": 0.0, "args": {"name": "zapraid-sim"},
        }]
        for tid in sorted(names):
            meta.append({
                "name": "thread_name", "ph": "M", "pid": TRACE_PID,
                "tid": tid, "ts": 0.0, "args": {"name": names[tid]},
            })
        events.sort(key=lambda e: (e["ts"], e["tid"]))
        return meta + events

    def export(self, path: str) -> dict:
        """Write Perfetto-loadable JSON; returns summary counters."""
        events = self.to_trace_events()
        with open(path, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
            f.write("\n")
        return {"events": len(events), "dropped": self.dropped}


def validate_trace_events(events: list[dict]) -> None:
    """Schema check for an exported ``traceEvents`` list.

    Raises ``AssertionError`` on the first malformed record: every event
    needs name/ph/pid/ts, complete events need a non-negative ``dur``,
    async begin/end events must balance per (id, name) with begin <= end
    and children strictly nested inside their ``io.request`` root.
    """
    open_stack: dict[str, list[tuple[str, float]]] = {}
    for ev in events:
        assert isinstance(ev.get("name"), str) and ev["name"], ev
        assert ev.get("ph") in ("X", "B", "E", "b", "e", "n", "i", "M"), ev
        assert isinstance(ev.get("pid"), int), ev
        assert isinstance(ev.get("ts"), (int, float)), ev
        if ev["ph"] == "X":
            assert isinstance(ev.get("dur"), (int, float)) and ev["dur"] >= 0, ev
        if ev["ph"] in ("b", "e", "n"):
            assert isinstance(ev.get("id"), str) and ev["id"], ev
        if ev["ph"] == "b":
            open_stack.setdefault(ev["id"], []).append((ev["name"], ev["ts"]))
        elif ev["ph"] == "e":
            stack = open_stack.get(ev["id"])
            assert stack, f"async end without begin: {ev}"
            name, t0 = stack.pop()
            assert name == ev["name"], f"mis-nested async spans: {ev} vs {name}"
            assert ev["ts"] >= t0, f"span ends before it begins: {ev}"
    leftovers = {k: v for k, v in open_stack.items() if v}
    assert not leftovers, f"unclosed async spans: {leftovers}"
