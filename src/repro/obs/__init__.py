"""Observability for the timed ZapRAID stack (DESIGN.md §13).

Three parts, all observe-only on the virtual clock:

* :mod:`repro.obs.trace` -- span tracing with a Chrome/Perfetto
  ``trace_event`` JSON exporter (request-scoped async spans + resource
  tracks for drives/cache/array);
* :mod:`repro.obs.metrics` -- counters/gauges/histograms plus the
  periodic :class:`MetricsSampler` actor and the stock
  :func:`standard_collector` catalog;
* :mod:`repro.obs.slo` -- the windowed-p99 :class:`SloMonitor` driving
  dynamic per-class admission through
  ``BlockDeviceService.class_caps``.

Every hook site in the stack guards on ``tracer is None`` /
``obs_event is None`` (the defaults), so with nothing attached the
timed and untimed datapaths execute bit-identically to a build without
this package.
"""
from repro.obs.metrics import (
    Histogram,
    MetricsRegistry,
    MetricsSampler,
    standard_collector,
    validate_metrics_series,
)
from repro.obs.slo import SloMonitor
from repro.obs.trace import Tracer, validate_trace_events

__all__ = [
    "Histogram",
    "MetricsRegistry",
    "MetricsSampler",
    "SloMonitor",
    "Tracer",
    "standard_collector",
    "validate_metrics_series",
    "validate_trace_events",
]
