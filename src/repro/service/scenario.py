"""Canned multi-tenant scenarios over the block service.

Two scenarios shared by the benchmarks, the examples, and the serving
launcher (imported lazily by callers -- this module drags in the
checkpoint/jax stack):

* :func:`read_qd_sweep` -- closed-loop read throughput vs offered queue
  depth: the saturation curve of the ZNS array (channel parallelism fills
  up, then the curve flattens);
* :func:`checkpoint_under_serving` -- the ML-cell workload: many simulated
  training jobs stream erasure-coded checkpoint saves through the service
  as throughput-class tenants while latency-class serving reads run
  alongside.  Run it once with ``policy="qos"`` and once with
  ``policy="fifo"`` to measure what admission control buys the serving
  tenant's tail.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.checkpoint.zapraid_ckpt import (
    MANIFEST_LBAS,
    CheckpointConfig,
    CheckpointEngine,
)
from repro.core.handlers import HandlerPipeline
from repro.service.dispatcher import BlockDeviceService, ClosedLoopClient
from repro.service.qos import LATENCY, QosClass
from repro.sim.workload import TenantSpec, synthetic


def _precondition_region(pipe, lo: int, n_blocks: int, *, seed: int,
                         extent: int = 256) -> None:
    """Install valid media under ``[lo, lo + n_blocks)`` outside the
    measured timeline, so read traffic hits mapped, reconstructable data."""
    bb = pipe.array.zns_cfg.block_bytes
    rng = np.random.default_rng(seed)

    def gen():
        lba = lo
        end = lo + n_blocks
        while lba < end:
            n = min(extent, end - lba)
            yield lba, rng.integers(0, 256, (n, bb), dtype=np.uint8)
            lba += n

    pipe.precondition(gen())


def read_qd_sweep(
    qds=(1, 2, 4, 8, 16, 32),
    *,
    n_ops: int = 192,
    logical_blocks: int = 4096,
    seed: int = 0,
    obs: bool = False,
) -> list[dict]:
    """Closed-loop single-tenant read sweep; one fresh array per depth.

    Returns one row per queue depth: ``{"qd", "virtual_iops",
    "p50_us", "p99_us"}`` -- virtual-time figures, deterministic for a
    given seed.  With ``obs=True`` the full observability stack (span
    tracer on every layer + metrics sampler actor) rides along; the
    virtual-time figures must be identical either way, which is exactly
    what the ``obs/trace_overhead`` benchmark rows assert."""
    cfg = CheckpointConfig(zone_cap_blocks=2048, n_zones=32)
    rows = []
    for qd in qds:
        pipe = HandlerPipeline.build_timed(
            cfg.zap_cfg(logical_blocks), cfg.zns_cfg(), seed=seed,
            flush_interval_us=200.0,
        )
        _precondition_region(pipe, 0, logical_blocks, seed=seed + 1)
        svc = BlockDeviceService(pipe, max_inflight=max(64, qd), policy="fifo")
        if obs:
            from repro.obs import (
                MetricsRegistry, MetricsSampler, standard_collector,
            )
            svc.tracer = pipe.attach_obs()
            sampler = MetricsSampler(
                pipe.engine, MetricsRegistry(),
                standard_collector(pipe, svc),
                interval_us=50.0, busy_fn=lambda s=svc: s._live > 0,
            )
            sampler.start(0.0)
        svc.register("sweep", QosClass("sweep"))
        reqs = synthetic(
            TenantSpec(name="sweep", kind="uniform", n_ops=n_ops,
                       read_frac=1.0, arrival="closed", window=qd, seed=seed),
            logical_blocks,
        )
        client = ClosedLoopClient(svc, "sweep", reqs, window=qd)
        client.start(0.0)
        svc.drain()
        assert client.done() and client.rejected == 0
        span = svc.recorder.span_us()
        pct = svc.recorder.percentiles(op="R")
        rows.append({
            "qd": qd,
            "virtual_iops": n_ops / span * 1e6 if span > 0 else 0.0,
            "p50_us": pct["p50"],
            "p99_us": pct["p99"],
        })
    return rows


def degraded_read_cache(
    *,
    warm: bool = True,
    kind: str = "hotspot",
    n_ops: int = 600,
    rate_iops: float = 60_000.0,
    logical_blocks: int = 2048,
    failed_drive: int = 1,
    cache_zones: int = 8,
    cache_zone_blocks: int = 32,
    burst_factor: float = 1.0,
    max_inflight: int = 8,
    seed: int = 0,
) -> dict:
    """Latency-class reads against a one-drive-down array, with the ZNS
    cache tier warm (hot set resident before the failure) or cold.

    Cold, every read landing on the failed drive fans out into k survivor
    reads and the drive channels saturate; warm, the cache absorbs the hot
    set at cache-device latency and the residual misses see idle drives --
    the warm-vs-cold p99 gap is the figure the cache tier is for.  The same
    seeded address stream is measured in both modes, so the two rows differ
    only in cache state.  Returns virtual-time percentiles plus hit-rate
    and bypass counters."""
    from repro.cache import CacheConfig, ZnsCacheTier

    cfg = CheckpointConfig(zone_cap_blocks=2048, n_zones=32)
    pipe = HandlerPipeline.build_timed(
        cfg.zap_cfg(logical_blocks), cfg.zns_cfg(), seed=seed,
        flush_interval_us=200.0,
    )
    cache = ZnsCacheTier(
        CacheConfig(n_zones=cache_zones, zone_cap_blocks=cache_zone_blocks,
                    block_bytes=cfg.block_bytes),
        logical_blocks,
    )
    pipe.attach_cache(cache)
    _precondition_region(pipe, 0, logical_blocks, seed=seed + 1)

    reqs = synthetic(
        TenantSpec(name="serve", kind=kind, n_ops=n_ops,
                   rate_iops=rate_iops, read_frac=1.0,
                   burst_factor=burst_factor, seed=seed),
        logical_blocks,
    )
    if warm:
        # replay the address stream functionally (outside the measured
        # timeline) twice: the second pass clears the admission sketch's
        # touch threshold for every block of the working set
        for _ in range(2):
            for r in reqs:
                pipe.array.read(r.lba, r.n_blocks)
        # discard warm-up timing/stats; the cache *contents* survive
        pipe.precondition(())

    pipe.array.fail_drive(failed_drive)
    svc = BlockDeviceService(pipe, max_inflight=max_inflight, policy="qos")
    svc.register("serve", LATENCY)
    for r in reqs:
        svc.submit_read("serve", r.lba, r.n_blocks, at=r.t_us)
    svc.drain()
    pct = svc.recorder.percentiles(op="R", tenant="serve")
    return {
        "warm": warm,
        "kind": kind,
        "p50_us": pct["p50"],
        "p99_us": pct["p99"],
        "n": pct["n"],
        "hit_rate": cache.stats.hit_rate(),
        "cache_bypasses": svc.cache_bypasses,
        # tier-level counters cover the measured window only (warm-up stats
        # are discarded by precondition)
        "cache_hits": int(cache.stats.hits),
        "cache_misses": int(cache.stats.misses),
    }


def checkpoint_under_serving(
    *,
    policy: str = "qos",
    n_jobs: int = 4,
    n_saves: int = 2,
    ckpt_interval_us: float = 2_000.0,
    leaf_blocks: int = 4,
    n_leaves: int = 12,
    serve_ops: int = 500,
    serve_rate_iops: float = 40_000.0,
    max_inflight: int = 8,
    seed: int = 0,
    restore_check: bool = True,
    slo_objective_us: Optional[float] = None,
    slo_kwargs: Optional[dict] = None,
    tracer=None,
    sampler_interval_us: Optional[float] = None,
) -> dict:
    """Checkpoint traffic at scale under latency-sensitive serving.

    ``n_jobs`` training jobs share one timed array, each confined to its
    own LBA window, and stream ``n_saves`` erasure-coded checkpoints
    through the service as throughput-class tenants (class-wide in-flight
    cap = half the window, so checkpoint bursts can never occupy every
    dispatcher slot).  Meanwhile an open-loop Poisson stream of
    latency-class reads models serving traffic against a preconditioned
    region.  Returns per-tenant latency/figures plus the save tickets'
    resolution times; with ``restore_check`` the last checkpoint of job 0
    is also restored through the service and verified bit-identical.

    Observability options (repro.obs): ``slo_objective_us`` arms an
    :class:`~repro.obs.SloMonitor` protecting the serving tenant's p99 by
    dynamically shrinking/restoring the checkpoint class's in-flight share
    (result gains an ``"slo"`` summary); ``tracer`` threads a span tracer
    through every layer; ``sampler_interval_us`` attaches a metrics
    sampler (result gains ``"metrics_series"``).
    """
    cfg = CheckpointConfig(zone_cap_blocks=2048, n_zones=32)
    serve_blocks = 1024
    job_span = MANIFEST_LBAS + 512
    logical_blocks = serve_blocks + n_jobs * job_span

    pipe = HandlerPipeline.build_timed(
        cfg.zap_cfg(logical_blocks), cfg.zns_cfg(), seed=seed,
        flush_interval_us=200.0,
    )
    engine = pipe.engine
    _precondition_region(pipe, 0, serve_blocks, seed=seed + 7)

    svc = BlockDeviceService(pipe, max_inflight=max_inflight, policy=policy)
    monitor = sampler = None
    registry = None
    if tracer is not None:
        pipe.attach_obs(tracer)
        svc.tracer = tracer
    if slo_objective_us is not None or sampler_interval_us is not None:
        from repro.obs import MetricsRegistry
        registry = MetricsRegistry()
    if sampler_interval_us is not None:
        from repro.obs import MetricsSampler, standard_collector
        sampler = MetricsSampler(
            engine, registry, standard_collector(pipe, svc),
            interval_us=sampler_interval_us,
            busy_fn=lambda: svc._live > 0,
        )
        sampler.start(0.0)
    if slo_objective_us is not None:
        from repro.obs import SloMonitor
        monitor = SloMonitor(
            svc, "serve", slo_objective_us, klass="ckpt",
            registry=registry, **(slo_kwargs or {}),
        )
        monitor.start(0.0)
    svc.register("serve", LATENCY)
    ckpt_qos = QosClass("ckpt", priority=2, max_inflight=max(2, max_inflight // 2))
    engines = []
    for j in range(n_jobs):
        svc.register(f"job{j}", ckpt_qos)
        engines.append(CheckpointEngine(
            cfg, logical_blocks, array=pipe.array,
            lba_base=serve_blocks + j * job_span, lba_span=job_span,
        ))

    # training state per job: a few leaves, each ``leaf_blocks`` blocks
    rng = np.random.default_rng(seed + 11)
    n_f32 = leaf_blocks * cfg.block_bytes // 4
    states = [
        {f"layer{i}": rng.standard_normal(n_f32).astype(np.float32)
         for i in range(n_leaves)}
        for _ in range(n_jobs)
    ]

    # serving traffic: open-loop latency-class reads
    for r in synthetic(
        TenantSpec(name="serve", kind="hotspot", n_ops=serve_ops,
                   rate_iops=serve_rate_iops, read_frac=1.0, seed=seed),
        serve_blocks,
    ):
        svc.submit_read("serve", r.lba, r.n_blocks, at=r.t_us)

    # checkpoint traffic: every job saves on a fixed cadence (staggered)
    tickets = []
    for j in range(n_jobs):
        for i in range(n_saves):
            t = 100.0 + j * (ckpt_interval_us / n_jobs) + i * ckpt_interval_us
            engine.at(t, lambda j=j, i=i: tickets.append(
                engines[j].save_async(i, states[j], service=svc,
                                      tenant=f"job{j}")
            ))
    svc.drain()
    assert len(tickets) == n_jobs * n_saves
    assert all(t.done for t in tickets)

    restore_ok = None
    if restore_check:
        rt = engines[0].restore_async(
            n_saves - 1, states[0], service=svc, tenant="job0"
        )
        svc.drain()
        assert rt.done
        restore_ok = all(
            np.array_equal(np.asarray(rt.state[k]), states[0][k])
            for k in states[0]
        )

    serve = svc.recorder.percentiles(op="R", tenant="serve")
    saves = np.array([t.latency_us for t in tickets])
    out = {
        "policy": policy,
        "serve_p50_us": serve["p50"],
        "serve_p99_us": serve["p99"],
        "serve_n": serve["n"],
        "ckpt_save_mean_us": float(saves.mean()),
        "ckpt_save_max_us": float(saves.max()),
        "restore_ok": restore_ok,
        "summary": svc.summary(),
    }
    if monitor is not None:
        out["slo"] = monitor.summary()
        out["slo_actions"] = monitor.actions
    if sampler is not None:
        out["metrics_series"] = sampler.series
        out["sampler"] = sampler
    return out
