"""QoS classes and token-bucket admission for the block service.

A :class:`QosClass` bundles everything the dispatcher needs to treat a
tenant's traffic differently from its neighbours':

* ``priority``     -- strict inter-class dispatch order (0 is served first:
  latency-sensitive serve reads preempt throughput-oriented checkpoint
  writes at every dispatch decision);
* ``deadline_us``  -- optional earliest-deadline-first reordering *within*
  a priority level (requests carry ``t_submit + deadline_us`` as their EDF
  key; classes without a deadline fall back to arrival order);
* ``rate_iops``/``burst`` -- per-tenant token bucket: a tenant with an
  empty bucket is simply not eligible for dispatch until it refills, which
  shapes its throughput without dropping requests;
* ``queue_cap``    -- per-tenant submission-queue depth cap; arrivals past
  it are rejected at admission (the NVMe "queue full" path) so an
  open-loop aggressor cannot grow unbounded host-side state;
* ``max_inflight`` -- per-class cap on in-flight requests, carving the
  dispatcher's global window so one class can never occupy every slot.

Two canned classes cover the common split; scenarios are free to define
their own.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class QosClass:
    name: str
    priority: int = 1            # 0 = served first (strict priority)
    deadline_us: float = math.inf  # relative deadline; EDF within the class
    rate_iops: float = 0.0       # 0 => no token bucket
    burst: int = 16              # bucket depth (requests)
    queue_cap: int = 1024        # per-tenant submission-queue depth cap
    max_inflight: int = 0        # 0 => no per-class in-flight cap


# latency-sensitive foreground traffic (e.g. serving reads)
LATENCY = QosClass("latency", priority=0, deadline_us=1_500.0)
# throughput-oriented background streams (e.g. checkpoint writes)
THROUGHPUT = QosClass("throughput", priority=2)


class TokenBucket:
    """Classic token bucket on the virtual clock (tokens = requests)."""

    def __init__(self, rate_iops: float, burst: int, t0: float = 0.0):
        assert rate_iops > 0
        self.rate = rate_iops / 1e6          # tokens per virtual microsecond
        self.burst = float(max(1, burst))
        self.tokens = self.burst             # starts full
        self.t_last = t0

    def _refill(self, now: float) -> None:
        if now > self.t_last:
            self.tokens = min(self.burst, self.tokens + (now - self.t_last) * self.rate)
            self.t_last = now

    def peek(self, now: float) -> float:
        """Tokens available at ``now`` (no consumption)."""
        self._refill(now)
        return self.tokens

    def take(self, now: float) -> bool:
        """Consume one token if available."""
        self._refill(now)
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False

    def next_ready(self, now: float) -> float:
        """Earliest virtual time at which a full token will exist."""
        self._refill(now)
        if self.tokens >= 1.0:
            return now
        return now + (1.0 - self.tokens) / self.rate
