"""Async block-device service front end over the timed engine (PR 6).

Layers:

* :mod:`repro.service.request`    -- ``IoRequest`` futures + the shared
  :class:`CompletionQueue` (the ``zns_raid_write/read(..., cb_fn, args)``
  surface of the real system);
* :mod:`repro.service.qos`        -- QoS classes (strict priority, EDF
  deadlines, token-bucket shaping, queue-depth caps) and admission state;
* :mod:`repro.service.dispatcher` -- per-tenant submission queues and the
  dispatcher actor enforcing the in-flight window and the QoS policy,
  plus :class:`ClosedLoopClient` for fixed-window (queue-depth sweep)
  load generation;
* :mod:`repro.service.scenario`   -- canned multi-tenant scenarios
  (checkpoint-traffic-under-serving, read QD sweeps) shared by the
  benchmarks, examples, and ``repro.launch.serve`` (imported lazily --
  pulling the scenario module drags in the checkpoint/jax stack).

Acks fire at the device-completion times the discrete-event engine
computes, never at Python-call return; see DESIGN.md §11.
"""
from repro.service.dispatcher import BlockDeviceService, ClosedLoopClient, Tenant
from repro.service.qos import LATENCY, THROUGHPUT, QosClass, TokenBucket
from repro.service.request import (
    DONE,
    INFLIGHT,
    QUEUED,
    REJECTED,
    CompletionQueue,
    IoRequest,
)

__all__ = [
    "BlockDeviceService",
    "ClosedLoopClient",
    "CompletionQueue",
    "DONE",
    "INFLIGHT",
    "IoRequest",
    "LATENCY",
    "QUEUED",
    "QosClass",
    "REJECTED",
    "THROUGHPUT",
    "Tenant",
    "TokenBucket",
]
