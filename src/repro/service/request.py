"""Request and completion-queue objects for the async block-device front end.

The real ZapRAID is exposed as a user-space block device with a
completion-callback API (``zns_raid_write/read(..., cb_fn, args)``); this
module is that surface for the simulator.  An :class:`IoRequest` doubles as
the future: it is returned synchronously from ``submit_*``, carries the
callback, and is filled in (status, timestamps, read payload) by the
dispatcher when the device-completion event fires on the virtual timeline.

A single shared :class:`CompletionQueue` collects every finished request in
completion order -- including admission rejections, which complete with
``status == "rejected"`` like an NVMe error completion -- so an application
can poll/drain it exactly like a CQ instead of (or in addition to) taking
callbacks.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Any, Callable, Optional

import numpy as np

QUEUED = "queued"        # admitted, waiting in the tenant's submission queue
INFLIGHT = "inflight"    # dispatched onto the array, device time booking
DONE = "done"            # ack fired at the device-completion time
REJECTED = "rejected"    # admission control refused it (queue-depth cap)


@dataclasses.dataclass
class IoRequest:
    """One block-device command with future/callback semantics."""

    tenant: str
    op: str                                   # "R" | "W"
    lba: int
    n_blocks: int = 1
    data: Optional[np.ndarray] = None         # write payload (n_blocks, bb)
    cb_fn: Optional[Callable[["IoRequest"], None]] = None
    seq: int = -1                             # service-wide submission order
    t_submit: float = math.nan                # arrival at the service
    t_dispatch: float = math.nan              # pulled onto the array
    t_done: float = math.nan                  # device completion (+host cost)
    deadline: float = math.inf                # absolute; EDF key within class
    status: str = QUEUED
    result: Any = None                        # read payload once DONE
    bypass: bool = False                      # served via the cache tier fast
                                              # path, outside the QoS window
    trace_id: int = -1                        # async-span id in the obs
                                              # tracer (-1: not traced)

    def done(self) -> bool:
        return self.status in (DONE, REJECTED)

    def ok(self) -> bool:
        return self.status == DONE

    @property
    def queue_wait_us(self) -> float:
        return self.t_dispatch - self.t_submit

    @property
    def service_us(self) -> float:
        return self.t_done - self.t_dispatch

    @property
    def latency_us(self) -> float:
        return self.t_done - self.t_submit


class CompletionQueue:
    """Shared completion ring fed by the dispatcher in completion order."""

    def __init__(self):
        self._q: collections.deque[IoRequest] = collections.deque()
        self.pushed = 0

    def push(self, req: IoRequest) -> None:
        self._q.append(req)
        self.pushed += 1

    def drain(self) -> list[IoRequest]:
        """Pop everything currently completed (like reaping a CQ)."""
        out = list(self._q)
        self._q.clear()
        return out

    def __len__(self) -> int:
        return len(self._q)
