"""Async block-device service: per-tenant submission queues + dispatcher.

This is the front end the real ZapRAID exposes to applications -- an async
block device with completion callbacks -- layered over the timed
:class:`repro.core.handlers.HandlerPipeline`:

* **submission queues** -- one FIFO per tenant.  ``submit_write/read``
  return an :class:`IoRequest` future immediately; the request *arrives*
  (enters its queue, or is rejected by admission control) at its arrival
  instant on the virtual clock.
* **dispatcher actor** -- pulls requests from the submission queues onto
  the array, never holding more than ``max_inflight`` outstanding (the
  device queue depth being modelled).  Under ``policy="qos"`` the next
  request is chosen by strict class priority, then earliest deadline, then
  arrival order; ``policy="fifo"`` ignores classes entirely (global arrival
  order) and exists as the baseline QoS is measured against.  Tenants whose
  token bucket is empty are ineligible until it refills; the dispatcher
  schedules its own wake-up at the earliest refill instant so shaping does
  not depend on unrelated traffic to make progress.
* **completion queue** -- acks fire at the device-completion times the
  timed engine computes (PR 3), *not* at Python-call return: the pipeline
  resolves a write when its stripe's slowest chunk lands and a read at its
  device time, and the service then stamps ``t_done``, fires ``cb_fn``, and
  pushes the request onto the shared :class:`CompletionQueue`.
* **stats** -- every completion records into a :class:`LatencyRecorder`
  with a per-tenant ``queue_wait_us`` (arrival -> dispatch, the admission/
  scheduling delay) vs ``service_us`` (dispatch -> ack, the device) split.

The service registers itself as the pipeline's ``busy_hook`` so the
timeout-flush tick keeps running while work exists only in submission
queues -- a drained queue must still pad+commit partially filled stripes
(see ``HandlerPipeline.ensure_flush_ticks``).
"""
from __future__ import annotations

import math
from collections import deque
from typing import Optional

import numpy as np

from repro.service.qos import THROUGHPUT, QosClass, TokenBucket
from repro.service.request import (
    DONE,
    INFLIGHT,
    QUEUED,
    REJECTED,
    CompletionQueue,
    IoRequest,
)


class Tenant:
    """Per-tenant service state: submission queue, shaping, counters."""

    def __init__(self, name: str, qos: QosClass, t0: float = 0.0):
        self.name = name
        self.qos = qos
        self.queue: deque[IoRequest] = deque()
        self.bucket = (
            TokenBucket(qos.rate_iops, qos.burst, t0) if qos.rate_iops > 0 else None
        )
        self.inflight = 0
        self.accepted = 0
        self.rejected = 0
        self.completed = 0

    def queue_depth(self) -> int:
        return len(self.queue)

    def outstanding(self) -> int:
        return len(self.queue) + self.inflight


class BlockDeviceService:
    """Submission/completion-queue block-device facade over a timed pipeline."""

    def __init__(
        self,
        pipe,
        *,
        max_inflight: int = 32,
        policy: str = "qos",
        recorder=None,
        cache_bypass: bool = True,
    ):
        assert pipe.engine is not None, "the service requires a timed pipeline"
        assert policy in ("qos", "fifo"), policy
        self.pipe = pipe
        self.engine = pipe.engine
        self.policy = policy
        self.max_inflight = max_inflight
        # Reads fully resident in the array's cache tier skip the submission
        # queue and the in-flight window: a cache hit needs no device queue
        # slot, so latency-class tenants see hits without queueing behind
        # checkpoint traffic.  Only active when a cache is attached.
        self.cache_bypass = cache_bypass
        self.cache_bypasses = 0
        self.tenants: dict[str, Tenant] = {}
        # Dynamic per-class in-flight overrides (repro.obs.SloMonitor): the
        # dispatcher consults this before the frozen QosClass default, so an
        # SLO controller can shrink/restore a class's share at runtime.
        # Empty by default -- static QoS behavior is untouched.
        self.class_caps: dict[str, int] = {}
        # Optional span tracer (repro.obs.Tracer); None = zero-cost no-op.
        self.tracer = None
        self.cq = CompletionQueue()
        if recorder is None:
            from repro.sim.stats import LatencyRecorder
            recorder = LatencyRecorder()
        self.recorder = recorder
        self.inflight = 0
        self._class_inflight: dict[str, int] = {}
        self._live = 0          # scheduled arrivals + queued + inflight
        self._seq = 0
        self._wake_at = math.inf
        # flush ticks must outlive the pipeline's own idle detection while
        # the service still holds queued or scheduled work
        pipe.busy_hook = lambda: self._live > 0

    # -- tenants -------------------------------------------------------------

    def register(self, name: str, qos: QosClass = THROUGHPUT) -> Tenant:
        assert name not in self.tenants, f"tenant {name!r} already registered"
        ten = Tenant(name, qos, self.engine.now)
        self.tenants[name] = ten
        self._class_inflight.setdefault(qos.name, 0)
        return ten

    # -- submission (the zns_raid_write/read surface) ------------------------

    def submit_write(self, tenant: str, lba: int, data: np.ndarray, *,
                     at: Optional[float] = None, cb=None) -> IoRequest:
        data = np.asarray(data, dtype=np.uint8)
        if data.ndim == 1:
            data = data.reshape(1, -1)
        req = IoRequest(tenant=tenant, op="W", lba=lba,
                        n_blocks=data.shape[0], data=data, cb_fn=cb)
        return self._submit(req, at)

    def submit_read(self, tenant: str, lba: int, n_blocks: int = 1, *,
                    at: Optional[float] = None, cb=None) -> IoRequest:
        req = IoRequest(tenant=tenant, op="R", lba=lba,
                        n_blocks=n_blocks, cb_fn=cb)
        return self._submit(req, at)

    def _submit(self, req: IoRequest, at: Optional[float]) -> IoRequest:
        assert req.tenant in self.tenants, f"unknown tenant {req.tenant!r}"
        t = self.engine.now if at is None else max(at, self.engine.now)
        req.seq = self._seq
        self._seq += 1
        self._live += 1
        self.pipe.ensure_flush_ticks()
        self.engine.at(t, self._ev_arrive, req)
        return req

    # -- events --------------------------------------------------------------

    def _ev_arrive(self, req: IoRequest) -> None:
        ten = self.tenants[req.tenant]
        req.t_submit = self.engine.now
        req.deadline = req.t_submit + ten.qos.deadline_us
        tr = self.tracer
        if tr is not None:
            req.trace_id = req.seq
            tr.req_begin(req.trace_id, "io.request", req.t_submit,
                         tenant=req.tenant, op=req.op, lba=req.lba,
                         n_blocks=req.n_blocks, qos=ten.qos.name)
        if ten.outstanding() >= ten.qos.queue_cap:
            # NVMe queue-full: reject at admission, complete with an error
            req.status = REJECTED
            ten.rejected += 1
            self._live -= 1
            if tr is not None:
                tr.req_instant(req.trace_id, "admission.reject", req.t_submit,
                               queue_cap=ten.qos.queue_cap)
                tr.req_end(req.trace_id, "io.request", req.t_submit,
                           status=REJECTED)
            self.cq.push(req)
            if req.cb_fn:
                req.cb_fn(req)
            return
        cache = self.pipe.array.cache if self.cache_bypass else None
        if (
            req.op == "R"
            and cache is not None
            and cache.contains_run(req.lba, req.n_blocks)
        ):
            # full cache hit: dispatch immediately, outside the window
            ten.accepted += 1
            req.bypass = True
            self.cache_bypasses += 1
            if tr is not None:
                tr.req_instant(req.trace_id, "cache.bypass", req.t_submit)
            self._dispatch(req)
            return
        ten.accepted += 1
        if tr is not None:
            tr.req_begin(req.trace_id, "sq.wait", req.t_submit)
        ten.queue.append(req)
        self._pump()

    def _ev_wake(self) -> None:
        self._wake_at = math.inf
        self._pump()

    def _pump(self) -> None:
        """Dispatch until the window is full or nothing is eligible."""
        now = self.engine.now
        while self.inflight < self.max_inflight:
            req = self._pop_next(now)
            if req is None:
                break
            self._dispatch(req)
        self._arm_token_wake(now)

    def _eligible(self, ten: Tenant, now: float) -> bool:
        if not ten.queue:
            return False
        if self.policy == "qos":
            cap = self.class_caps.get(ten.qos.name, ten.qos.max_inflight)
            if cap and self._class_inflight[ten.qos.name] >= cap:
                return False
        if ten.bucket is not None and ten.bucket.peek(now) < 1.0:
            return False
        return True

    def _pop_next(self, now: float) -> Optional[IoRequest]:
        best: Optional[Tenant] = None
        best_key = None
        for ten in self.tenants.values():
            if not self._eligible(ten, now):
                continue
            head = ten.queue[0]
            if self.policy == "fifo":
                key = (head.t_submit, head.seq)
            else:
                key = (ten.qos.priority, head.deadline, head.t_submit, head.seq)
            if best_key is None or key < best_key:
                best, best_key = ten, key
        if best is None:
            return None
        if best.bucket is not None:
            best.bucket.take(now)
        return best.queue.popleft()

    def _dispatch(self, req: IoRequest) -> None:
        ten = self.tenants[req.tenant]
        req.status = INFLIGHT
        req.t_dispatch = self.engine.now
        if not req.bypass:  # cache-hit reads don't hold a window slot
            ten.inflight += 1
            self.inflight += 1
            self._class_inflight[ten.qos.name] += 1
        tr = self.tracer
        if tr is not None:
            t = req.t_dispatch
            if not req.bypass:
                tr.req_end(req.trace_id, "sq.wait", t)
                tr.req_instant(req.trace_id, "qos.dispatch", t,
                               klass=ten.qos.name,
                               class_inflight=self._class_inflight[ten.qos.name],
                               inflight=self.inflight, window=self.max_inflight)
            tr.req_begin(req.trace_id, "device.service", t)
        if req.op == "W":
            self.pipe.submit_write(
                req.lba, req.data, tenant=req.tenant,
                cb=lambda _t_ack, r=req: self._ev_complete(r, None),
            )
        else:
            self.pipe.submit_read(
                req.lba, req.n_blocks, tenant=req.tenant,
                cb=lambda out, r=req: self._ev_complete(r, out),
            )

    def _ev_complete(self, req: IoRequest, result) -> None:
        ten = self.tenants[req.tenant]
        req.status = DONE
        req.t_done = self.engine.now
        req.result = result
        if not req.bypass:
            ten.inflight -= 1
            self.inflight -= 1
            self._class_inflight[ten.qos.name] -= 1
        ten.completed += 1
        self._live -= 1
        tr = self.tracer
        if tr is not None:
            tr.req_end(req.trace_id, "device.service", req.t_done)
            tr.req_end(req.trace_id, "io.request", req.t_done,
                       latency_us=req.latency_us, status=DONE)
        self.recorder.record(
            req.tenant, req.op, req.t_submit, req.t_done,
            stages={"queue_wait_us": req.queue_wait_us,
                    "service_us": req.service_us},
        )
        self.cq.push(req)
        if req.cb_fn:
            req.cb_fn(req)
        self._pump()

    def _arm_token_wake(self, now: float) -> None:
        """If dispatch is blocked only by empty token buckets, self-schedule
        a pump at the earliest refill so shaping makes progress on its own."""
        if self.inflight >= self.max_inflight:
            return  # a completion will pump
        t_next = math.inf
        for ten in self.tenants.values():
            if not ten.queue or ten.bucket is None:
                continue
            if self.policy == "qos":
                cap = self.class_caps.get(ten.qos.name, ten.qos.max_inflight)
                if cap and self._class_inflight[ten.qos.name] >= cap:
                    continue
            t_next = min(t_next, ten.bucket.next_ready(now))
        if t_next < self._wake_at and t_next < math.inf and t_next > now:
            self._wake_at = t_next
            self.engine.at(t_next, self._ev_wake)

    # -- draining / stats ----------------------------------------------------

    def drain(self) -> None:
        """Run the engine until every submitted request has completed."""
        self.pipe.drain()
        assert self._live == 0, "service drain left live requests"

    def summary(self) -> dict:
        return {
            "policy": self.policy,
            "max_inflight": self.max_inflight,
            "cache_bypasses": self.cache_bypasses,
            "tenants": {
                name: {
                    "qos": ten.qos.name,
                    "accepted": ten.accepted,
                    "rejected": ten.rejected,
                    "completed": ten.completed,
                }
                for name, ten in sorted(self.tenants.items())
            },
            "latency": self.recorder.summary(),
        }


class ClosedLoopClient:
    """Fixed-outstanding-window load driver (closed-loop arrival mode).

    Consumes a :mod:`repro.sim.workload` request list (arrival timestamps
    ignored -- generate with ``TenantSpec(arrival="closed")``), keeps at
    most ``window`` requests outstanding, and submits the next op
    ``think_time_us`` after each completion.  This is how queue-depth
    sweeps are expressed: the window *is* the offered queue depth, and
    throughput as a function of it is the ZNS saturation curve.

    Rejected submissions (possible when the tenant's ``queue_cap`` is below
    the window) count as completions so the loop always terminates.
    """

    def __init__(self, service: BlockDeviceService, tenant: str, requests, *,
                 window: int = 4, think_time_us: float = 0.0,
                 payload_fn=None, seed: int = 0xC10):
        self.service = service
        self.tenant = tenant
        self.reqs = list(requests)
        self.window = max(1, window)
        self.think_time_us = think_time_us
        self._payload_fn = payload_fn
        self._rng = np.random.default_rng(seed)
        self._bb = service.pipe.array.zns_cfg.block_bytes
        self._next = 0
        self.completed = 0
        self.rejected = 0

    def start(self, at: float = 0.0) -> None:
        self.service.engine.at(at, self._ev_start)

    def _ev_start(self) -> None:
        for _ in range(min(self.window, len(self.reqs))):
            self._issue()

    def _payload(self, r) -> np.ndarray:
        if self._payload_fn is not None:
            return self._payload_fn(r)
        return self._rng.integers(0, 256, (r.n_blocks, self._bb), dtype=np.uint8)

    def _issue(self) -> None:
        r = self.reqs[self._next]
        self._next += 1
        if r.op == "W":
            self.service.submit_write(self.tenant, r.lba, self._payload(r),
                                      cb=self._on_done)
        else:
            self.service.submit_read(self.tenant, r.lba, r.n_blocks,
                                     cb=self._on_done)

    def _on_done(self, req: IoRequest) -> None:
        if req.status == REJECTED:
            self.rejected += 1
        self.completed += 1
        if self._next < len(self.reqs):
            if self.think_time_us > 0:
                self.service.engine.after(self.think_time_us, self._issue)
            else:
                self._issue()

    def done(self) -> bool:
        return self.completed == len(self.reqs)
