"""On-device erasure coding of sharded training state across DP ranks.

Beyond-paper application of ZapRAID's stripe encoding to live training
state: the k optimizer-state shards held by k data-parallel failure domains
are treated as the data chunks of a stripe, and m parity shards are computed
on-device with the same Pallas kernels (XOR for m=1, GF(256) RS for m=2).
If a DP rank dies, its optimizer shard is reconstructed from the surviving
k-1 shards + parity *without* any re-upload from checkpoint storage -- the
in-memory analogue of the paper's full-drive recovery.

All functions operate on byte-views of pytree leaves, so any dtype works.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _leaf_to_lanes(leaf: np.ndarray) -> jnp.ndarray:
    raw = np.asarray(leaf).tobytes()
    pad = (-len(raw)) % 4
    raw += b"\x00" * pad
    return ops.pack_bytes(jnp.asarray(np.frombuffer(raw, np.uint8)))


def _lanes_to_leaf(lanes: jnp.ndarray, dtype, shape, nbytes: int) -> np.ndarray:
    raw = np.asarray(ops.unpack_bytes(lanes)).tobytes()[:nbytes]
    return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()


def encode_shards(shards: list, m: int = 1, *, use_pallas: bool = True,
                  interpret: bool = True) -> list:
    """Compute m parity pytrees over k rank-shard pytrees (leafwise)."""
    k = len(shards)
    flat = [jax.tree.leaves(s) for s in shards]
    treedef = jax.tree.structure(shards[0])
    parity_leaves: list[list] = [[] for _ in range(m)]
    for leaves in zip(*flat):
        lanes = jnp.stack([_leaf_to_lanes(l) for l in leaves])
        if m == 1:
            p = ops.xor_parity(lanes, use_pallas=use_pallas, interpret=interpret)
            p = p[None]
        else:
            p = ops.rs_encode(lanes, m, use_pallas=use_pallas, interpret=interpret)
        ref = np.asarray(leaves[0])
        for j in range(m):
            parity_leaves[j].append(
                _lanes_to_leaf(p[j], np.uint8, (ref.nbytes + (-ref.nbytes) % 4,),
                               ref.nbytes + (-ref.nbytes) % 4)
            )
    return [jax.tree.unflatten(treedef, pl) for pl in parity_leaves]


def reconstruct_shard(
    lost_rank: int,
    surviving: dict[int, object],
    parity: list,
    k: int,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
):
    """Rebuild rank ``lost_rank``'s shard pytree from k-1 survivors + parity."""
    m = len(parity)
    template = next(iter(surviving.values()))
    treedef = jax.tree.structure(template)
    surv_flat = {r: jax.tree.leaves(s) for r, s in surviving.items()}
    par_flat = [jax.tree.leaves(p) for p in parity]
    out_leaves = []
    t_leaves = jax.tree.leaves(template)
    for i, t in enumerate(t_leaves):
        rows, roles = [], []
        for r, leaves in surv_flat.items():
            rows.append(_leaf_to_lanes(leaves[i]))
            roles.append(r)
        for j in range(m):
            if len(rows) >= k:
                break
            rows.append(_leaf_to_lanes(par_flat[j][i]))
            roles.append(k + j)
        lanes = jnp.stack(rows[:k])
        roles = tuple(roles[:k])
        if m == 1:
            rec = ops.xor_parity(lanes, use_pallas=use_pallas, interpret=interpret)
        else:
            data = ops.rs_decode(lanes, roles, k, m,
                                 use_pallas=use_pallas, interpret=interpret)
            rec = data[lost_rank]
        ref = np.asarray(t)
        out_leaves.append(_lanes_to_leaf(rec, ref.dtype, ref.shape, ref.nbytes))
    return jax.tree.unflatten(treedef, out_leaves)
