"""ZapRAID-backed checkpoint engine.

The paper's log-structured RAID becomes the trainer's checkpoint substrate:

* every training-state leaf is serialized into 4 KiB blocks and streamed
  through a ``ZapRAIDArray`` whose *drives* model independent storage lanes
  (one per failure domain -- a host, a pod's NVMe set, ...);
* checkpoints are erasure-coded (RAID-5/6) across lanes at write time by the
  Pallas XOR/GF(256) kernels, so losing up to m lanes still restores --
  ``restore`` transparently takes the degraded-read path of §3.5;
* checkpoints are *log-structured*: a new save appends; old checkpoints
  become stale blocks reclaimed by the array's GC -- exactly the paper's
  workload;
* Zone-Append group commits let the k+m lane writers complete out of order
  inside each stripe group (the paper's §3.2 insight), with the compact
  stripe table absorbing the disorder -- the checkpoint writer never issues
  a cross-lane barrier except at group boundaries;
* a small manifest (step -> leaf extents) is kept in memory and serialized
  into the log itself under reserved LBAs, so ``CheckpointEngine.attach``
  can mount an existing array after a crash (crash consistency inherited
  from §3.4 recovery).
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core.array import ZapRaidConfig, ZapRAIDArray
from repro.core.recovery import recover_array
from repro.core.zns import ZnsConfig

MANIFEST_LBAS = 64  # reserved logical region for the manifest


@dataclasses.dataclass
class CheckpointConfig:
    n_lanes: int = 4
    scheme: str = "raid5"
    group_size: int = 16
    chunk_blocks: int = 4
    block_bytes: int = 4096
    zone_cap_blocks: int = 4096
    n_zones: int = 64
    keep_last: int = 2
    # datapath: the jnp oracle (use_pallas=False) is the fast path on CPU
    # (jitted XLA); interpret-mode Pallas is for kernel validation and runs
    # the kernel body in Python -- orders of magnitude slower for bulk
    # rebuild loops.  On real TPUs set use_pallas=True, interpret=False.
    use_pallas: bool = False
    interpret: bool = True

    def zap_cfg(self, logical_blocks: int) -> ZapRaidConfig:
        return ZapRaidConfig(
            scheme=self.scheme,
            n_drives=self.n_lanes,
            group_size=self.group_size,
            chunk_blocks=self.chunk_blocks,
            logical_blocks=logical_blocks,
            gc_free_segments_low=2,
            use_pallas=self.use_pallas,
            interpret=self.interpret,
        )

    def zns_cfg(self) -> ZnsConfig:
        return ZnsConfig(
            n_zones=self.n_zones,
            zone_cap_blocks=self.zone_cap_blocks,
            block_bytes=self.block_bytes,
        )


def _flatten_state(state) -> tuple[list[tuple[str, np.ndarray]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    out = []
    for path, leaf in flat:
        out.append((jax.tree_util.keystr(path), np.asarray(leaf)))
    return out, treedef


@dataclasses.dataclass
class SaveTicket:
    """Future for an async (service-tier) checkpoint save."""

    step: int
    t_issue: float
    manifest: dict
    n_extents: int
    done: bool = False
    t_done: float = math.nan
    cb: Optional[Callable[["SaveTicket"], None]] = None

    @property
    def latency_us(self) -> float:
        return self.t_done - self.t_issue


@dataclasses.dataclass
class RestoreTicket:
    """Future for an async (service-tier) checkpoint restore."""

    step: int
    t_issue: float
    n_extents: int
    done: bool = False
    t_done: float = math.nan
    state: Any = None
    cb: Optional[Callable[["RestoreTicket"], None]] = None

    @property
    def latency_us(self) -> float:
        return self.t_done - self.t_issue


class CheckpointEngine:
    def __init__(
        self,
        cfg: CheckpointConfig,
        logical_blocks: int = 1 << 14,
        *,
        array: Optional[ZapRAIDArray] = None,
        lba_base: int = 0,
        lba_span: Optional[int] = None,
    ):
        """``array`` lets many engines share one volume (e.g. the timed
        array behind a block service), each confined to its own logical
        window ``[lba_base, lba_base + lba_span)`` with its manifest at
        ``lba_base`` -- the many-training-jobs layout."""
        self.cfg = cfg
        self.logical_blocks = logical_blocks
        self.array = array if array is not None else ZapRAIDArray(
            cfg.zap_cfg(logical_blocks), cfg.zns_cfg()
        )
        self.lba_base = lba_base
        self.lba_span = logical_blocks - lba_base if lba_span is None else lba_span
        assert self.lba_span > MANIFEST_LBAS, "window too small for a manifest"
        assert self.lba_base + self.lba_span <= logical_blocks
        self.catalog: dict[int, dict] = {}  # step -> manifest
        self._alloc_ptr = lba_base + MANIFEST_LBAS  # bump allocator, ring
        self.saves = 0

    @classmethod
    def build_timed(
        cls,
        cfg: CheckpointConfig,
        logical_blocks: int = 1 << 14,
        *,
        seed: int = 0,
        flush_interval_us: float = 1000.0,
        **engine_kw,
    ):
        """Checkpoint engine over a discrete-event timed pipeline.

        Returns ``(ckpt, pipe)``; wrap ``pipe`` in a
        :class:`repro.service.BlockDeviceService` and use
        :meth:`save_async`/:meth:`restore_async` to stream checkpoints as
        admission-controlled tenant traffic."""
        from repro.core.handlers import HandlerPipeline

        pipe = HandlerPipeline.build_timed(
            cfg.zap_cfg(logical_blocks), cfg.zns_cfg(), seed=seed,
            flush_interval_us=flush_interval_us, **engine_kw,
        )
        return cls(cfg, logical_blocks, array=pipe.array), pipe

    # ------------------------------------------------------------- space

    def _alloc(self, n_blocks: int) -> int:
        lo = self.lba_base + MANIFEST_LBAS
        hi = self.lba_base + self.lba_span
        if self._alloc_ptr + n_blocks > hi:
            self._alloc_ptr = lo  # wrap: old extents become stale
        lba = self._alloc_ptr
        self._alloc_ptr += n_blocks
        return lba

    # ------------------------------------------------------------- save

    def _ensure_lanes(self) -> None:
        """Hot-spare semantics: *writes* require all lanes, so a failed lane
        is rebuilt (replacement drive + §3.5 full-drive recovery) before a
        save.  *Reads* never need this -- restore() runs degraded."""
        for i, d in enumerate(self.array.drives):
            if d.failed:
                self.array.rebuild_drive(i)

    def _stage_save(self, step: int, state) -> tuple[dict, list[tuple[int, np.ndarray]]]:
        """Serialize ``state`` into block extents: allocation + packing,
        shared by the sync and async save paths."""
        bb = self.cfg.block_bytes
        leaves, _ = _flatten_state(state)
        manifest = {"step": step, "leaves": {}}
        extents: list[tuple[int, np.ndarray]] = []
        for name, arr in leaves:
            raw = arr.tobytes()
            n_blocks = max(1, -(-len(raw) // bb))
            lba = self._alloc(n_blocks)
            buf = np.zeros((n_blocks, bb), np.uint8)
            flat = np.frombuffer(raw, np.uint8)
            buf.reshape(-1)[: flat.size] = flat
            extents.append((lba, buf))
            manifest["leaves"][name] = {
                "lba": lba,
                "n_blocks": n_blocks,
                "nbytes": len(raw),
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
            }
        return manifest, extents

    def save(self, step: int, state) -> dict:
        """Append a checkpoint for ``step``; returns its manifest."""
        self._ensure_lanes()
        manifest, extents = self._stage_save(step, state)
        for lba, buf in extents:
            self.array.write(lba, buf)
        self.array.flush()
        self.catalog[step] = manifest
        self._write_manifest()
        self.saves += 1
        self._retire_old()
        return manifest

    def _manifest_blocks(self) -> np.ndarray:
        bb = self.cfg.block_bytes
        blob = json.dumps(self.catalog).encode()
        n_blocks = -(-len(blob) // (bb - 8))
        assert n_blocks <= MANIFEST_LBAS, "manifest too large for reserved region"
        buf = np.zeros((n_blocks, bb), np.uint8)
        header = np.frombuffer(
            np.int64(len(blob)).tobytes() , np.uint8
        )
        flat = np.frombuffer(blob, np.uint8)
        buf[0, :8] = header
        rest = buf.reshape(-1)[8:]
        rest[: flat.size] = flat
        return buf

    def _write_manifest(self) -> None:
        self.array.write(self.lba_base, self._manifest_blocks())
        self.array.flush()

    # ------------------------------------------------- async (service tier)

    def save_async(self, step: int, state, *, service, tenant: str = "ckpt",
                   at: Optional[float] = None, cb=None) -> SaveTicket:
        """Stream a checkpoint through a block service as tenant traffic.

        One write request per leaf extent enters the tenant's submission
        queue (subject to its QoS class: token bucket, queue cap, in-flight
        share); the manifest is submitted only after *every* extent has
        acked, preserving the crash-ordering invariant of the sync path
        (a manifest never points at unwritten extents).  The returned
        ticket resolves at the manifest's device-completion time.

        Unlike :meth:`save`, failed lanes are not rebuilt inline -- in the
        timed world a rebuild is an engine actor
        (``HandlerPipeline.schedule_rebuild``), not a synchronous call."""
        manifest, extents = self._stage_save(step, state)
        self.catalog[step] = manifest
        self.saves += 1
        self._retire_old()
        mblocks = self._manifest_blocks()
        ticket = SaveTicket(
            step=step,
            t_issue=service.engine.now if at is None else at,
            manifest=manifest, n_extents=len(extents), cb=cb,
        )
        remaining = [len(extents)]

        def manifest_done(req) -> None:
            ticket.done = True
            ticket.t_done = req.t_done
            if ticket.cb:
                ticket.cb(ticket)

        def leaf_done(_req) -> None:
            remaining[0] -= 1
            if remaining[0] == 0:
                service.submit_write(tenant, self.lba_base, mblocks,
                                     cb=manifest_done)

        if not extents:
            service.submit_write(tenant, self.lba_base, mblocks, at=at,
                                 cb=manifest_done)
        for lba, buf in extents:
            service.submit_write(tenant, lba, buf, at=at, cb=leaf_done)
        return ticket

    def restore_async(self, step: int, like, *, service, tenant: str = "ckpt",
                      at: Optional[float] = None, cb=None) -> RestoreTicket:
        """Async restore: one read request per leaf extent; the ticket
        resolves (with ``.state`` holding the rebuilt pytree) when the last
        read acks.  Degraded lanes restore transparently -- the reads take
        the array's reconstruction path and simply book more device time."""
        manifest = self.catalog.get(step)
        if manifest is None:
            raise KeyError(f"no checkpoint for step {step}")
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        entries = [manifest["leaves"][jax.tree_util.keystr(p)] for p, _ in flat]
        results: list[Optional[np.ndarray]] = [None] * len(entries)
        ticket = RestoreTicket(
            step=step,
            t_issue=service.engine.now if at is None else at,
            n_extents=len(entries), cb=cb,
        )
        remaining = [len(entries)]

        def leaf_done(idx: int, ent: dict, req) -> None:
            raw = req.result.reshape(-1)[: ent["nbytes"]].tobytes()
            results[idx] = np.frombuffer(raw, dtype=np.dtype(ent["dtype"])).reshape(
                ent["shape"]
            ).copy()
            remaining[0] -= 1
            if remaining[0] == 0:
                ticket.state = jax.tree.unflatten(treedef, results)
                ticket.done = True
                ticket.t_done = req.t_done
                if ticket.cb:
                    ticket.cb(ticket)

        for idx, ent in enumerate(entries):
            service.submit_read(
                tenant, ent["lba"], ent["n_blocks"], at=at,
                cb=lambda req, i=idx, e=ent: leaf_done(i, e, req),
            )
        return ticket

    def _retire_old(self) -> None:
        steps = sorted(self.catalog)
        for s in steps[: -self.cfg.keep_last]:
            del self.catalog[s]
        # stale extents are reclaimed lazily by array GC on overwrite

    # ------------------------------------------------------------ restore

    def restore(self, step: int, like) -> Any:
        """Rebuild the state pytree for ``step`` (``like`` supplies the tree
        structure).  Works identically with failed lanes (degraded reads)."""
        manifest = self.catalog.get(step)
        if manifest is None:
            raise KeyError(f"no checkpoint for step {step}")
        bb = self.cfg.block_bytes
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        out = []
        for path, leaf in flat:
            name = jax.tree_util.keystr(path)
            ent = manifest["leaves"][name]
            blocks = self.array.read(ent["lba"], ent["n_blocks"])
            raw = blocks.reshape(-1)[: ent["nbytes"]].tobytes()
            arr = np.frombuffer(raw, dtype=np.dtype(ent["dtype"])).reshape(
                ent["shape"]
            )
            out.append(arr.copy())
        return jax.tree.unflatten(treedef, out)

    # -------------------------------------------------------- fault paths

    def fail_lane(self, lane: int) -> None:
        self.array.fail_drive(lane)

    def rebuild_lane(self, lane: int) -> None:
        self.array.rebuild_drive(lane)

    def crash_and_remount(self) -> "CheckpointEngine":
        """Simulate a host crash: recover the array from the drives and
        re-read the manifest from the log."""
        drives = self.array.drives
        new = CheckpointEngine.__new__(CheckpointEngine)
        new.cfg = self.cfg
        new.logical_blocks = self.logical_blocks
        new.array = recover_array(
            drives, self.cfg.zap_cfg(self.logical_blocks), self.cfg.zns_cfg()
        )
        new.lba_base = self.lba_base
        new.lba_span = self.lba_span
        new.catalog = {}
        new._alloc_ptr = self.lba_base + MANIFEST_LBAS
        new.saves = 0
        new._load_manifest()
        return new

    def _load_manifest(self) -> None:
        bb = self.cfg.block_bytes
        first = self.array.read(self.lba_base, 1)
        size = int(np.frombuffer(first[0, :8].tobytes(), np.int64)[0])
        if size <= 0 or size > MANIFEST_LBAS * bb:
            return  # no manifest yet
        n_blocks = -(-(size + 8) // bb)
        blocks = self.array.read(self.lba_base, n_blocks)
        blob = blocks.reshape(-1)[8 : 8 + size].tobytes()
        raw = json.loads(blob)
        self.catalog = {int(k): v for k, v in raw.items()}
        if self.catalog:
            last = max(
                e["lba"] + e["n_blocks"]
                for m in self.catalog.values()
                for e in m["leaves"].values()
            )
            self._alloc_ptr = max(self.lba_base + MANIFEST_LBAS, last)

    # ------------------------------------------------------------- stats

    def stats(self) -> dict:
        s = self.array.stats
        return {
            "saves": self.saves,
            "device_blocks_written": s.device_blocks_written,
            "write_amp": s.write_amp(),
            "gc_runs": s.gc_runs,
            "degraded_reads": s.degraded_reads,
        }
