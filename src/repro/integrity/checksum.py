"""Vectorized CRC32C (Castagnoli) over fixed-size blocks.

The write path stages payloads in int32-packed arenas
(``core.array._StripeArena``), so the checksum primitive must digest a
whole ``(N, block_bytes)`` uint8 view in one numpy pass -- no per-block
Python loops, no byte-at-a-time state machine on the hot path.

CRC is GF(2)-affine in the message, which makes a *per-position table*
formulation possible: for a fixed block length ``L`` there is a table
``postable[pos][byte]`` (the raw CRC contribution of ``byte`` at
position ``pos`` in an otherwise-zero message) and a constant folding
the ``0xFFFFFFFF`` init/xorout through ``L`` zero bytes, such that

    crc(M) = const(L)  XOR  XOR_{pos} postable[pos, M[pos]]

The whole batch then reduces to one fancy-indexed gather plus an XOR
reduction -- a shape (map + reduce over independent lanes) that ports
directly to a Pallas kernel if the arenas ever move on-device.  Tables
are built once per distinct block length and cached (1 KiB per
position: 4 MiB for 4 KiB blocks).

The same primitive digests arbitrary-length byte strings through the
classic byte-loop (:func:`crc32c`) for header/footer metadata, and the
two agree: ``crc32c(block.tobytes()) == crc32c_many(block[None])[0]``.
"""
from __future__ import annotations

import numpy as np

__all__ = ["CRC_BYTES", "crc32c", "crc32c_many", "crc32c_pack", "verify_many"]

CRC_BYTES = 4  # stored checksum width (uint32, little-endian when packed)

_POLY = np.uint32(0x82F63B78)  # CRC-32C (Castagnoli), reflected


def _base_table() -> np.ndarray:
    t = np.arange(256, dtype=np.uint32)
    for _ in range(8):
        t = np.where(t & 1, (t >> 1) ^ _POLY, t >> 1).astype(np.uint32)
    return t


_TABLE = _base_table()

# Per-length cache: block length -> (postable (L, 256) uint32, const uint32)
_POS_CACHE: dict[int, tuple[np.ndarray, int]] = {}

# Positions digested per gather chunk; bounds the (N, chunk) uint32
# scratch so huge batches never materialize an N*L temp.
_CHUNK = 1024


def _pos_tables(length: int) -> tuple[np.ndarray, int]:
    cached = _POS_CACHE.get(length)
    if cached is not None:
        return cached
    post = np.empty((length, 256), dtype=np.uint32)
    post[length - 1] = _TABLE
    for pos in range(length - 2, -1, -1):
        s = post[pos + 1]
        post[pos] = (s >> 8) ^ _TABLE[s & 0xFF]
    # Fold init=0xFFFFFFFF through `length` zero bytes, plus the xorout.
    c = 0xFFFFFFFF
    for _ in range(length):
        c = (c >> 8) ^ int(_TABLE[c & 0xFF])
    const = c ^ 0xFFFFFFFF
    _POS_CACHE[length] = (post, const)
    return post, const


def crc32c(data: bytes | bytearray | memoryview | np.ndarray) -> int:
    """Scalar CRC32C of an arbitrary-length byte string."""
    buf = np.frombuffer(memoryview(data), dtype=np.uint8) \
        if not isinstance(data, np.ndarray) else data.reshape(-1)
    crc = 0xFFFFFFFF
    for b in buf.tobytes():
        crc = (crc >> 8) ^ int(_TABLE[(crc ^ b) & 0xFF])
    return crc ^ 0xFFFFFFFF


def crc32c_many(blocks: np.ndarray) -> np.ndarray:
    """CRC32C of each row: ``(N, L) uint8 -> (N,) uint32``.

    Accepts any 2-D array whose rows are the messages; int32-packed
    arena rows digest zero-copy via a uint8 view.
    """
    if blocks.dtype != np.uint8:
        blocks = np.ascontiguousarray(blocks).view(np.uint8)
    if blocks.ndim != 2:
        blocks = blocks.reshape(blocks.shape[0], -1)
    n, length = blocks.shape
    if length == 0:
        return np.zeros(n, dtype=np.uint32)
    post, const = _pos_tables(length)
    acc = np.full(n, const, dtype=np.uint32)
    for start in range(0, length, _CHUNK):
        stop = min(start + _CHUNK, length)
        idx = np.arange(start, stop)
        # (N, chunk) gather of per-position contributions, XOR-reduced.
        acc ^= np.bitwise_xor.reduce(post[idx, blocks[:, start:stop]], axis=1)
    return acc


def crc32c_pack(crcs: np.ndarray) -> np.ndarray:
    """Pack ``(N,) uint32`` checksums as ``(N, 4)`` little-endian bytes."""
    return np.ascontiguousarray(crcs, dtype="<u4").view(np.uint8).reshape(-1, 4)


def verify_many(blocks: np.ndarray, crcs: np.ndarray) -> np.ndarray:
    """Boolean mask: ``True`` where row i's CRC32C matches ``crcs[i]``."""
    return crc32c_many(blocks) == np.asarray(crcs, dtype=np.uint32)
