"""End-to-end data integrity: per-block CRC32C + verify/repair plumbing.

The integrity layer gives the simulated ZNS stack the missing half of
its fault model: parity can repair silent media faults (bit rot, torn
writes, misdirected writes, latent unreadable sectors) *only if the
host detects them first*.  Detection is a per-block CRC32C computed at
commit time on the packed arenas (``repro.integrity.checksum``), stored
in the drive's per-block checksum store alongside the OOB area and
embedded in the slack bytes of zone footer blocks.

Consumers:

* ``repro.core.zns``      -- checksum store, UNC mask, media-fault
  application, in-place ``repair_block``;
* ``repro.core.array``    -- verify-on-read + reconstruction repair,
  ``scrub_once`` bulk verify;
* ``repro.core.handlers`` -- the paced ``schedule_scrub`` timed actor;
* ``repro.core.recovery`` -- checksum-validated header/footer winners.
"""
from repro.integrity.checksum import (
    CRC_BYTES,
    crc32c,
    crc32c_many,
    crc32c_pack,
    verify_many,
)

__all__ = [
    "CRC_BYTES",
    "crc32c",
    "crc32c_many",
    "crc32c_pack",
    "verify_many",
]
