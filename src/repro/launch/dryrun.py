import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x shape) cell on
the production meshes and extract roofline inputs from the compiled module.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all              # every cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi # 512-chip

Results are written incrementally to ``experiments/dryrun/*.json`` (one file
per cell x mesh); existing files are skipped so the sweep is resumable.
"""
import argparse
import json
import math
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis import roofline as rl
from repro.configs import ARCHS, get_config
from repro.data.pipeline import DataConfig
from repro.distributed import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, batch_struct, cell_supported, decode_structs
from repro.optim import adamw
from repro.train import steps as steps_mod

OUT_DIR = pathlib.Path("experiments/dryrun")


def _dev_bytes(shape_tree, spec_tree, mesh) -> float:
    """Per-device bytes of a sharded pytree (from shapes + specs)."""
    total = 0.0
    flat_s = jax.tree.leaves(
        spec_tree, is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec)
    )
    flat_t = jax.tree.leaves(shape_tree)
    for leaf, spec in zip(flat_t, flat_s):
        n = math.prod(leaf.shape) if leaf.shape else 1
        denom = 1
        for ax in spec:
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                denom *= mesh.shape[a]
        total += n * jnp.dtype(leaf.dtype).itemsize / denom
    return total


def _cache_specs(model, cfg, cache_structs, mesh):
    specs = {}
    for name, leaf in cache_structs.items():
        if name == "len":
            specs[name] = jax.sharding.PartitionSpec()
        elif name in ("k", "v", "ak", "av", "ck", "cv"):
            specs[name] = sh.cache_spec(mesh, leaf.shape, kv_heads_dim=3, seq_dim=2)
        elif name == "conv":
            specs[name] = sh.cache_spec(mesh, leaf.shape, kv_heads_dim=3, seq_dim=2)
        elif name == "ssd":
            # (L,B,H,N,P): heads over model, batch over data
            specs[name] = sh.cache_spec(mesh, leaf.shape, kv_heads_dim=2, seq_dim=3)
        else:
            specs[name] = jax.sharding.PartitionSpec()
    return specs


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: pathlib.Path,
             *, force: bool = False, opt_overrides: dict | None = None,
             cfg_overrides: dict | None = None, tag: str = "") -> dict:
    mesh_name = "multi" if multi_pod else "single"
    out_file = out_dir / f"{arch}__{shape_name}__{mesh_name}{tag}.json"
    if out_file.exists() and not force:
        return json.loads(out_file.read_text())
    cfg = get_config(arch)
    if cfg_overrides:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **cfg_overrides)
    cell = SHAPES[shape_name]
    ok, reason = cell_supported(cfg, shape_name)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": cell.kind, "status": "skip", "reason": reason,
    }
    if not ok:
        out_dir.mkdir(parents=True, exist_ok=True)
        out_file.write_text(json.dumps(result, indent=2))
        return result

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = math.prod(mesh.shape.values())
    try:
        result.update(_lower_and_analyze(cfg, cell, mesh, n_dev, opt_overrides))
        result["status"] = "ok"
    except Exception as e:  # noqa: BLE001 -- record the failure, keep sweeping
        result["status"] = "fail"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc(limit=20)
    result["wall_s"] = round(time.time() - t0, 1)
    out_dir.mkdir(parents=True, exist_ok=True)
    out_file.write_text(json.dumps(result, indent=2))
    return result


def _lower_and_analyze(cfg, cell, mesh, n_dev, opt_overrides=None):
    opt_cfg = adamw.AdamWConfig(**(opt_overrides or {}))
    model, train_step = steps_mod.make_train_step(cfg, opt_cfg)
    key = jax.random.PRNGKey(0)
    tp = cfg.parallelism == "tp"
    param_shapes = jax.eval_shape(model.init, key)
    pspecs = sh.param_specs(param_shapes, model.axes(), mesh, fsdp=cfg.fsdp, tp=tp)
    param_dev_bytes = _dev_bytes(param_shapes, pspecs, mesh)
    inc_model = not tp  # pure-DP profile: batch shards over the model axis too

    if cell.kind == "train":
        opt_shapes = jax.eval_shape(
            lambda p: steps_mod.init_opt_state(model, p, opt_cfg), param_shapes
        )
        ospecs = adamw.state_specs(pspecs, param_shapes, mesh, zero1=True)
        if "residual" in opt_shapes:
            ospecs["residual"] = ospecs["m"]
        opt_dev_bytes = _dev_bytes(opt_shapes, ospecs, mesh)
        batch = batch_struct(cfg, cell)
        bspecs = {k: sh.data_spec(mesh, len(v.shape), batch_size=v.shape[0],
                                  include_model=inc_model)
                  for k, v in batch.items()}
        fn = jax.jit(
            train_step,
            in_shardings=(
                sh.named(mesh, pspecs), sh.named(mesh, ospecs), sh.named(mesh, bspecs)
            ),
        )
        with jax.set_mesh(mesh):
            lowered = fn.lower(param_shapes, opt_shapes, batch)
        analytic_hbm = 2 * param_dev_bytes + 2 * opt_dev_bytes
        state_bytes = param_dev_bytes + opt_dev_bytes
    elif cell.kind == "prefill":
        _, prefill_step = steps_mod.make_prefill_step(cfg)
        batch = batch_struct(cfg, cell)
        bspecs = {k: sh.data_spec(mesh, len(v.shape), batch_size=v.shape[0],
                                  include_model=inc_model)
                  for k, v in batch.items()}
        fn = jax.jit(
            prefill_step,
            in_shardings=(sh.named(mesh, pspecs), sh.named(mesh, bspecs)),
        )
        with jax.set_mesh(mesh):
            lowered = fn.lower(param_shapes, batch)
        analytic_hbm = param_dev_bytes
        state_bytes = param_dev_bytes
    else:  # decode
        _, decode_step = steps_mod.make_decode_step(cfg)
        cache_structs, tok = decode_structs(model, cfg, cell)
        cspecs = _cache_specs(model, cfg, cache_structs, mesh)
        cache_dev_bytes = _dev_bytes(cache_structs, cspecs, mesh)
        fn = jax.jit(
            decode_step,
            in_shardings=(
                sh.named(mesh, pspecs), sh.named(mesh, cspecs),
                sh.named(mesh, sh.data_spec(mesh, 2, batch_size=cell.global_batch)),
            ),
        )
        with jax.set_mesh(mesh):
            lowered = fn.lower(param_shapes, cache_structs, tok)
        analytic_hbm = param_dev_bytes + 2 * cache_dev_bytes
        state_bytes = param_dev_bytes + cache_dev_bytes

    with jax.set_mesh(mesh):
        compiled = lowered.compile()
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
    except Exception:
        cost = {}
    mem_info = {}
    try:
        ma = compiled.memory_analysis()
        for field in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
        ):
            if hasattr(ma, field):
                mem_info[field] = int(getattr(ma, field))
    except Exception as e:
        mem_info["error"] = str(e)

    hlo = compiled.as_text()
    report = rl.analyze_hlo(
        hlo, n_devices=n_dev, cost_analysis=cost, analytic_hbm_bytes=analytic_hbm
    )
    model_fl = rl.model_flops_per_step(cfg, cell)
    per_dev_model_fl = model_fl / n_dev
    dom = report.dominant()
    bound_s = max(report.compute_s, report.memory_s, report.collective_s)
    return {
        "n_devices": n_dev,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "param_dev_bytes": param_dev_bytes,
        "state_dev_bytes": state_bytes,
        "memory_analysis": mem_info,
        "roofline": report.to_dict(),
        "model_flops_step": model_fl,
        "model_flops_dev": per_dev_model_fl,
        "useful_flops_ratio": (
            per_dev_model_fl / report.flops if report.flops else None
        ),
        # fraction of the chip's peak the step achieves if it runs exactly at
        # its dominant roofline bound: (useful FLOPs / peak) / bound_time
        "roofline_fraction": (
            (per_dev_model_fl / rl.PEAK_FLOPS) / bound_s if bound_s else None
        ),
        "dominant": dom,
        "hlo_bytes": len(hlo),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (perf variants)")
    ap.add_argument("--tag", default="", help="suffix for variant result files")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = {"true": True, "false": False}.get(
            v.lower(), int(v) if v.isdigit() else v
        )

    out_dir = pathlib.Path(args.out)
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                r = run_cell(arch, shape, mp, out_dir, force=args.force,
                             cfg_overrides=overrides or None, tag=args.tag)
                mesh_s = "multi" if mp else "single"
                rf = r.get("roofline_fraction")
                extra = (
                    f"dom={r.get('dominant')} roofline={rf:.3f}"
                    if rf is not None
                    else r.get("reason", r.get("error", ""))[:70]
                )
                print(
                    f"{arch:24s} {shape:12s} {mesh_s:6s} {r['status']:5s} "
                    f"wall={r.get('wall_s', 0)}s {extra}",
                    flush=True,
                )


if __name__ == "__main__":
    main()
