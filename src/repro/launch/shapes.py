"""Assigned input-shape cells and their ShapeDtypeStruct input specs.

Every (architecture x shape) pair is a dry-run cell.  ``decode_*`` /
``long_*`` lower ``decode_step`` (one new token against a seq_len KV/state
cache); ``prefill_32k`` lowers the prefill; ``train_4k`` lowers the full
train step.  ``long_500k`` requires sub-quadratic attention and runs only
for the SSM/hybrid architectures (spec-directed skip for pure
full-attention archs; see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def cell_supported(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and cfg.family not in LONG_CONTEXT_FAMILIES:
        return False, (
            "spec-directed skip: long_500k needs sub-quadratic attention; "
            f"{cfg.name} is a full-attention family ({cfg.family})"
        )
    return True, ""


def batch_struct(cfg: ModelConfig, cell: ShapeCell):
    """ShapeDtypeStructs for the model-input batch of a train/prefill cell."""
    b, t = cell.global_batch, cell.seq_len
    out = {
        "tokens": jax.ShapeDtypeStruct((b, t), jnp.int32),
    }
    if cell.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((b, t), jnp.int32)
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_len, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        out["vis_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vis_prefix_len, cfg.vis_embed_dim), jnp.float32
        )
    return out


def decode_structs(model, cfg: ModelConfig, cell: ShapeCell):
    """(cache_structs, token_struct) for a decode cell."""
    cache = model.init_cache(cell.global_batch, cell.seq_len)
    tokens = jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)
    return cache, tokens
