"""End-to-end training driver (example + integration target).

Runs a real training loop on the local devices (CPU smoke sizes by default,
production mesh when launched on a pod), with:

* deterministic synthetic data pipeline,
* AdamW (+ optional gradient compression),
* ZapRAID-backed checkpointing every ``--ckpt-every`` steps,
* failure injection (``--fail-lane N --fail-at S``) exercising degraded
  restore mid-run,
* crash-restart determinism check (``--restart-at``): the loop restores and
  the loss trace must continue identically.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --steps 20 --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.zapraid_ckpt import CheckpointConfig, CheckpointEngine
from repro.configs import get_config
from repro.data.pipeline import DataConfig, batch_for_step
from repro.models.config import smoke
from repro.optim import adamw
from repro.train import steps as steps_mod


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--compression", default="none", choices=["none", "int8", "topk"])
    ap.add_argument("--fail-lane", type=int, default=-1)
    ap.add_argument("--fail-at", type=int, default=-1)
    ap.add_argument("--restart-at", type=int, default=-1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke(cfg)
    opt_cfg = adamw.AdamWConfig(compression=args.compression, warmup_steps=10)
    model, train_step = steps_mod.make_train_step(cfg, opt_cfg)
    train_step = jax.jit(train_step)

    params = model.init(jax.random.PRNGKey(0))
    opt_state = steps_mod.init_opt_state(model, params, opt_cfg)
    dc = DataConfig(args.global_batch, args.seq_len, cfg.vocab)

    engine = CheckpointEngine(
        CheckpointConfig(n_lanes=4, scheme="raid5", group_size=8,
                         block_bytes=4096, zone_cap_blocks=512, n_zones=96),
        logical_blocks=1 << 14,
    )

    losses = []
    step = 0
    t0 = time.time()
    while step < args.steps:
        batch = batch_for_step(dc, cfg, step)
        params, opt_state, metrics = train_step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        step += 1
        if step % args.ckpt_every == 0:
            engine.save(step, {"params": params, "opt": opt_state})
            print(f"step {step}: loss={losses[-1]:.4f} (checkpointed)")
        else:
            print(f"step {step}: loss={losses[-1]:.4f}")

        if step == args.fail_at and args.fail_lane >= 0:
            print(f"!! injecting storage-lane failure: lane {args.fail_lane}")
            engine.fail_lane(args.fail_lane)

        if step == args.restart_at:
            print("!! simulating preemption: restore from latest checkpoint")
            args.restart_at = -1  # one-shot
            last = max(engine.catalog)
            restored = engine.restore(
                last, {"params": params, "opt": opt_state}
            )
            params = jax.tree.map(jnp.asarray, restored["params"])
            opt_state = jax.tree.map(jnp.asarray, restored["opt"])
            step = last

    dt = time.time() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s; "
          f"final loss {losses[-1]:.4f}; ckpt stats: {engine.stats()}")
    return losses


if __name__ == "__main__":
    run()
