"""Serving driver: continuous-batch prefill + decode loop.

Runs a real generation service loop on local devices (smoke sizes on CPU;
the same ``prefill``/``decode_step`` functions are what the decode_32k /
long_500k dry-run cells lower at production shapes).  Features:

* batched prefill, then token-by-token batched greedy decode;
* per-request generation lengths with early-exit slots refilled from a
  request queue (continuous batching at step granularity);
* throughput report (prefill tokens/s, decode tokens/s).

With ``--storage-sim`` the token loop is replaced by the storage-side view
of the same cell: many simulated training jobs stream erasure-coded
checkpoint saves through the async block service (``repro.service``) while
latency-class serving reads run alongside, and the report is per-tenant
tail latency under the chosen dispatch policy (``--policy both`` prints
the QoS-vs-FIFO comparison).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --requests 8
  PYTHONPATH=src python -m repro.launch.serve --storage-sim --policy both
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.config import smoke
from repro.models.model import build_model


def run_storage_sim(args) -> None:
    """Checkpoint-traffic-at-scale under serving, on the virtual clock."""
    from repro.service.scenario import checkpoint_under_serving

    policies = ("qos", "fifo") if args.policy == "both" else (args.policy,)
    results = {}
    for pol in policies:
        res = checkpoint_under_serving(
            policy=pol, n_jobs=args.jobs, n_saves=args.saves, seed=args.seed
        )
        results[pol] = res
        ten = res["summary"]["tenants"]
        print(
            f"[{pol:4s}] serve read p50 {res['serve_p50_us']:7.1f}us "
            f"p99 {res['serve_p99_us']:7.1f}us (n={res['serve_n']}) | "
            f"ckpt save mean {res['ckpt_save_mean_us']:8.1f}us "
            f"max {res['ckpt_save_max_us']:8.1f}us | "
            f"restore bit-identical: {res['restore_ok']}"
        )
        for name in sorted(ten):
            t = ten[name]
            print(
                f"       {name:6s} class={t['qos']:10s} accepted={t['accepted']:4d} "
                f"rejected={t['rejected']:3d} completed={t['completed']:4d}"
            )
    if len(results) == 2:
        gain = results["fifo"]["serve_p99_us"] / results["qos"]["serve_p99_us"]
        print(f"QoS cuts the serving tenant's read p99 by {gain:.1f}x vs FIFO")


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=24)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--storage-sim", action="store_true",
                    help="run the checkpoint-under-serving storage scenario")
    ap.add_argument("--policy", default="both", choices=("qos", "fifo", "both"))
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--saves", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.storage_sim:
        run_storage_sim(args)
        return

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    rng = np.random.default_rng(0)
    queue = [
        jnp.asarray(rng.integers(0, cfg.vocab, (args.prompt_len,)), jnp.int32)
        for _ in range(args.requests)
    ]
    done = 0
    t0 = time.time()
    prefill_tokens = decode_tokens = 0
    while queue:
        batch_prompts = [queue.pop(0) for _ in range(min(args.batch, len(queue)))]
        while len(batch_prompts) < args.batch:
            batch_prompts.append(batch_prompts[-1])  # pad batch with repeats
        prompts = jnp.stack(batch_prompts)
        logits, cache = prefill(params, {"tokens": prompts})
        prefill_tokens += prompts.size
        for k in ("k", "v", "ak", "av"):
            if k in cache:
                pad = [(0, 0)] * cache[k].ndim
                pad[2] = (0, args.gen_len)
                cache[k] = jnp.pad(cache[k], pad)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        outs = [tok]
        for _ in range(args.gen_len - 1):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            outs.append(tok)
            decode_tokens += tok.shape[0]
        done += len(batch_prompts)
    dt = time.time() - t0
    print(
        f"served {done} requests in {dt:.1f}s | "
        f"prefill {prefill_tokens/dt:.0f} tok/s | decode {decode_tokens/dt:.0f} tok/s"
    )


if __name__ == "__main__":
    run()
