"""Serving driver: continuous-batch prefill + decode loop.

Runs a real generation service loop on local devices (smoke sizes on CPU;
the same ``prefill``/``decode_step`` functions are what the decode_32k /
long_500k dry-run cells lower at production shapes).  Features:

* batched prefill, then token-by-token batched greedy decode;
* per-request generation lengths with early-exit slots refilled from a
  request queue (continuous batching at step granularity);
* throughput report (prefill tokens/s, decode tokens/s).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.config import smoke
from repro.models.model import build_model


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=24)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    rng = np.random.default_rng(0)
    queue = [
        jnp.asarray(rng.integers(0, cfg.vocab, (args.prompt_len,)), jnp.int32)
        for _ in range(args.requests)
    ]
    done = 0
    t0 = time.time()
    prefill_tokens = decode_tokens = 0
    while queue:
        batch_prompts = [queue.pop(0) for _ in range(min(args.batch, len(queue)))]
        while len(batch_prompts) < args.batch:
            batch_prompts.append(batch_prompts[-1])  # pad batch with repeats
        prompts = jnp.stack(batch_prompts)
        logits, cache = prefill(params, {"tokens": prompts})
        prefill_tokens += prompts.size
        for k in ("k", "v", "ak", "av"):
            if k in cache:
                pad = [(0, 0)] * cache[k].ndim
                pad[2] = (0, args.gen_len)
                cache[k] = jnp.pad(cache[k], pad)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        outs = [tok]
        for _ in range(args.gen_len - 1):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            outs.append(tok)
            decode_tokens += tok.shape[0]
        done += len(batch_prompts)
    dt = time.time() - t0
    print(
        f"served {done} requests in {dt:.1f}s | "
        f"prefill {prefill_tokens/dt:.0f} tok/s | decode {decode_tokens/dt:.0f} tok/s"
    )


if __name__ == "__main__":
    run()
