"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The single-pod mesh
is 16x16 = 256 chips (data, model); the multi-pod mesh is 2x16x16 = 512
chips (pod, data, model).  The dry-run launcher forces 512 host devices
before any jax import; real deployments get the same topology from the TPU
runtime.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Tiny mesh over the actually-present local devices (tests/examples)."""
    n = len(jax.devices())
    dp = max(1, n // model_parallel)
    return jax.make_mesh((dp, model_parallel), ("data", "model"))
