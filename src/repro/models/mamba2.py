"""Mamba-2 (SSD) block: chunked state-space duality in pure JAX.

Math identical to the Pallas kernel in ``kernels/ssd_scan.py`` (which is the
TPU fast path, validated against ``kernels/ref.py``); this module provides
the einsum formulation that XLA partitions across the mesh for training and
the dry-run.  B/C projections are shared across heads (ngroups=1), heads are
sharded over the model axis.

Block structure (Mamba-2 paper):
  in-proj -> [z | x | B | C | dt] -> causal conv(x,B,C) -> silu
          -> SSD(x, dt, A, B, C) + D*x -> gated RMSNorm(z) -> out-proj
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dtype_of, normal_init, rmsnorm


def init_mamba_block(key, cfg: ModelConfig):
    d, di = cfg.d_model, cfg.d_inner
    h, n, cw = cfg.ssm_nheads, cfg.ssm_state, cfg.ssm_conv
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 8)
    sc = d ** -0.5
    p = {
        "w_z": normal_init(ks[0], (d, di), sc, dt),
        "w_x": normal_init(ks[1], (d, di), sc, dt),
        "w_b": normal_init(ks[2], (d, n), sc, dt),
        "w_c": normal_init(ks[3], (d, n), sc, dt),
        "w_dt": normal_init(ks[4], (d, h), sc, jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "conv_w": normal_init(ks[5], (cw, di + 2 * n), 0.2, dt),
        "conv_b": jnp.zeros((di + 2 * n,), dt),
        "norm": jnp.ones((di,), dt),
        "w_out": normal_init(ks[6], (di, d), di ** -0.5, dt),
    }
    a = {
        "w_z": ("embed", "ssm_inner"),
        "w_x": ("embed", "ssm_inner"),
        "w_b": ("embed", None),
        "w_c": ("embed", None),
        "w_dt": ("embed", "ssm_heads"),
        "dt_bias": ("ssm_heads",),
        "a_log": ("ssm_heads",),
        "d_skip": ("ssm_heads",),
        "conv_w": (None, "ssm_conv_ch"),
        "conv_b": ("ssm_conv_ch",),
        "norm": ("ssm_inner",),
        "w_out": ("ssm_inner", "embed"),
    }
    return p, a


def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time.  x: (B,T,C), w: (W,C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(width):  # static tiny loop (W=4)
        out = out + xp[:, j : j + x.shape[1], :].astype(jnp.float32) * w[j].astype(
            jnp.float32
        )
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(x, dt, a, b, c, h0=None, *, chunk: int):
    """Chunked SSD scan.

    x: (B,T,H,P) values; dt: (B,T,H) (>0); a: (H,) (<0);
    b, c: (B,T,N) shared across heads.  Returns (y (B,T,H,P), h (B,H,N,P)).
    """
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, t)
    t_orig = t
    pad = (-t) % q
    if pad:  # dt=0 padding steps are exact identities (decay exp(0)=1, no input)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        t = t + pad
    nc = t // q
    xr = x.reshape(bsz, nc, q, h, p).astype(jnp.float32)
    dtr = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    br = b.reshape(bsz, nc, q, n).astype(jnp.float32)
    cr = c.reshape(bsz, nc, q, n).astype(jnp.float32)

    la = dtr * a  # (B,nc,Q,H), <= 0
    s = jnp.cumsum(la, axis=2)
    rel = s[:, :, :, None, :] - s[:, :, None, :, :]  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    # mask BEFORE exp: the upper triangle has rel > 0 and exp overflows,
    # poisoning gradients through jnp.where (inf * 0 -> NaN in the vjp).
    lmat = jnp.exp(jnp.where(tri[None, None, :, :, None], rel, -1e30))
    cb = jnp.einsum("bcqn,bcpn->bcqp", cr, br)  # shared across heads
    xdt = xr * dtr[..., None]
    y_diag = jnp.einsum("bcqp,bcqph,bcphv->bcqhv", cb, lmat, xdt)

    # per-chunk input states and decays
    w = jnp.exp(s[:, :, -1:, :] - s) * dtr  # (B,nc,Q,H)
    states = jnp.einsum("bcpn,bcph,bcphv->bchnv", br, w, xr)  # (B,nc,H,N,P)
    chunk_decay = jnp.exp(s[:, :, -1, :])  # (B,nc,H)

    h0 = (
        jnp.zeros((bsz, h, n, p), jnp.float32)
        if h0 is None
        else h0.astype(jnp.float32)
    )

    def step(carry, inp):
        st, dec = inp  # (B,H,N,P), (B,H)
        prev = carry
        new = dec[:, :, None, None] * prev + st
        return new, prev  # emit the state *entering* the chunk

    _last, h_prev = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # (B,nc,H,N,P)
    y_off = jnp.einsum("bcqn,bchnv->bcqhv", cr, h_prev) * jnp.exp(s)[..., None]
    y = (y_diag + y_off).reshape(bsz, t, h, p)[:, :t_orig]
    return y.astype(x.dtype), _last


def mamba_apply(p, x, cfg: ModelConfig, *, state=None):
    """Mamba-2 block.  Training/prefill: state=None.  Decode: state is
    (conv_state (B,W-1,C), ssd_state (B,H,N,P)) and x is (B,1,D)."""
    b_sz, t, _ = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    pdim = cfg.ssm_head_dim
    z = jnp.einsum("btd,de->bte", x, p["w_z"])
    xs = jnp.einsum("btd,de->bte", x, p["w_x"])
    bb = jnp.einsum("btd,dn->btn", x, p["w_b"])
    cc = jnp.einsum("btd,dn->btn", x, p["w_c"])
    dt = jnp.einsum("btd,dh->bth", x.astype(jnp.float32), p["w_dt"])
    dt = jax.nn.softplus(dt + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    conv_in = jnp.concatenate([xs, bb.astype(xs.dtype), cc.astype(xs.dtype)], -1)
    if state is None:
        conv_out = causal_conv(conv_in, p["conv_w"], p["conv_b"])
        new_conv_state = conv_in[:, -(cfg.ssm_conv - 1) :, :] if t >= cfg.ssm_conv - 1 else None
    else:
        conv_state, ssd_state = state
        window = jnp.concatenate([conv_state, conv_in], axis=1)  # (B,W,C)
        conv_out = jnp.einsum(
            "bwc,wc->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32)
        )[:, None, :] + p["conv_b"].astype(jnp.float32)
        conv_out = conv_out.astype(conv_in.dtype)
        new_conv_state = window[:, 1:, :]
    conv_out = jax.nn.silu(conv_out)
    xs2 = conv_out[..., :di].reshape(b_sz, t, h, pdim)
    bb2 = conv_out[..., di : di + n]
    cc2 = conv_out[..., di + n :]

    if state is None:
        y, final_state = ssd_chunked(
            xs2, dt, a, bb2, cc2, chunk=cfg.ssm_chunk
        )
    else:
        _, ssd_state = state
        decay = jnp.exp(dt[:, 0, :] * a)  # (B,H)
        upd = jnp.einsum(
            "bn,bh,bhv->bhnv", bb2[:, 0].astype(jnp.float32),
            dt[:, 0, :], xs2[:, 0].astype(jnp.float32),
        )
        final_state = decay[:, :, None, None] * ssd_state + upd
        y = jnp.einsum("bn,bhnv->bhv", cc2[:, 0].astype(jnp.float32), final_state)
        y = y[:, None].astype(x.dtype)  # (B,1,H,P)
        y = y.reshape(b_sz, 1, h, pdim)

    y = y + xs2 * p["d_skip"][:, None].astype(y.dtype).reshape(1, 1, h, 1)
    y = y.reshape(b_sz, t, di)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["w_out"])
    if state is None:
        # training/prefill returns the final SSD state + conv tail for decode
        tail = conv_in[:, -(cfg.ssm_conv - 1) :, :]
        if t < cfg.ssm_conv - 1:
            tail = jnp.pad(conv_in, ((0, 0), (cfg.ssm_conv - 1 - t, 0), (0, 0)))
        return out, (tail, final_state)
    return out, (new_conv_state, final_state)
