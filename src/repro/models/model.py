"""Model assembly: one ``Model`` facade per architecture family.

Families:
  dense / moe / vlm -> decoder-only transformer (GQA, RoPE, SwiGLU, optional
                       MoE every layer, optional stubbed vision prefix)
  ssm               -> Mamba-2 stack (attention-free)
  hybrid            -> Mamba-2 stack + one shared attention block applied
                       every ``shared_attn_every`` layers (zamba2-style)
  encdec            -> whisper backbone: bidirectional encoder over stubbed
                       frame embeddings + causal decoder with cross-attention

All layer stacks run under ``jax.lax.scan`` over stacked parameters so the
HLO (and compile time) stays O(1) in depth; remat is per-layer with the
``dots_with_no_batch_dims_saveable`` policy.

API (all pure functions of (params, batch)):
  init(key) -> params            axes() -> logical-axis tree
  loss(params, batch) -> scalar  (train_step target)
  prefill(params, batch) -> (last_logits, cache)
  decode_step(params, cache, tokens, pos) -> (logits, cache)
  init_cache(batch_size, max_len) -> cache ShapeDtypeStructs (for dry-run)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models.config import ModelConfig

REMAT_POLICY = jax.checkpoint_policies.dots_with_no_batch_dims_saveable


def _split_tree(key, n):
    return list(jax.random.split(key, n))


def _stacked_init(key, n_layers, init_fn):
    """vmap an init over layers -> stacked params + per-leaf axes."""
    keys = jax.random.split(key, n_layers)
    p0, axes = init_fn(keys[0])
    stacked = jax.vmap(lambda k: init_fn(k)[0])(keys)
    axes = jax.tree.map(lambda a: ("layers",) + a, axes,
                        is_leaf=lambda a: isinstance(a, tuple))
    return stacked, axes


def _ce_loss(logits, labels):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# =====================================================================
# decoder-only transformer (dense / moe / vlm)
# =====================================================================

class TransformerLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._axes = None

    # ---- init ----------------------------------------------------------
    def _init_layer(self, key):
        cfg = self.cfg
        ks = _split_tree(key, 4)
        attn_p, attn_a = L.init_attention(ks[0], cfg)
        if cfg.n_experts:
            mlp_p, mlp_a = L.init_moe(ks[1], cfg)
        else:
            mlp_p, mlp_a = L.init_mlp(ks[1], cfg)
        p = {
            "attn": attn_p,
            "mlp": mlp_p,
            "ln1": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
            "ln2": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
        }
        a = {"attn": attn_a, "mlp": mlp_a, "ln1": (None,), "ln2": (None,)}
        return p, a

    def init(self, key):
        cfg = self.cfg
        ks = _split_tree(key, 5)
        dt = L.dtype_of(cfg)
        layers_p, layers_a = _stacked_init(ks[0], cfg.n_layers, self._init_layer)
        p = {
            "embed": L.normal_init(ks[1], (cfg.vocab, cfg.d_model), 1.0, dt),
            "layers": layers_p,
            "final_norm": jnp.ones((cfg.d_model,), dt),
        }
        a = {
            "embed": ("vocab", "embed"),
            "layers": layers_a,
            "final_norm": (None,),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = L.normal_init(
                ks[2], (cfg.d_model, cfg.vocab), cfg.d_model ** -0.5, dt
            )
            a["lm_head"] = ("embed", "vocab")
        if cfg.family == "vlm":
            p["vis_proj"] = L.normal_init(
                ks[3], (cfg.vis_embed_dim, cfg.d_model), cfg.vis_embed_dim ** -0.5, dt
            )
            a["vis_proj"] = (None, "embed")
        self._axes = a
        return p

    def axes(self):
        if self._axes is None:
            jax.eval_shape(self.init, jax.random.PRNGKey(0))
        return self._axes

    # ---- forward -------------------------------------------------------
    def _layer_fwd(self, p_layer, x, positions, q_block):
        cfg = self.cfg
        h, kv = L.attention_apply(
            p_layer["attn"], L.rmsnorm(x, p_layer["ln1"], cfg.norm_eps), cfg,
            positions=positions, q_block=q_block,
        )
        x = x + h
        z = L.rmsnorm(x, p_layer["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            x = x + L.moe_apply(p_layer["mlp"], z, cfg)
        else:
            x = x + L.mlp_apply(p_layer["mlp"], z, cfg.bf16_reduce)
        return x, kv

    def _trunk(self, params, x, positions, collect_cache=False, q_block=512):
        cfg = self.cfg
        fwd = functools.partial(self._layer_fwd, positions=positions, q_block=q_block)
        axes_layer = (
            L.strip_layer_axis(self.axes()["layers"]) if cfg.fsdp_gather else None
        )

        def wrapped(p, h):
            if axes_layer is not None:
                p = L.gather_fsdp_weights(p, axes_layer)
                h = L.pin_activation_batch(h)
            return fwd(p, h)

        body = (
            jax.checkpoint(wrapped, policy=REMAT_POLICY) if cfg.remat else wrapped
        )

        def scan_fn(h, p_layer):
            h2, kv = body(p_layer, h)
            return h2, kv if collect_cache else 0

        x, caches = jax.lax.scan(scan_fn, x, params["layers"])
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return x, caches

    def _embed_tokens(self, params, tokens):
        return jnp.take(params["embed"], tokens, axis=0)

    def _logits(self, params, x):
        cfg = self.cfg
        if cfg.tie_embeddings:
            # gemma-style scaling keeps tied-head logits O(1)
            return jnp.einsum("btd,dv->btv", x, params["embed"].T) * cfg.d_model ** -0.5
        return jnp.einsum("btd,dv->btv", x, params["lm_head"])

    def _inputs(self, params, batch):
        """Token embeddings (plus projected vision prefix for VLM)."""
        x = self._embed_tokens(params, batch["tokens"])
        if self.cfg.family == "vlm" and "vis_embeds" in batch:
            pre = jnp.einsum(
                "bpe,ed->bpd", batch["vis_embeds"].astype(x.dtype), params["vis_proj"]
            )
            x = jnp.concatenate([pre, x], axis=1)
        return x

    def loss(self, params, batch):
        cfg = self.cfg
        x = self._inputs(params, batch)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x, _ = self._trunk(params, x, positions)
        if cfg.family == "vlm" and "vis_embeds" in batch:
            x = x[:, batch["vis_embeds"].shape[1] :, :]
        logits = self._logits(params, x)
        return _ce_loss(logits, batch["labels"])

    def prefill(self, params, batch):
        x = self._inputs(params, batch)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x, caches = self._trunk(params, x, positions, collect_cache=True)
        logits = self._logits(params, x[:, -1:, :])
        k, v = caches
        cache = {"k": k, "v": v, "len": jnp.int32(x.shape[1])}
        return logits, cache

    def init_cache(self, batch_size: int, max_len: int):
        cfg = self.cfg
        shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.hd())
        dt = L.dtype_of(cfg)
        return {
            "k": jax.ShapeDtypeStruct(shape, dt),
            "v": jax.ShapeDtypeStruct(shape, dt),
            "len": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def decode_step(self, params, cache, tokens, pos=None):
        """tokens: (B,1); cache k/v: (L,B,S,KV,HD); cache['len'] = #valid."""
        cfg = self.cfg
        x = self._embed_tokens(params, tokens)
        new_len = cache["len"] + 1
        positions = jnp.broadcast_to(new_len - 1, tokens.shape)

        def scan_fn(h, xs):
            p_layer, kc, vc = xs
            hn = L.rmsnorm(h, p_layer["ln1"], cfg.norm_eps)
            out, (kc2, vc2) = L.attention_apply(
                p_layer["attn"], hn, cfg, positions=positions,
                kv_cache=(kc, vc), cache_len=new_len,
            )
            h = h + out
            z = L.rmsnorm(h, p_layer["ln2"], cfg.norm_eps)
            if cfg.n_experts:
                h = h + L.moe_apply(p_layer["mlp"], z, cfg)
            else:
                h = h + L.mlp_apply(p_layer["mlp"], z)
            return h, (kc2, vc2)

        x, (k2, v2) = jax.lax.scan(
            scan_fn, x, (params["layers"], cache["k"], cache["v"])
        )
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x)
        return logits, {"k": k2, "v": v2, "len": new_len}


# =====================================================================
# Mamba-2 stack (ssm) and zamba2-style hybrid
# =====================================================================

class MambaLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._axes = None
        self.hybrid = cfg.family == "hybrid"
        if self.hybrid:
            self.n_apps = cfg.n_layers // cfg.shared_attn_every

    def _init_layer(self, key):
        cfg = self.cfg
        p, a = M.init_mamba_block(key, cfg)
        p = {"block": p, "ln": jnp.ones((cfg.d_model,), L.dtype_of(cfg))}
        a = {"block": a, "ln": (None,)}
        return p, a

    def init(self, key):
        cfg = self.cfg
        ks = _split_tree(key, 6)
        dt = L.dtype_of(cfg)
        layers_p, layers_a = _stacked_init(ks[0], cfg.n_layers, self._init_layer)
        p = {
            "embed": L.normal_init(ks[1], (cfg.vocab, cfg.d_model), 1.0, dt),
            "layers": layers_p,
            "final_norm": jnp.ones((cfg.d_model,), dt),
            "lm_head": L.normal_init(
                ks[2], (cfg.d_model, cfg.vocab), cfg.d_model ** -0.5, dt
            ),
        }
        a = {
            "embed": ("vocab", "embed"),
            "layers": layers_a,
            "final_norm": (None,),
            "lm_head": ("embed", "vocab"),
        }
        if self.hybrid:
            attn_p, attn_a = L.init_attention(ks[3], cfg)
            mlp_p, mlp_a = L.init_mlp(ks[4], cfg)
            p["shared"] = {
                "attn": attn_p, "mlp": mlp_p,
                "ln1": jnp.ones((cfg.d_model,), dt),
                "ln2": jnp.ones((cfg.d_model,), dt),
            }
            a["shared"] = {"attn": attn_a, "mlp": mlp_a, "ln1": (None,), "ln2": (None,)}
        self._axes = a
        return p

    def axes(self):
        if self._axes is None:
            jax.eval_shape(self.init, jax.random.PRNGKey(0))
        return self._axes

    # ---- forward --------------------------------------------------------
    def _shared_attn(self, params, x, positions, cache=None, cache_len=None):
        cfg = self.cfg
        sp = params["shared"]
        h, kv = L.attention_apply(
            sp["attn"], L.rmsnorm(x, sp["ln1"], cfg.norm_eps), cfg,
            positions=positions, kv_cache=cache, cache_len=cache_len,
        )
        x = x + h
        x = x + L.mlp_apply(sp["mlp"], L.rmsnorm(x, sp["ln2"], cfg.norm_eps))
        return x, kv

    def _trunk(self, params, x, positions, collect_state=False):
        cfg = self.cfg
        every = cfg.shared_attn_every

        def layer_fwd(p_layer, h):
            z = L.rmsnorm(h, p_layer["ln"], cfg.norm_eps)
            out, state = M.mamba_apply(p_layer["block"], z, cfg)
            return h + out, state

        body = (
            jax.checkpoint(layer_fwd, policy=REMAT_POLICY)
            if cfg.remat else layer_fwd
        )

        if not self.hybrid:
            def scan_fn(h, p_layer):
                h2, state = body(p_layer, h)
                return h2, state if collect_state else 0
            x, states = jax.lax.scan(scan_fn, x, params["layers"])
            x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
            return x, states, None

        # hybrid: shared attention applied every ``every`` layers.  The
        # attention caches for all applications are collected outside scan.
        def scan_fn(carry, xs):
            h, app_kv_k, app_kv_v, li = carry
            p_layer = xs
            h2, state = body(p_layer, h)
            is_attn = (li % every) == (every - 1)
            app = li // every

            def with_attn(h_in):
                h3, (k, v) = self._shared_attn(params, h_in, positions)
                kk = jax.lax.dynamic_update_index_in_dim(app_kv_k, k, app, 0)
                vv = jax.lax.dynamic_update_index_in_dim(app_kv_v, v, app, 0)
                return h3, kk, vv

            def without(h_in):
                return h_in, app_kv_k, app_kv_v

            h2, app_kv_k, app_kv_v = jax.lax.cond(is_attn, with_attn, without, h2)
            return (h2, app_kv_k, app_kv_v, li + 1), (state if collect_state else 0)

        b, t = x.shape[:2]
        kv_shape = (self.n_apps, b, t, cfg.n_kv_heads, cfg.hd())
        k0 = jnp.zeros(kv_shape, L.dtype_of(cfg))
        v0 = jnp.zeros(kv_shape, L.dtype_of(cfg))
        (x, ak, av, _), states = jax.lax.scan(
            scan_fn, (x, k0, v0, jnp.int32(0)), params["layers"]
        )
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return x, states, (ak, av)

    def loss(self, params, batch):
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x, _, _ = self._trunk(params, x, positions)
        logits = jnp.einsum("btd,dv->btv", x, params["lm_head"])
        return _ce_loss(logits, batch["labels"])

    def prefill(self, params, batch):
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x, states, attn_kv = self._trunk(params, x, positions, collect_state=True)
        logits = jnp.einsum("btd,dv->btv", x[:, -1:, :], params["lm_head"])
        conv, ssd = states
        cache = {"conv": conv, "ssd": ssd, "len": jnp.int32(x.shape[1])}
        if self.hybrid:
            cache["ak"], cache["av"] = attn_kv
        return logits, cache

    def init_cache(self, batch_size: int, max_len: int):
        cfg = self.cfg
        dt = L.dtype_of(cfg)
        ch = cfg.d_inner + 2 * cfg.ssm_state
        cache = {
            "conv": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch_size, cfg.ssm_conv - 1, ch), dt
            ),
            "ssd": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch_size, cfg.ssm_nheads, cfg.ssm_state,
                 cfg.ssm_head_dim), jnp.float32,
            ),
            "len": jax.ShapeDtypeStruct((), jnp.int32),
        }
        if self.hybrid:
            kv = (self.n_apps, batch_size, max_len, cfg.n_kv_heads, cfg.hd())
            cache["ak"] = jax.ShapeDtypeStruct(kv, dt)
            cache["av"] = jax.ShapeDtypeStruct(kv, dt)
        return cache

    def decode_step(self, params, cache, tokens, pos=None):
        cfg = self.cfg
        every = cfg.shared_attn_every
        x = jnp.take(params["embed"], tokens, axis=0)
        new_len = cache["len"] + 1
        positions = jnp.broadcast_to(new_len - 1, tokens.shape)

        if not self.hybrid:
            def scan_fn(h, xs):
                p_layer, conv, ssd = xs
                z = L.rmsnorm(h, p_layer["ln"], cfg.norm_eps)
                out, (c2, s2) = M.mamba_apply(
                    p_layer["block"], z, cfg, state=(conv, ssd)
                )
                return h + out, (c2, s2)

            x, (c2, s2) = jax.lax.scan(
                scan_fn, x, (params["layers"], cache["conv"], cache["ssd"])
            )
            x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
            logits = jnp.einsum("btd,dv->btv", x, params["lm_head"])
            return logits, {"conv": c2, "ssd": s2, "len": new_len}

        def scan_fn(carry, xs):
            h, ak, av, li = carry
            p_layer, conv, ssd = xs
            z = L.rmsnorm(h, p_layer["ln"], cfg.norm_eps)
            out, (c2, s2) = M.mamba_apply(p_layer["block"], z, cfg, state=(conv, ssd))
            h = h + out
            is_attn = (li % every) == (every - 1)
            app = li // every

            def with_attn(h_in):
                kc = jax.lax.dynamic_index_in_dim(ak, app, 0, keepdims=False)
                vc = jax.lax.dynamic_index_in_dim(av, app, 0, keepdims=False)
                h3, (k2, v2) = self._shared_attn(
                    params, h_in, positions, cache=(kc, vc), cache_len=new_len
                )
                return (
                    h3,
                    jax.lax.dynamic_update_index_in_dim(ak, k2, app, 0),
                    jax.lax.dynamic_update_index_in_dim(av, v2, app, 0),
                )

            h, ak2, av2 = jax.lax.cond(
                is_attn, with_attn, lambda h_in: (h_in, ak, av), h
            )
            return (h, ak2, av2, li + 1), (c2, s2)

        (x, ak, av, _), (c2, s2) = jax.lax.scan(
            scan_fn,
            (x, cache["ak"], cache["av"], jnp.int32(0)),
            (params["layers"], cache["conv"], cache["ssd"]),
        )
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("btd,dv->btv", x, params["lm_head"])
        return logits, {
            "conv": c2, "ssd": s2, "ak": ak, "av": av, "len": new_len
        }


# =====================================================================
# whisper-style encoder-decoder
# =====================================================================

class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._axes = None

    def _init_enc_layer(self, key):
        cfg = self.cfg
        ks = _split_tree(key, 2)
        attn_p, attn_a = L.init_attention(ks[0], cfg)
        mlp_p, mlp_a = L.init_mlp(ks[1], cfg, gated=False)
        p = {"attn": attn_p, "mlp": mlp_p,
             "ln1": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
             "ln2": jnp.ones((cfg.d_model,), L.dtype_of(cfg))}
        a = {"attn": attn_a, "mlp": mlp_a, "ln1": (None,), "ln2": (None,)}
        return p, a

    def _init_dec_layer(self, key):
        cfg = self.cfg
        ks = _split_tree(key, 3)
        self_p, self_a = L.init_attention(ks[0], cfg)
        cross_p, cross_a = L.init_attention(ks[1], cfg)
        mlp_p, mlp_a = L.init_mlp(ks[2], cfg, gated=False)
        p = {"self": self_p, "cross": cross_p, "mlp": mlp_p,
             "ln1": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
             "ln2": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
             "ln3": jnp.ones((cfg.d_model,), L.dtype_of(cfg))}
        a = {"self": self_a, "cross": cross_a, "mlp": mlp_a,
             "ln1": (None,), "ln2": (None,), "ln3": (None,)}
        return p, a

    def init(self, key):
        cfg = self.cfg
        ks = _split_tree(key, 5)
        dt = L.dtype_of(cfg)
        enc_p, enc_a = _stacked_init(ks[0], cfg.enc_layers, self._init_enc_layer)
        dec_p, dec_a = _stacked_init(ks[1], cfg.n_layers, self._init_dec_layer)
        p = {
            "embed": L.normal_init(ks[2], (cfg.vocab, cfg.d_model), 1.0, dt),
            "enc_layers": enc_p,
            "dec_layers": dec_p,
            "enc_norm": jnp.ones((cfg.d_model,), dt),
            "final_norm": jnp.ones((cfg.d_model,), dt),
        }
        a = {
            "embed": ("vocab", "embed"),
            "enc_layers": enc_a,
            "dec_layers": dec_a,
            "enc_norm": (None,),
            "final_norm": (None,),
        }
        self._axes = a
        return p

    def axes(self):
        if self._axes is None:
            jax.eval_shape(self.init, jax.random.PRNGKey(0))
        return self._axes

    def _encode(self, params, frames):
        """frames: (B, T_enc, d_model) stubbed frame embeddings."""
        cfg = self.cfg
        x = frames.astype(L.dtype_of(cfg))
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

        def fwd(p_layer, h):
            z = L.rmsnorm(h, p_layer["ln1"], cfg.norm_eps)
            q, k, v = L._qkv(p_layer["attn"], z, cfg)
            q = L.rope(q, positions, cfg.rope_theta)
            k = L.rope(k, positions, cfg.rope_theta)
            # bidirectional: full attention, no mask
            kvh, hd = cfg.n_kv_heads, cfg.hd()
            qr = q.reshape(*q.shape[:2], kvh, cfg.n_heads // kvh, hd)
            s = L._gqa_scores_block(qr, k, hd ** -0.5)
            w = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bkgts,bskh->btkgh", w.astype(v.dtype), v)
            o = o.reshape(*q.shape[:2], cfg.n_heads * hd)
            h = h + jnp.einsum("btf,fd->btd", o, p_layer["attn"]["wo"])
            h = h + L.mlp_apply(p_layer["mlp"], L.rmsnorm(h, p_layer["ln2"], cfg.norm_eps))
            return h, 0

        body = jax.checkpoint(fwd, policy=REMAT_POLICY) if cfg.remat else fwd
        x, _ = jax.lax.scan(lambda h, pl: body(pl, h), x, params["enc_layers"])
        return L.rmsnorm(x, params["enc_norm"], cfg.norm_eps)

    def _cross_kv(self, params, enc_out):
        """Precompute per-layer cross-attention K/V from encoder output."""
        cfg = self.cfg
        def one(p_layer):
            _, k, v = L._qkv(p_layer["cross"], enc_out, cfg)
            return k, v
        return jax.vmap(one)(params["dec_layers"])  # stacked (L, B, S, KV, HD)

    def _dec_layer(self, p_layer, h, positions, cross_k, cross_v,
                   kv_cache=None, cache_len=None):
        cfg = self.cfg
        out, kv = L.attention_apply(
            p_layer["self"], L.rmsnorm(h, p_layer["ln1"], cfg.norm_eps), cfg,
            positions=positions, kv_cache=kv_cache, cache_len=cache_len,
        )
        h = h + out
        # cross attention (keys/values fixed, no causal mask)
        z = L.rmsnorm(h, p_layer["ln2"], cfg.norm_eps)
        kvh, hd = cfg.n_kv_heads, cfg.hd()
        q = jnp.einsum("btd,dh->bth", z, p_layer["cross"]["wq"])
        if cfg.qkv_bias:
            q = q + p_layer["cross"]["bq"]
        q = q.reshape(*z.shape[:2], kvh, cfg.n_heads // kvh, hd)
        s = L._gqa_scores_block(q, cross_k, hd ** -0.5)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgts,bskh->btkgh", w.astype(cross_v.dtype), cross_v)
        o = o.reshape(*z.shape[:2], cfg.n_heads * hd)
        h = h + jnp.einsum("btf,fd->btd", o, p_layer["cross"]["wo"])
        h = h + L.mlp_apply(
            p_layer["mlp"], L.rmsnorm(h, p_layer["ln3"], cfg.norm_eps)
        )
        return h, kv

    def loss(self, params, batch):
        cfg = self.cfg
        enc_out = self._encode(params, batch["frames"])
        ck, cv = self._cross_kv(params, enc_out)
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

        def fwd(xs, h):
            p_layer, k, v = xs
            return self._dec_layer(p_layer, h, positions, k, v)

        body = jax.checkpoint(fwd, policy=REMAT_POLICY) if cfg.remat else fwd
        x, _ = jax.lax.scan(
            lambda h, xs: body(xs, h), x, (params["dec_layers"], ck, cv)
        )
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("btd,dv->btv", x, params["embed"].T) * cfg.d_model ** -0.5
        return _ce_loss(logits, batch["labels"])

    def prefill(self, params, batch):
        cfg = self.cfg
        enc_out = self._encode(params, batch["frames"])
        ck, cv = self._cross_kv(params, enc_out)
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

        def fwd(h, xs):
            p_layer, k, v = xs
            h2, kv = self._dec_layer(p_layer, h, positions, k, v)
            return h2, kv

        x, (sk, sv) = jax.lax.scan(fwd, x, (params["dec_layers"], ck, cv))
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("btd,dv->btv", x[:, -1:, :], params["embed"].T) * cfg.d_model ** -0.5
        return logits, {"k": sk, "v": sv, "ck": ck, "cv": cv,
                        "len": jnp.int32(x.shape[1])}

    def init_cache(self, batch_size: int, max_len: int):
        cfg = self.cfg
        dt = L.dtype_of(cfg)
        kv = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.hd())
        ckv = (cfg.n_layers, batch_size, cfg.enc_len, cfg.n_kv_heads, cfg.hd())
        return {
            "k": jax.ShapeDtypeStruct(kv, dt),
            "v": jax.ShapeDtypeStruct(kv, dt),
            "ck": jax.ShapeDtypeStruct(ckv, dt),
            "cv": jax.ShapeDtypeStruct(ckv, dt),
            "len": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def decode_step(self, params, cache, tokens, pos=None):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        new_len = cache["len"] + 1
        positions = jnp.broadcast_to(new_len - 1, tokens.shape)

        def fwd(h, xs):
            p_layer, kc, vc, ck, cv = xs
            h2, (k2, v2) = self._dec_layer(
                p_layer, h, positions, ck, cv,
                kv_cache=(kc, vc), cache_len=new_len,
            )
            return h2, (k2, v2)

        x, (k2, v2) = jax.lax.scan(
            fwd, x,
            (params["dec_layers"], cache["k"], cache["v"], cache["ck"], cache["cv"]),
        )
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("btd,dv->btv", x, params["embed"].T) * cfg.d_model ** -0.5
        return logits, {"k": k2, "v": v2, "ck": cache["ck"], "cv": cache["cv"],
                        "len": new_len}


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return TransformerLM(cfg)
    if cfg.family in ("ssm",):
        return MambaLM(cfg)
    if cfg.family == "hybrid":
        return MambaLM(cfg)
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
