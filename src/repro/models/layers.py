"""Model building blocks: norms, rotary, blocked GQA attention, SwiGLU MLP,
and a capacity-based sorted-dispatch MoE.

Conventions:

* params are plain dicts of jnp arrays; every init function returns
  ``(params, axes)`` where ``axes`` mirrors the params tree with a tuple of
  *logical axis names* per dimension (resolved to mesh axes in
  ``distributed/sharding.py``);
* compute dtype = cfg.dtype (bf16 in production), accumulation in f32 via
  ``preferred_element_type``;
* attention over long sequences is *blocked* over query chunks (exact, not
  approximate) so the T x T score matrix never materializes whole -- the
  TPU-native replacement for a CUDA fused kernel;
* the MoE dispatch sorts tokens by expert within each batch row (shard-local
  by construction: the sorted axis is the unsharded T axis), scattering into
  an (E, C, D) capacity buffer -- the standard "dropping" formulation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------- sharding-constraint helpers

TP_AXES = {"heads", "kv", "ff", "vocab", "experts",
           "ssm_inner", "ssm_heads", "ssm_conv_ch"}


def _ambient_mesh():
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or am.empty:
            return None
        return am
    except Exception:  # pragma: no cover - older jax
        return None


def _wsc(x, parts):
    """with_sharding_constraint against the ambient mesh (no-op without)."""
    return jax.lax.with_sharding_constraint(x, jax.sharding.PartitionSpec(*parts))


def gather_fsdp_weights(p_layer, axes_layer):
    """FSDP weight gather: constrain each layer weight to its TP-only spec
    (data axes dropped), so GSPMD all-gathers the (small) weight shards once
    per layer instead of all-reducing (huge) partial-sum activations.

    ``axes_layer`` is the logical-axes tree of one layer's params (leading
    "layers" axis already stripped)."""
    am = _ambient_mesh()
    if am is None or "model" not in am.axis_names:
        return p_layer
    msz = am.shape["model"]

    def one(ax, w):
        parts = []
        used = False
        for dim, a in zip(w.shape, ax):
            if a in TP_AXES and not used and dim % msz == 0:
                parts.append("model")
                used = True
            else:
                parts.append(None)
        return _wsc(w, parts)

    return jax.tree.map(one, axes_layer, p_layer,
                        is_leaf=lambda a: isinstance(a, tuple))


def strip_layer_axis(axes_layer_tree):
    """Drop the leading "layers" stacking axis from an axes tree."""
    return jax.tree.map(
        lambda a: tuple(a[1:]), axes_layer_tree,
        is_leaf=lambda a: isinstance(a, tuple),
    )


def pin_activation_batch(x):
    """Constrain an activation tensor to batch-sharded / feature-replicated.

    With FSDP weight specs, GSPMD's propagation can flip to a
    weight-stationary layout (batch replicated, features sharded over data),
    which turns every projection into a full-batch f32 reshard.  Pinning the
    residual stream at layer boundaries keeps the canonical data-parallel
    layout, so FSDP resolves into cheap per-layer weight all-gathers."""
    am = _ambient_mesh()
    if am is None:
        return x
    dp = tuple(a for a in ("pod", "data") if a in am.axis_names)
    if not dp:
        return x
    dpsz = 1
    for a in dp:
        dpsz *= am.shape[a]
    if x.shape[0] % dpsz != 0:
        return x
    parts = [dp if len(dp) > 1 else dp[0]] + [None] * (x.ndim - 1)
    return _wsc(x, parts)


# ----------------------------------------------------------------- plumbing

def normal_init(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding; x: (..., T, H, D), positions: (..., T)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,T,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention

def init_attention(key, cfg: ModelConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    sc = d ** -0.5
    p = {
        "wq": normal_init(ks[0], (d, h * hd), sc, dt),
        "wk": normal_init(ks[1], (d, kv * hd), sc, dt),
        "wv": normal_init(ks[2], (d, kv * hd), sc, dt),
        "wo": normal_init(ks[3], (h * hd, d), (h * hd) ** -0.5, dt),
    }
    a = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv"),
        "wv": ("embed", "kv"),
        "wo": ("heads", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
        a["bq"], a["bk"], a["bv"] = ("heads",), ("kv",), ("kv",)
    return p, a


def _qkv(p, x, cfg: ModelConfig):
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    q = jnp.einsum("btd,dh->bth", x, p["wq"])
    k = jnp.einsum("btd,dh->bth", x, p["wk"])
    v = jnp.einsum("btd,dh->bth", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    b, t = x.shape[:2]
    return (
        q.reshape(b, t, h, hd),
        k.reshape(b, t, kv, hd),
        v.reshape(b, t, kv, hd),
    )


def _gqa_scores_block(q, k, scale):
    """q: (B,Tq,KV,G,hd), k: (B,S,KV,hd) -> (B,KV,G,Tq,S) f32."""
    return jnp.einsum(
        "btkgh,bskh->bkgts", q, k, preferred_element_type=jnp.float32
    ) * scale


def blocked_causal_attention(
    q, k, v, *, q_block: int, q_offset: int = 0, attn_chunk: int = 0
):
    """Exact causal GQA attention, blocked over query chunks.

    q: (B,T,H,hd); k,v: (B,S,KV,hd).  Query position i attends to key
    positions <= i + q_offset (and, with attn_chunk>0, only keys in the same
    local chunk -- llama4-style chunked attention).
    Returns (B,T,H,hd).
    """
    b, t, h, hd = q.shape
    s = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    scale = hd ** -0.5
    qb = min(q_block, t)
    while t % qb:  # largest block <= q_block that divides t (ragged prefixes)
        qb -= 1
    nq = t // qb
    qr = q.reshape(b, nq, qb, kvh, g, hd)

    kpos = jnp.arange(s)

    def one_block(i):
        qi = qr[:, i]
        qpos = q_offset + i * qb + jnp.arange(qb)
        scores = _gqa_scores_block(qi, k, scale)  # (B,KV,G,qb,S)
        mask = kpos[None, :] <= qpos[:, None]
        if attn_chunk:
            mask &= (kpos[None, :] // attn_chunk) == (qpos[:, None] // attn_chunk)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum(
            "bkgts,bskh->btkgh", w.astype(v.dtype), v,
            preferred_element_type=jnp.float32,
        )
        return out.reshape(b, qb, h, hd).astype(q.dtype)

    if nq == 1:
        return one_block(0)
    outs = jax.lax.map(one_block, jnp.arange(nq))  # (nq,B,qb,H,hd)
    return jnp.moveaxis(outs, 0, 1).reshape(b, t, h, hd)


def seq_sharded_attention(q, k, v, *, q_offset: int = 0, attn_chunk: int = 0):
    """Exact causal GQA attention with the query *time* axis sharded over the
    model mesh axis (context parallelism).

    For architectures whose head count does not divide the TP degree (e.g.
    llama4's 40 heads or smollm's 9 on a 16-way model axis), head-sharding
    degenerates to hd-dim partial sums and GSPMD emits giant score-tensor
    all-reduces.  Sharding query time instead keeps every contraction local:
    the only collective is an all-gather of K/V (tiny by comparison).
    """
    am = _ambient_mesh()
    b, t, h, hd = q.shape
    s_len = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    msz = am.shape["model"]
    tq = t // msz
    dp = tuple(a for a in ("pod", "data") if a in am.axis_names)
    dpsz = 1
    for a in dp:
        dpsz *= am.shape[a]
    bpart = (dp if len(dp) > 1 else dp[0]) if (dp and b % dpsz == 0) else None

    qr = q.reshape(b, msz, tq, kvh, g, hd)
    qr = _wsc(qr, (bpart, "model", None, None, None, None))
    k = _wsc(k, (bpart, None, None, None))
    v = _wsc(v, (bpart, None, None, None))
    scale = hd ** -0.5
    scores = jnp.einsum(
        "bmtkgh,bskh->bmkgts", qr, k, preferred_element_type=jnp.float32
    ) * scale  # (b, msz, kv, g, tq, s)
    kpos = jnp.arange(s_len)
    qpos = (
        q_offset
        + jax.lax.broadcasted_iota(jnp.int32, (msz, tq), 0) * tq
        + jax.lax.broadcasted_iota(jnp.int32, (msz, tq), 1)
    )
    mask = kpos[None, None, :] <= qpos[:, :, None]  # (msz, tq, s)
    if attn_chunk:
        mask &= (kpos[None, None, :] // attn_chunk) == (qpos[:, :, None] // attn_chunk)
    scores = jnp.where(mask[None, :, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bmkgts,bskh->bmtkgh", w.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, t, h, hd).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, attn_chunk: int = 0):
    """Single-token attention over a KV cache.

    q: (B,1,H,hd); caches: (B,S,KV,hd); cache_len: scalar count of valid
    entries (the new token's K/V must already be written at cache_len-1).
    """
    b, _, h, hd = q.shape
    s = k_cache.shape[1]
    kvh = k_cache.shape[2]
    qr = q.reshape(b, 1, kvh, h // kvh, hd)
    scores = _gqa_scores_block(qr, k_cache, hd ** -0.5)  # (B,KV,G,1,S)
    kpos = jnp.arange(s)
    mask = kpos < cache_len
    if attn_chunk:
        qpos = cache_len - 1
        mask &= (kpos // attn_chunk) == (qpos // attn_chunk)
    scores = jnp.where(mask[None, None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgts,bskh->btkgh", w.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def attention_apply(
    p,
    x,
    cfg: ModelConfig,
    *,
    positions,
    kv_cache=None,
    cache_len=None,
    q_block: int = 512,
):
    """Unified attention: training/prefill (kv_cache=None -> returns fresh
    cache) or decode (kv_cache given, x is (B,1,D))."""
    h, hd = cfg.n_heads, cfg.hd()
    b = x.shape[0]
    q, k, v = _qkv(p, x, cfg)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if kv_cache is None:
        am = _ambient_mesh()
        t = q.shape[1]
        if (
            cfg.attn_seq_shard
            and am is not None
            and "model" in am.axis_names
            and t % am.shape["model"] == 0
        ):
            out = seq_sharded_attention(q, k, v, attn_chunk=cfg.attn_chunk)
        else:
            out = blocked_causal_attention(
                q, k, v, q_block=q_block, attn_chunk=cfg.attn_chunk
            )
        new_cache = (k, v)
    else:
        kc, vc = kv_cache
        idx = cache_len - 1
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, idx, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, idx, axis=1)
        out = decode_attention(q, kc, vc, cache_len, attn_chunk=cfg.attn_chunk)
        new_cache = (kc, vc)
    acc = jnp.bfloat16 if cfg.bf16_reduce else None
    y = jnp.einsum("btf,fd->btd", out.reshape(b, -1, h * hd), p["wo"],
                   preferred_element_type=acc)
    return y, new_cache


# ------------------------------------------------------------------- MLP

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None, gated: bool = True):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    p = {
        "w_in": normal_init(ks[0], (d, ff), d ** -0.5, dt),
        "w_out": normal_init(ks[2], (ff, d), ff ** -0.5, dt),
    }
    a = {"w_in": ("embed", "ff"), "w_out": ("ff", "embed")}
    if gated:
        p["w_gate"] = normal_init(ks[1], (d, ff), d ** -0.5, dt)
        a["w_gate"] = ("embed", "ff")
    return p, a


def mlp_apply(p, x, bf16_reduce: bool = False):
    h = jnp.einsum("btd,df->btf", x, p["w_in"])
    if "w_gate" in p:
        g = jnp.einsum("btd,df->btf", x, p["w_gate"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    acc = jnp.bfloat16 if bf16_reduce else None
    return jnp.einsum("btf,fd->btd", h, p["w_out"], preferred_element_type=acc)


# ------------------------------------------------------------------- MoE

def init_moe(key, cfg: ModelConfig):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 5)
    p = {
        "router": normal_init(ks[0], (d, e), d ** -0.5, jnp.float32),
        "w_gate": normal_init(ks[1], (e, d, ff), d ** -0.5, dt),
        "w_in": normal_init(ks[2], (e, d, ff), d ** -0.5, dt),
        "w_out": normal_init(ks[3], (e, ff, d), ff ** -0.5, dt),
    }
    a = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "ff"),
        "w_in": ("experts", "embed", "ff"),
        "w_out": ("experts", "ff", "embed"),
    }
    if cfg.shared_expert_ff:
        sp, sa = init_mlp(ks[4], cfg, d_ff=cfg.shared_expert_ff)
        p["shared"], a["shared"] = sp, sa
    return p, a


def moe_apply(p, x, cfg: ModelConfig):
    """Capacity-based top-k MoE with shard-local sorted dispatch.

    The sort runs along the (unsharded) token axis of each batch row, so the
    dispatch is local to every data shard; expert FFN weights are sharded on
    (experts x ff) over the model axis.
    """
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = max(1, int(np.ceil(t * k / e * cfg.capacity_factor)))

    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (b,t,k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    flat_e = top_e.reshape(b, t * k)
    flat_p = top_p.reshape(b, t * k)
    order = jnp.argsort(flat_e, axis=-1)  # (b, tk)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    sorted_p = jnp.take_along_axis(flat_p, order, axis=-1)
    token_of = order // k  # source token per sorted slot
    onehot = jax.nn.one_hot(sorted_e, e, dtype=jnp.int32)  # (b,tk,e)
    pos_in_e = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=1), sorted_e[..., None], axis=-1
    )[..., 0] - 1  # (b,tk)
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # drop -> OOB

    def scatter_row(xr, token_idx, slot_idx):
        gathered = jnp.take(xr, token_idx, axis=0)  # (tk, d)
        buf = jnp.zeros((e * cap + 1, d), xr.dtype)
        return buf.at[slot_idx].add(gathered)[:-1]

    buf = jax.vmap(scatter_row)(x, token_of, slot)  # (b, e*cap, d)
    buf = buf.reshape(b, e, cap, d)
    gate = jnp.einsum("becd,edf->becf", buf, p["w_gate"])
    up = jnp.einsum("becd,edf->becf", buf, p["w_in"])
    act = jax.nn.silu(gate) * up
    out = jnp.einsum("becf,efd->becd", act, p["w_out"])  # (b,e,cap,d)
    out = out.reshape(b, e * cap, d)

    def gather_row(outr, slot_idx, probs_r, keep_r, token_idx):
        vals = jnp.take(
            jnp.concatenate([outr, jnp.zeros((1, d), outr.dtype)], axis=0),
            slot_idx, axis=0,
        )  # (tk, d)
        vals = vals * (probs_r * keep_r)[:, None].astype(vals.dtype)
        y = jnp.zeros((t, d), outr.dtype)
        return y.at[token_idx].add(vals)

    y = jax.vmap(gather_row)(out, slot, sorted_p, keep.astype(jnp.float32), token_of)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x)
    return y.astype(x.dtype)
