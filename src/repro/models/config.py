"""Model configuration schema for the assigned architectures.

One ``ModelConfig`` drives every family: dense / MoE transformers, Mamba-2
SSMs, Mamba+attention hybrids, encoder-decoder (whisper) and VLM backbones
(paligemma).  ``src/repro/configs/<arch>.py`` instantiates the exact public
configurations; ``smoke()`` shrinks any config to a CPU-testable size of the
same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    shared_expert_ff: int = 0      # llama4-style always-on shared expert
    moe_every: int = 1             # MoE layer every N layers (rest dense)
    capacity_factor: float = 1.25

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0             # N (state size per head)
    ssm_head_dim: int = 64         # P
    ssm_expand: int = 2            # d_inner = expand * d_model
    ssm_conv: int = 4              # causal conv width
    ssm_chunk: int = 128           # SSD chunk length

    # hybrid (zamba2): a shared attention block applied every k SSM blocks
    shared_attn_every: int = 6

    # encoder-decoder (whisper): encoder depth/length (frontend is a stub
    # providing precomputed frame embeddings, per the assignment spec)
    enc_layers: int = 0
    enc_len: int = 1500

    # VLM (paligemma): stubbed SigLIP patch embeddings prepended as a prefix
    vis_prefix_len: int = 256
    vis_embed_dim: int = 1152      # SigLIP-So400m width (stub input dim)

    # llama4: chunked local attention (iRoPE); 0 = full attention
    attn_chunk: int = 0

    # distribution / execution policy
    fsdp: bool = False             # shard weights over the data axis too
    remat: bool = True             # activation checkpointing per layer
    dtype: str = "bfloat16"
    parallelism: str = "tp"        # "tp" | "dp" (dp: no tensor parallelism;
                                   #  batch shards over every mesh axis)
    fsdp_gather: bool = False      # FSDP via per-layer weight all-gather
                                   #  (constraint) instead of GSPMD partial-
                                   #  sum all-reduces of activations
    attn_seq_shard: bool = False   # sequence-parallel attention: shard query
                                   #  time over the model axis (for archs
                                   #  whose head count doesn't divide TP)
    bf16_reduce: bool = False      # accumulate TP output projections in
                                   #  bf16 so cross-chip all-reduces move
                                   #  half the bytes (per-chip MXU partials
                                   #  are still f32 internally)

    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_moe_layer(self, layer: int) -> bool:
        return self.n_experts > 0 and (layer % self.moe_every == self.moe_every - 1)

    # -- parameter counting (for 6ND roofline cross-checks) ------------------

    def param_count(self) -> int:
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd, h, kv = self.hd(), self.n_heads, self.n_kv_heads
        n = 0
        if self.family in ("dense", "moe", "vlm", "hybrid", "ssm", "encdec"):
            n += v * d  # embeddings
            if not self.tie_embeddings:
                n += d * v  # lm head
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        mlp = 3 * d * ff  # gated (swiglu)
        if self.family in ("dense", "vlm"):
            n += self.n_layers * (attn + mlp + 2 * d)
        elif self.family == "moe":
            moe_layers = sum(1 for l in range(self.n_layers) if self.is_moe_layer(l))
            dense_layers = self.n_layers - moe_layers
            expert_mlp = self.n_experts * 3 * d * ff + d * self.n_experts
            shared = 3 * d * self.shared_expert_ff if self.shared_expert_ff else 0
            n += moe_layers * (attn + expert_mlp + shared + 2 * d)
            n += dense_layers * (attn + mlp + 2 * d)
        elif self.family == "ssm":
            n += self.n_layers * self._ssm_block_params()
        elif self.family == "hybrid":
            n += self.n_layers * self._ssm_block_params()
            n += attn + mlp + 2 * d  # one shared attention block
        elif self.family == "encdec":
            n += self.enc_layers * (attn + 2 * d * ff + 2 * d)  # relu mlp
            n += self.n_layers * (2 * attn + 2 * d * ff + 3 * d)  # self+cross
        if self.family == "vlm":
            n += self.vis_embed_dim * d  # projector (frontend itself stubbed)
        return n

    def _ssm_block_params(self) -> int:
        d, di = self.d_model, self.d_inner
        nh, ns = self.ssm_nheads, self.ssm_state
        in_proj = d * (2 * di + 2 * ns + nh)  # z, x, B, C, dt
        conv = self.ssm_conv * (di + 2 * ns)
        out = di * d
        extras = 2 * nh + di + d  # A, D, gated-norm, rmsnorm
        return in_proj + conv + out + extras

    def active_param_count(self) -> int:
        """Active parameters per token (MoE uses top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        moe_layers = sum(1 for l in range(self.n_layers) if self.is_moe_layer(l))
        inactive = moe_layers * (self.n_experts - self.top_k) * 3 * d * ff
        return self.param_count() - inactive


def smoke(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Shrink any config to a CPU-smoke-test size of the same family."""
    small = dict(
        n_layers=2 if cfg.family != "hybrid" else 4,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
        d_ff=128,
        vocab=256,
        head_dim=16,
        enc_layers=2 if cfg.enc_layers else 0,
        enc_len=8 if cfg.enc_layers else 1500,
        n_experts=min(cfg.n_experts, 4),
        shared_expert_ff=64 if cfg.shared_expert_ff else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16,
        ssm_chunk=8,
        shared_attn_every=2,
        vis_prefix_len=4 if cfg.family == "vlm" else cfg.vis_prefix_len,
        vis_embed_dim=32 if cfg.family == "vlm" else cfg.vis_embed_dim,
        fsdp=False,
        remat=False,
        dtype="float32",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
