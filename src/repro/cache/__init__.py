"""ZNS-aware zero-copy cache tier in front of the ZapRAID array.

See :mod:`repro.cache.tier` for the design; DESIGN.md §12 for the writeup.
"""
from repro.cache.sketch import FrequencySketch
from repro.cache.tier import (
    CacheConfig,
    CacheStats,
    ZnsCacheTier,
    meta_key,
    user_key,
)

__all__ = [
    "CacheConfig",
    "CacheStats",
    "FrequencySketch",
    "ZnsCacheTier",
    "meta_key",
    "user_key",
]
