"""Vectorized count-min frequency sketch for cache admission.

TinyLFU-style admission: the sketch counts *misses* per key, and a fill
is admitted only once the key's estimated frequency reaches the
configured threshold.  One-touch scan traffic therefore never displaces
resident blocks, while anything in the zipf/hotspot working set clears
the bar on its second access.

Everything is batched numpy: hashing is multiply-shift over uint64
(wrapping multiply, xor-shift mix), updates are one ``np.add.at`` per
hash row, and estimates are a row-wise ``np.minimum`` reduction.  The
sketch ages by halving every counter after a fixed number of updates,
so stale popularity decays instead of pinning the admission gate open.
"""
from __future__ import annotations

import numpy as np


class FrequencySketch:
    """Count-min sketch over int64 cache keys, width must be a power of two."""

    def __init__(
        self,
        width: int = 1024,
        n_hashes: int = 4,
        decay_every: int | None = None,
        seed: int = 0xCAFE,
    ) -> None:
        if width <= 0 or width & (width - 1):
            raise ValueError("sketch width must be a power of two")
        self.width = width
        self._mask = np.uint64(width - 1)
        self.table = np.zeros((n_hashes, width), dtype=np.uint32)
        rng = np.random.default_rng(seed)
        # Odd multipliers so the multiply-shift hash is a bijection on u64.
        self.salts = rng.integers(1, 1 << 62, size=n_hashes, dtype=np.uint64)
        self.salts = (self.salts << np.uint64(1)) | np.uint64(1)
        self.decay_every = int(decay_every) if decay_every else width * 8
        self._updates = 0

    def _rows(self, keys: np.ndarray) -> np.ndarray:
        k = keys.astype(np.uint64, copy=False)
        h = k[None, :] * self.salts[:, None]  # wraps mod 2^64
        h ^= h >> np.uint64(33)
        return (h & self._mask).astype(np.int64)

    def add(self, keys: np.ndarray) -> None:
        """Count one access for each key (duplicates count individually)."""
        keys = np.asarray(keys)
        if keys.size == 0:
            return
        rows = self._rows(keys)
        for r in range(self.table.shape[0]):
            np.add.at(self.table[r], rows[r], 1)
        self._updates += int(keys.size)
        if self._updates >= self.decay_every:
            self.table >>= 1
            self._updates = 0

    def estimate(self, keys: np.ndarray) -> np.ndarray:
        """Estimated access count per key (count-min upper bound)."""
        keys = np.asarray(keys)
        if keys.size == 0:
            return np.zeros(0, dtype=np.uint32)
        rows = self._rows(keys)
        est = self.table[0][rows[0]]
        for r in range(1, self.table.shape[0]):
            est = np.minimum(est, self.table[r][rows[r]])
        return est

    def clear(self) -> None:
        self.table[:] = 0
        self._updates = 0
