"""ZNS-aware device-resident cache tier.

The cache models a small fast device (CMB/DRAM tier or a cache-grade
ZNS namespace) sitting in front of the ZapRAID array.  Its layout
mirrors the array's own staging arenas (`core/array.py`):

* **Arena** — one int32-packed payload arena ``data_i32`` of
  ``n_zones * zone_cap_blocks`` slots with a uint8 view ``data_u8``,
  exactly the representation the write-path arenas and the fused
  encode kernels use, so promotion on read-fill and demotion are plain
  row gathers with zero host repacking.
* **Zones** — slots are grouped into zones filled append-only through a
  per-zone write pointer.  Eviction is *segment/zone-granular*: a whole
  victim zone is reset at once (reset-friendly, like the flash-cache
  paper), never block-by-block.  The victim is the full zone with the
  fewest referenced live blocks (CLOCK at zone granularity: every reset
  is one clock tick that clears all reference bits, so survivors must
  be re-referenced to stay protected).
* **Keys** — the cache indexes *logical* keys using the array's LSB
  discrimination trick: ``lba << 1`` for user blocks and
  ``(gid << 1) | 1`` for offloaded L2P mapping blocks.  Because keys are
  logical, GC relocation and drive rebuild (which move physical copies
  only) need no cache maintenance at all; the only coherence points are
  commit-time refresh on overwrite and mapping-block commit.
* **Admission** — a count-min :class:`~repro.cache.sketch.FrequencySketch`
  counts misses; a read-fill is admitted only once the key has been
  seen ``admit_threshold`` times, so one-touch scans never displace the
  working set.  Mapping blocks and explicit warm fills bypass the gate
  (``force=True``) — they are small metadata in ZapRAID's own spirit.

All bookkeeping (lookup, fill, refresh, invalidate, zone reset) is
vectorized over numpy bitmaps; there are no per-block Python loops on
the batched paths.

Write policy is write-through refresh: a committed overwrite updates a
resident copy in place and never dirties the cache, so demotion is a
zone reset with no writeback.

When a :class:`repro.sim.device.TimedCacheDevice` is attached, every
batch of hits books cache-device service time on the virtual clock via
``engine.touch_io``, so the timed handler pipeline automatically
completes cache hits at cache-tier latency instead of NAND latency.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.cache.sketch import FrequencySketch

NO_SLOT = -1


def user_key(lba: int) -> int:
    """Cache key for a user logical block (LSB 0, like the OOB encoding)."""
    return lba << 1


def meta_key(gid: int) -> int:
    """Cache key for an offloaded L2P mapping-group block (LSB 1)."""
    return (gid << 1) | 1


@dataclasses.dataclass
class CacheConfig:
    """Geometry + policy for the cache tier.

    The arena holds ``n_zones * zone_cap_blocks`` block slots of
    ``block_bytes`` each (``block_bytes`` must be int32-aligned).
    """

    n_zones: int = 8
    zone_cap_blocks: int = 64
    block_bytes: int = 256
    admit_threshold: int = 2
    sketch_width: int = 1024
    sketch_hashes: int = 4
    sketch_decay_every: int | None = None
    sketch_seed: int = 0xCAFE


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    fills: int = 0
    refreshes: int = 0
    rejects: int = 0
    invalidations: int = 0
    zone_resets: int = 0

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)


class ZnsCacheTier:
    """Zone-structured, logically-keyed block cache (see module docstring)."""

    def __init__(
        self,
        cfg: CacheConfig,
        logical_blocks: int,
        timed_dev=None,
    ) -> None:
        if cfg.block_bytes % 4 != 0:
            raise ValueError("block_bytes must be a multiple of 4 (int32 lanes)")
        self.cfg = cfg
        self.n_slots = cfg.n_zones * cfg.zone_cap_blocks
        lanes = cfg.block_bytes // 4
        # Same packing as _StripeArena: int32 arena + uint8 view, one buffer.
        self.data_i32 = np.zeros((self.n_slots, lanes), dtype=np.int32)
        self.data_u8 = self.data_i32.view(np.uint8).reshape(
            self.n_slots, cfg.block_bytes
        )
        # keys[slot] = cache key resident in that slot, -1 if empty/invalid.
        self.keys = np.full(self.n_slots, -1, dtype=np.int64)
        # Direct-map index over the (user | meta) key space: key -> slot.
        self.slot_of = np.full(2 * logical_blocks, NO_SLOT, dtype=np.int64)
        # CLOCK reference bitmap, cleared wholesale on every zone reset.
        self.ref = np.zeros(self.n_slots, dtype=np.uint8)
        # Per-zone write pointer (blocks filled) and fill generation.
        self.wp = np.zeros(cfg.n_zones, dtype=np.int64)
        self.zone_seq = np.zeros(cfg.n_zones, dtype=np.int64)
        self._seq = 1
        self.zone_seq[0] = 1
        self.active = 0
        self.sketch = FrequencySketch(
            width=cfg.sketch_width,
            n_hashes=cfg.sketch_hashes,
            decay_every=cfg.sketch_decay_every,
            seed=cfg.sketch_seed,
        )
        self.stats = CacheStats()
        self.timed_dev = timed_dev
        # Observability hook (repro.obs via repro.core.handlers): called as
        # ``obs_event(name, **args)`` on lookups and zone resets.  None (the
        # default) keeps the batched paths at one attribute test.
        self.obs_event = None

    # ------------------------------------------------------------- lookup

    def lookup_many(self, keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Batched probe: returns ``(hit_mask, hit_rows)``.

        ``hit_rows`` are the payloads for ``keys[hit_mask]`` in order.
        Hits set reference bits and book cache-device time; misses feed
        the admission sketch.
        """
        keys = np.asarray(keys, dtype=np.int64)
        slots = self.slot_of[keys]
        hit = slots >= 0
        n_hit = int(np.count_nonzero(hit))
        self.stats.hits += n_hit
        self.stats.misses += int(keys.size) - n_hit
        if self.obs_event is not None:
            self.obs_event("cache.lookup", hits=n_hit,
                           misses=int(keys.size) - n_hit)
        if n_hit:
            hs = slots[hit]
            self.ref[hs] = 1
            self._book(n_hit)
            rows = self.data_u8[hs]
        else:
            rows = np.zeros((0, self.cfg.block_bytes), dtype=np.uint8)
        miss_keys = keys[~hit]
        if miss_keys.size:
            self.sketch.add(miss_keys)
        return hit, rows

    def lookup_one(self, key: int) -> np.ndarray | None:
        """Scalar probe; returns a payload view or None on miss."""
        slot = int(self.slot_of[key])
        if slot < 0:
            self.stats.misses += 1
            self.sketch.add(np.array([key], dtype=np.int64))
            if self.obs_event is not None:
                self.obs_event("cache.lookup", hits=0, misses=1)
            return None
        self.stats.hits += 1
        self.ref[slot] = 1
        self._book(1)
        if self.obs_event is not None:
            self.obs_event("cache.lookup", hits=1, misses=0)
        return self.data_u8[slot]

    def contains_many(self, keys: np.ndarray) -> np.ndarray:
        """Side-effect-free residency mask (no stats, no ref bits)."""
        return self.slot_of[np.asarray(keys, dtype=np.int64)] >= 0

    def contains_run(self, lba: int, n_blocks: int) -> bool:
        """True iff user blocks ``[lba, lba + n_blocks)`` are all resident."""
        if n_blocks == 1:
            return bool(self.slot_of[lba << 1] >= 0)
        keys = np.arange(lba, lba + n_blocks, dtype=np.int64) << 1
        return bool((self.slot_of[keys] >= 0).all())

    def gather_packed(self, slots: np.ndarray) -> np.ndarray:
        """Int32-lane gather of resident rows (zero-copy handoff shape)."""
        return self.data_i32[np.asarray(slots, dtype=np.int64)]

    # --------------------------------------------------------------- fill

    def fill_many(
        self, keys: np.ndarray, blocks: np.ndarray, *, force: bool = False
    ) -> None:
        """Read-fill / promotion path.

        Keys already resident are refreshed in place.  New keys pass the
        frequency-sketch admission gate unless ``force`` is set, then
        are appended at the active zone's write pointer; zone resets
        happen inline when the arena is full.  Bookkeeping is committed
        chunk-by-chunk as zones fill so a victim reset always sees a
        consistent index, even if it cannibalizes an earlier chunk of
        the same batch.
        """
        keys = np.asarray(keys, dtype=np.int64)
        if keys.size == 0:
            return
        blocks = np.asarray(blocks, dtype=np.uint8).reshape(
            keys.size, self.cfg.block_bytes
        )
        slots = self.slot_of[keys]
        present = slots >= 0
        n_present = int(np.count_nonzero(present))
        if n_present:
            ps = slots[present]
            self.data_u8[ps] = blocks[present]
            self.ref[ps] = 1
            self.stats.refreshes += n_present
        new = ~present
        if not new.any():
            return
        nk = keys[new]
        nb = blocks[new]
        if not force:
            admit = self.sketch.estimate(nk) >= self.cfg.admit_threshold
            n_rej = int(nk.size - np.count_nonzero(admit))
            if n_rej:
                self.stats.rejects += n_rej
            nk = nk[admit]
            nb = nb[admit]
        if nk.size == 0:
            return
        # Dedupe within the batch (a read batch may repeat an LBA).
        if nk.size > 1:
            _, first = np.unique(nk, return_index=True)
            if first.size != nk.size:
                first.sort()
                nk = nk[first]
                nb = nb[first]
        self._append(nk, nb)
        self.stats.fills += int(nk.size)

    def fill_one(self, key: int, block: np.ndarray, *, force: bool = False) -> None:
        self.fill_many(
            np.array([key], dtype=np.int64), block[None, :], force=force
        )

    def _append(self, nk: np.ndarray, nb: np.ndarray) -> None:
        cap = self.cfg.zone_cap_blocks
        got = 0
        n = int(nk.size)
        while got < n:
            if self.wp[self.active] == cap:
                self.active = self._next_zone()
            space = cap - int(self.wp[self.active])
            take = min(n - got, space)
            base = self.active * cap + int(self.wp[self.active])
            sl = np.arange(base, base + take, dtype=np.int64)
            self.wp[self.active] += take
            kk = nk[got : got + take]
            self.data_u8[sl] = nb[got : got + take]
            self.keys[sl] = kk
            self.slot_of[kk] = sl
            self.ref[sl] = 1  # one zone-reset grace period for fresh fills
            got += take

    def _next_zone(self) -> int:
        empty = np.flatnonzero(self.wp == 0)
        z = int(empty[0]) if empty.size else self._evict_zone()
        self._seq += 1
        self.zone_seq[z] = self._seq
        return z

    def _evict_zone(self) -> int:
        """Zone-granular CLOCK: reset the zone with the fewest referenced
        live blocks (live count breaks ties, then oldest fill)."""
        cap = self.cfg.zone_cap_blocks
        live = (self.keys >= 0).reshape(self.cfg.n_zones, cap)
        refd = (self.ref > 0).reshape(self.cfg.n_zones, cap) & live
        score = refd.sum(axis=1) * (cap + 1) + live.sum(axis=1)
        z = int(np.lexsort((self.zone_seq, score))[0])
        self._reset_zone(z)
        return z

    def _reset_zone(self, z: int) -> None:
        cap = self.cfg.zone_cap_blocks
        sl = slice(z * cap, (z + 1) * cap)
        ks = self.keys[sl]
        livek = ks[ks >= 0]
        if livek.size:
            self.slot_of[livek] = NO_SLOT
        self.keys[sl] = -1
        self.wp[z] = 0
        # One clock tick: every resident block must be re-referenced to
        # stay protected through the next reset.
        self.ref[:] = 0
        self.stats.zone_resets += 1
        if self.obs_event is not None:
            self.obs_event("cache.zone_reset", zone=z, evicted=int(livek.size))

    # ---------------------------------------------------------- coherence

    def refresh_many(self, keys: np.ndarray, blocks: np.ndarray) -> None:
        """Write-path coherence: update resident copies in place.

        Non-resident keys are left alone (no write-allocate) — the read
        path re-fills them on demand if they stay hot.
        """
        keys = np.asarray(keys, dtype=np.int64)
        if keys.size == 0:
            return
        slots = self.slot_of[keys]
        m = slots >= 0
        n = int(np.count_nonzero(m))
        if not n:
            return
        blocks = np.asarray(blocks, dtype=np.uint8).reshape(
            keys.size, self.cfg.block_bytes
        )
        ms = slots[m]
        self.data_u8[ms] = blocks[m]
        self.ref[ms] = 1
        self.stats.refreshes += n

    def refresh_one(self, key: int, block: np.ndarray) -> None:
        slot = int(self.slot_of[key])
        if slot < 0:
            return
        self.data_u8[slot] = block
        self.ref[slot] = 1
        self.stats.refreshes += 1

    def invalidate_many(self, keys: np.ndarray) -> None:
        keys = np.asarray(keys, dtype=np.int64)
        if keys.size == 0:
            return
        slots = self.slot_of[keys]
        m = slots >= 0
        n = int(np.count_nonzero(m))
        if not n:
            return
        ms = slots[m]
        self.keys[ms] = -1
        self.ref[ms] = 0
        self.slot_of[keys[m]] = NO_SLOT
        self.stats.invalidations += n

    def invalidate_one(self, key: int) -> None:
        slot = int(self.slot_of[key])
        if slot < 0:
            return
        self.keys[slot] = -1
        self.ref[slot] = 0
        self.slot_of[key] = NO_SLOT
        self.stats.invalidations += 1

    # ------------------------------------------------------------- timing

    def _book(self, n_blocks: int) -> None:
        if self.timed_dev is not None:
            self.timed_dev.book_read(n_blocks, self.timed_dev.engine.now)

    def reset_timing(self) -> None:
        if self.timed_dev is not None:
            self.timed_dev.reset_timing()

    # --------------------------------------------------------------- misc

    def clear(self) -> None:
        """Drop all contents and counters (cold cache)."""
        self.data_i32[:] = 0
        self.keys[:] = -1
        self.slot_of[:] = NO_SLOT
        self.ref[:] = 0
        self.wp[:] = 0
        self.zone_seq[:] = 0
        self._seq = 1
        self.zone_seq[0] = 1
        self.active = 0
        self.sketch.clear()
        self.stats.reset()

    def resident_count(self) -> int:
        return int(np.count_nonzero(self.keys >= 0))
