"""Gradient compression for bandwidth-bound data-parallel training.

Two composable compressors, both with error feedback (the residual is
carried in the train state so compression error accumulates into later
steps instead of being lost):

* ``int8``  -- symmetric per-tensor quantization before the (simulated)
  all-reduce: 4x wire reduction on fp32 grads, 2x on bf16.
* ``topk``  -- keep the top rho fraction of entries by magnitude (with a
  deterministic threshold estimated from the tensor's moments, avoiding a
  full sort on TPU), zeroing the rest.

With pjit, gradients are reduced by XLA inside the backward pass, so the
compressor runs *before* the optimizer applies updates -- this matches
error-feedback SGD formulations (the compression is applied to the summed
gradient; wire-level compression is modeled for the roofline in
EXPERIMENTS.md, and exact on real deployments that use
``jax.experimental.custom_partitioning`` reduce hooks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(g: jax.Array) -> jax.Array:
    """Quantize-dequantize to int8 (symmetric, per tensor)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return (q.astype(jnp.float32) * scale).astype(g.dtype)


def compress_topk(g: jax.Array, rho: float = 0.05) -> jax.Array:
    """Magnitude sparsification keeping ~rho of entries.

    The threshold is estimated as mean + z*std of |g| (z chosen from rho via
    a Gaussian tail approximation) -- O(n) instead of O(n log n), which is
    what production gradient-sparsification systems do on accelerators.
    """
    gf = g.astype(jnp.float32)
    a = jnp.abs(gf)
    mu = jnp.mean(a)
    sd = jnp.std(a) + 1e-12
    # z such that P(|x| > mu + z sd) ~ rho for a half-normal-ish tail
    z = jnp.sqrt(jnp.maximum(0.0, -2.0 * jnp.log(jnp.asarray(rho))))
    thr = mu + (z - 1.0) * sd
    return jnp.where(a >= thr, gf, 0.0).astype(g.dtype)


def apply_compression(grads, residual, kind: str):
    """Error-feedback compression: compress(g + r); r' = (g + r) - c."""
    if kind == "none":
        return grads, residual

    fn = {"int8": compress_int8, "topk": compress_topk}[kind]

    def one(g, r):
        full = g.astype(jnp.float32) + r
        c = fn(full)
        return c.astype(g.dtype), full - c.astype(jnp.float32)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        jax.tree.unflatten(tdef, [o[0] for o in outs]),
        jax.tree.unflatten(tdef, [o[1] for o in outs]),
    )


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
