"""Elastic training runtime: failure handling, re-meshing, and group-bounded
commit scheduling (ZapRAID's §3.2 insight applied to distributed training).

Components:

* ``RankTable`` / ``ElasticRuntime`` -- heartbeat bookkeeping; on failure it
  plans the largest viable (data x model) mesh from surviving hosts, and the
  driver restores from the ZapRAID checkpoint (degraded restore if the lost
  host held a storage lane) and re-jits on the new mesh.  State resharding
  is free under GSPMD: global arrays are simply re-sharded by the new mesh.

* ``GroupCommitScheduler`` -- the paper's stripe-group idea applied to
  gradient commits: instead of a hard barrier every step (Zone-Write-like,
  one outstanding step), workers may run ahead within a *commit group* of G
  steps and complete out of order; a barrier lands only at group boundaries,
  and bounded metadata (G-entry commit table per group, the CST analogue)
  tracks which worker finished which step.  ``simulate`` quantifies the
  straggler-stall reduction under heavy-tailed per-step latencies -- the
  training-side reproduction of Figure 8's G-sweep.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np


# --------------------------------------------------------------------- ranks

@dataclasses.dataclass
class RankInfo:
    rank: int
    healthy: bool = True
    last_heartbeat: float = 0.0


class RankTable:
    def __init__(self, n_ranks: int):
        self.ranks = {r: RankInfo(r) for r in range(n_ranks)}

    def heartbeat(self, rank: int, now: float) -> None:
        self.ranks[rank].last_heartbeat = now
        self.ranks[rank].healthy = True

    def sweep(self, now: float, timeout: float) -> list[int]:
        dead = []
        for r, info in self.ranks.items():
            if info.healthy and now - info.last_heartbeat > timeout:
                info.healthy = False
                dead.append(r)
        return dead

    def healthy(self) -> list[int]:
        return [r for r, i in self.ranks.items() if i.healthy]


class ElasticRuntime:
    """Plans mesh changes as hosts fail/join."""

    def __init__(self, n_hosts: int, chips_per_host: int, model_parallel: int,
                 heartbeat_timeout: float = 30.0):
        self.table = RankTable(n_hosts)
        self.chips_per_host = chips_per_host
        self.model_parallel = model_parallel
        self.timeout = heartbeat_timeout
        self.generation = 0

    def plan_mesh(self) -> tuple[int, int]:
        """Largest (data, model) mesh from healthy hosts.  The model axis is
        fixed (weights are TP-sharded); the data axis shrinks to the largest
        power-of-two of remaining chips."""
        chips = len(self.table.healthy()) * self.chips_per_host
        data = chips // self.model_parallel
        data_pow2 = 1 << max(0, (data.bit_length() - 1))
        return (data_pow2, self.model_parallel)

    def on_failure(self, dead_ranks: list[int]) -> dict:
        for r in dead_ranks:
            self.table.ranks[r].healthy = False
        self.generation += 1
        data, model = self.plan_mesh()
        return {
            "generation": self.generation,
            "mesh": (data, model),
            "healthy_hosts": len(self.table.healthy()),
            "action": "restore_from_checkpoint_and_rejit",
        }

    def on_join(self, rank: int) -> dict:
        self.table.ranks[rank] = RankInfo(rank, healthy=True)
        self.generation += 1
        data, model = self.plan_mesh()
        return {"generation": self.generation, "mesh": (data, model)}


# --------------------------------------------------- group-bounded commits

@dataclasses.dataclass
class GroupCommitStats:
    steps: int
    group_size: int
    makespan: float
    barrier_stall: float
    per_step_barrier_makespan: float

    @property
    def speedup(self) -> float:
        return self.per_step_barrier_makespan / self.makespan


class GroupCommitScheduler:
    """Discrete-event model of group-bounded out-of-order commits.

    Workers process steps with i.i.d. heavy-tailed latencies.  Under a
    per-step barrier (G=1, the Zone-Write analogue) every step waits for the
    slowest worker.  With a commit group of G steps (Zone-Append analogue),
    each worker runs its G steps asynchronously and the barrier lands only
    at the group boundary -- stalls amortize exactly like the paper's
    intra-zone parallelism, at the cost of a G-entry commit table per group
    (compact-stripe-table analogue, ceil(log2 G) bits per entry).
    """

    def __init__(self, n_workers: int, *, mean: float = 1.0,
                 straggle_p: float = 0.05, straggle_factor: float = 4.0,
                 seed: int = 0):
        self.n = n_workers
        self.mean = mean
        self.p = straggle_p
        self.f = straggle_factor
        self.rng = np.random.default_rng(seed)

    def _latencies(self, steps: int) -> np.ndarray:
        base = self.rng.exponential(self.mean * 0.2, (steps, self.n)) + self.mean * 0.8
        straggle = self.rng.random((steps, self.n)) < self.p
        return np.where(straggle, base * self.f, base)

    def simulate(self, steps: int, group_size: int) -> GroupCommitStats:
        lat = self._latencies(steps)
        g = max(1, group_size)
        n_groups = math.ceil(steps / g)
        makespan = 0.0
        stall = 0.0
        for gi in range(n_groups):
            block = lat[gi * g : (gi + 1) * g]  # (<=g, n)
            per_worker = block.sum(axis=0)  # async within the group
            t = per_worker.max()
            makespan += t
            stall += t * self.n - per_worker.sum()
        # per-step barrier baseline on the same latency draws
        base = lat.max(axis=1).sum()
        return GroupCommitStats(
            steps=steps, group_size=g, makespan=makespan,
            barrier_stall=stall, per_step_barrier_makespan=base,
        )

    def commit_table_bits(self, group_size: int) -> int:
        """CST-analogue metadata cost per commit group."""
        return self.n * group_size * max(1, math.ceil(math.log2(max(group_size, 2))))
