"""Logical-axis -> mesh-axis resolution (GSPMD partitioning rules).

Model code annotates every parameter dimension with a *logical* axis name
("heads", "ff", "vocab", "experts", ...).  This module resolves those names
against a physical mesh:

* tensor-parallel axes map to ``model``;
* with FSDP enabled, the ``embed`` (d_model) dimension of weight matrices is
  additionally sharded over the data axes (``("pod","data")`` on the
  multi-pod mesh) -- ZeRO-3-style weight sharding;
* a dimension only receives a mesh axis if its size is divisible by the mesh
  axis size (e.g. grok's 8 experts do NOT divide a 16-way model axis, so the
  resolver falls through to sharding the expert *ffn* dimension instead --
  TP-inside-expert; llama4's 16 experts DO divide it -- true EP);
* each mesh axis is used at most once per tensor.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def mesh_rules(mesh: Mesh, *, fsdp: bool = False, tp: bool = True) -> dict:
    """logical axis -> mesh axis (str or tuple) for this mesh."""
    names = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    model = ("model" if "model" in names else None) if tp else None
    rules = {
        "vocab": model,
        "heads": model,
        "kv": model,
        "ff": model,
        "experts": model,
        "ssm_inner": model,
        "ssm_heads": model,
        "ssm_conv_ch": model,
        "embed": (data_axes if fsdp and data_axes else None),
        "layers": None,
        None: None,
    }
    return rules


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def resolve_spec(shape: tuple, axes: tuple, rules: dict, mesh: Mesh) -> P:
    """Build a PartitionSpec for one tensor, honoring divisibility and
    single-use-per-mesh-axis constraints."""
    assert len(shape) == len(axes), (shape, axes)
    used: set = set()
    out = []
    for dim, logical in zip(shape, axes):
        mesh_axis = rules.get(logical)
        if mesh_axis is None:
            out.append(None)
            continue
        flat = mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,)
        if any(a in used for a in flat):
            out.append(None)
            continue
        if dim % _axis_size(mesh, mesh_axis) != 0:
            out.append(None)
            continue
        used.update(flat)
        out.append(mesh_axis)
    return P(*out)


def param_specs(param_shapes, axes_tree, mesh: Mesh, *, fsdp: bool = False,
                tp: bool = True):
    """PartitionSpec tree for a params tree (shapes from jax.eval_shape)."""
    rules = mesh_rules(mesh, fsdp=fsdp, tp=tp)

    def leaf(shape_leaf, ax):
        return resolve_spec(tuple(shape_leaf.shape), ax, rules, mesh)

    return jax.tree.map(
        leaf, param_shapes, axes_tree,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict),
    )


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def batch_axes(mesh: Mesh):
    """Mesh axes used for data parallelism (batch dimension)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_spec(mesh: Mesh, ndim: int, *, batch_dim: int = 0,
              batch_size: Optional[int] = None,
              include_model: bool = False) -> P:
    """Batch-over-data-axes spec; leaves the batch replicated if its size
    does not divide the data-parallel degree (e.g. long_500k's batch of 1).
    With ``include_model`` (pure-DP profiles) the batch also shards over the
    model axis."""
    dp = batch_axes(mesh)
    if include_model and "model" in mesh.axis_names:
        dp = dp + ("model",)
    parts = [None] * ndim
    if dp and (batch_size is None or batch_size % _axis_size(mesh, dp) == 0):
        parts[batch_dim] = dp if len(dp) > 1 else dp[0]
    return P(*parts)


def cache_spec(mesh: Mesh, shape: tuple, kv_heads_dim: int, seq_dim: int,
               batch_dim: int = 1) -> P:
    """KV-cache spec: batch over data axes; kv-heads over model when
    divisible, else sequence over model (cache sequence parallelism)."""
    dp = batch_axes(mesh)
    parts: list = [None] * len(shape)
    if dp and shape[batch_dim] % _axis_size(mesh, dp) == 0:
        parts[batch_dim] = dp
    model = "model" if "model" in mesh.axis_names else None
    if model:
        msz = mesh.shape[model]
        if shape[kv_heads_dim] % msz == 0 and shape[kv_heads_dim] >= msz:
            parts[kv_heads_dim] = model
        elif shape[seq_dim] % msz == 0:
            parts[seq_dim] = model
    return P(*parts)
