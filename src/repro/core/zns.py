"""Simulated ZNS SSD device model.

Faithful functional model of the paper's device abstraction (§2.1):

* append-only zones with per-zone write pointers and EMPTY/OPEN/FULL states;
* 4 KiB logical blocks (configurable) with a per-page out-of-band (OOB)
  metadata area (LBA u64, write-timestamp u64, stripe-id u32 -- 20 bytes, as
  in §3.1);
* ``zone_write`` -- ordered, offset must equal the write pointer, one
  outstanding command per zone;
* ``zone_append`` -- device assigns the offset and returns it; a *batch* of
  appends to one zone may complete in any order (the device model permutes
  completion order with a seeded RNG -- this is exactly the disorder the
  compact stripe table must absorb);
* explicit ``reset_zone`` / ``finish_zone``; bounded open zones.

Crash injection: the array owns a shared ``CrashBudget``; every block commit
decrements it, and when it hits zero the device stops persisting (simulating
power loss mid-group).  Completed commits stay durable, exactly like NAND.

Integrity (PR 10): every committed block carries a CRC32C in a per-block
checksum store (``self.crc``, the simulated DIF/OOB checksum lane).  The
store always reflects what the *host* wrote -- media faults
(:meth:`corrupt_bit_rot`, :meth:`corrupt_torn_write`,
:meth:`corrupt_misdirected_write`, :meth:`mark_unreadable`) perturb the
data plane or the UNC mask only, so a verify pass detects them as
checksum mismatches / unreadable sectors.  Reads keep their historical
non-raising contract; verification layers (``array`` verify-on-read, the
scrub actor, recovery scans) consult :meth:`crc_blocks` /
:meth:`unc_blocks` and repair in place via :meth:`repair_blocks`.

The data plane (block payloads) lives in numpy; parity math over it runs
through the JAX/Pallas kernels in ``repro.kernels``.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np

from repro.integrity.checksum import crc32c_many

OOB_DTYPE = np.dtype([("lba", "<u8"), ("ts", "<u8"), ("stripe", "<u4")])
OOB_ENTRY_BYTES = 20  # paper §3.1: 8 (LBA) + 8 (timestamp) + 4 (stripe id)
INVALID_LBA = np.uint64(0xFFFFFFFFFFFFFFFF)


class ZoneState(enum.IntEnum):
    EMPTY = 0
    OPEN = 1
    FULL = 2
    OFFLINE = 3


class DeviceCrashed(Exception):
    """Raised when a write is attempted after the crash budget is exhausted."""


class DriveFailed(Exception):
    """Raised when reading a failed drive."""


class UncorrectableError(Exception):
    """UNC-style media error: a block is flagged unreadable.

    Raised by the *verifying* read layers (``read_verified`` here, the
    array's verify-on-read / scrub paths) when a gather touches a sector
    the device can no longer return -- the host must reconstruct it from
    parity or surface the loss loudly."""


class TooManyOpenZones(Exception):
    """Raised when opening a zone would exceed ``ZnsConfig.max_open_zones``.

    The paper (§2.1) bounds the number of simultaneously open zones -- the
    device holds per-open-zone buffer/XOR resources -- so the controller must
    seal or reset before opening more."""


@dataclasses.dataclass
class ZnsConfig:
    n_zones: int = 16
    zone_cap_blocks: int = 1024  # zone capacity in blocks
    block_bytes: int = 4096
    max_open_zones: int = 8

    @property
    def capacity_blocks(self) -> int:
        return self.n_zones * self.zone_cap_blocks


class CrashBudget:
    """Shared block-commit budget for crash injection (None = no crash)."""

    def __init__(self, blocks: Optional[int] = None):
        self.remaining = blocks

    def consume(self) -> bool:
        """Consume one block commit; False if the power is already out."""
        if self.remaining is None:
            return True
        if self.remaining <= 0:
            return False
        self.remaining -= 1
        return True


class SimZnsDrive:
    """One simulated ZNS SSD."""

    def __init__(self, cfg: ZnsConfig, drive_id: int, budget: Optional[CrashBudget] = None):
        self.cfg = cfg
        self.drive_id = drive_id
        self.budget = budget or CrashBudget(None)
        self.data = np.zeros(
            (cfg.n_zones, cfg.zone_cap_blocks, cfg.block_bytes), dtype=np.uint8
        )
        self.oob = np.zeros((cfg.n_zones, cfg.zone_cap_blocks), dtype=OOB_DTYPE)
        self.oob["lba"] = INVALID_LBA
        # Per-block CRC32C store (simulated DIF lane) + unreadable mask.
        self.crc = np.zeros((cfg.n_zones, cfg.zone_cap_blocks), dtype=np.uint32)
        self.unc = np.zeros((cfg.n_zones, cfg.zone_cap_blocks), dtype=bool)
        self.wp = np.zeros(cfg.n_zones, dtype=np.int64)
        self.state = np.full(cfg.n_zones, ZoneState.EMPTY, dtype=np.int32)
        self.failed = False
        # Device counters (used by benchmarks / write-amplification accounting)
        self.blocks_written = 0
        self.zone_resets = 0
        self.media_faults = 0      # injected sub-drive faults (all kinds)
        self.blocks_repaired = 0   # in-place repairs via repair_blocks

    # -- state management ---------------------------------------------------

    def _check_alive(self):
        if self.failed:
            raise DriveFailed(f"drive {self.drive_id} failed")

    def open_zone_count(self) -> int:
        return int(np.sum(self.state == ZoneState.OPEN))

    def _open_zone(self, zone: int) -> None:
        """EMPTY -> OPEN transition, enforcing the bounded-open-zones limit."""
        if self.state[zone] != ZoneState.EMPTY:
            return
        if self.open_zone_count() >= self.cfg.max_open_zones:
            raise TooManyOpenZones(
                f"drive {self.drive_id}: opening zone {zone} would exceed "
                f"max_open_zones={self.cfg.max_open_zones}"
            )
        self.state[zone] = ZoneState.OPEN

    def reset_zone(self, zone: int) -> None:
        self._check_alive()
        self.wp[zone] = 0
        self.state[zone] = ZoneState.EMPTY
        self.data[zone] = 0
        self.oob[zone] = np.zeros((), dtype=OOB_DTYPE)
        self.oob[zone]["lba"] = INVALID_LBA
        self.crc[zone] = 0
        self.unc[zone] = False
        self.zone_resets += 1

    def finish_zone(self, zone: int) -> None:
        self._check_alive()
        self.state[zone] = ZoneState.FULL

    # -- writes -------------------------------------------------------------

    def _commit_block(self, zone: int, block: np.ndarray, oob_entry, crc=None) -> bool:
        """Persist one block at the write pointer.  False => power lost."""
        if not self.budget.consume():
            return False
        off = int(self.wp[zone])
        assert off < self.cfg.zone_cap_blocks, (zone, off)
        self.data[zone, off] = block
        self.oob[zone, off] = oob_entry
        self.crc[zone, off] = crc if crc is not None \
            else crc32c_many(block[None])[0]
        self.unc[zone, off] = False
        self.wp[zone] = off + 1
        self.blocks_written += 1
        if self.wp[zone] == self.cfg.zone_cap_blocks:
            self.state[zone] = ZoneState.FULL
        return True

    def _commit_blocks(
        self, zone: int, blocks: np.ndarray, oobs: np.ndarray, crcs=None
    ) -> None:
        """Persist a contiguous run of blocks at the write pointer.

        When no crash budget is armed the whole run lands in two slice
        assignments (the hot path for group commits); with a budget armed we
        fall back to per-block commits so power loss cuts at exact block
        granularity, like NAND.

        ``crcs`` lets the caller pass checksums it already computed on the
        packed arenas (the group committer does one vectorized pass over
        the whole codeword); otherwise they are computed here.
        """
        n = blocks.shape[0]
        if crcs is None:
            crcs = crc32c_many(blocks)
        if self.budget.remaining is None:
            off = int(self.wp[zone])
            assert off + n <= self.cfg.zone_cap_blocks, (zone, off, n)
            self.data[zone, off : off + n] = blocks
            self.oob[zone, off : off + n] = oobs
            self.crc[zone, off : off + n] = crcs
            self.unc[zone, off : off + n] = False
            self.wp[zone] = off + n
            self.blocks_written += n
            if self.wp[zone] == self.cfg.zone_cap_blocks:
                self.state[zone] = ZoneState.FULL
            return
        for i in range(n):
            if not self._commit_block(zone, blocks[i], oobs[i], crcs[i]):
                raise DeviceCrashed(f"crash on drive={self.drive_id}")

    def zone_write(
        self, zone: int, offset: int, blocks: np.ndarray, oobs: np.ndarray, crcs=None
    ) -> None:
        """Ordered write: ``offset`` must equal the zone write pointer."""
        self._check_alive()
        if offset != int(self.wp[zone]):
            raise ValueError(
                f"zone_write offset {offset} != wp {int(self.wp[zone])} (zone {zone})"
            )
        self._open_zone(zone)
        self._commit_blocks(zone, blocks, oobs, crcs)

    def zone_append_begin(self, zone: int) -> None:
        self._check_alive()
        self._open_zone(zone)

    def zone_append_commit(
        self, zone: int, blocks: np.ndarray, oobs: np.ndarray, crcs=None
    ) -> int:
        """Commit one append command (a contiguous chunk); returns its offset.

        The *caller* (the array's group committer) is responsible for issuing
        commands of a batch in permuted completion order; the device only
        guarantees that each command lands contiguously at the current wp.
        """
        self._check_alive()
        self._open_zone(zone)
        off = int(self.wp[zone])
        self._commit_blocks(zone, blocks, oobs, crcs)
        return off

    def zone_append_commit_many(
        self, zone: int, chunks: np.ndarray, oobs: np.ndarray, crcs=None
    ) -> np.ndarray:
        """Commit a run of append commands to one zone in the given order.

        ``chunks`` is (n_cmds, chunk_blocks, block_bytes) and ``oobs`` is
        (n_cmds, chunk_blocks); command i lands at ``offsets[i]``, exactly as
        n_cmds sequential :meth:`zone_append_commit` calls would -- but the
        media update is two slice assignments for the whole run (the group
        committer's per-drive hot path).  Returns the per-command offsets.

        Only valid with no crash budget armed: per-block power-loss
        granularity needs the scalar path (the caller falls back to it)."""
        assert self.budget.remaining is None, "bulk append needs the scalar path"
        self._check_alive()
        self._open_zone(zone)
        n_cmds, c, bb = chunks.shape
        off0 = int(self.wp[zone])
        self._commit_blocks(zone, chunks.reshape(n_cmds * c, bb),
                            oobs.reshape(n_cmds * c),
                            None if crcs is None else
                            np.asarray(crcs).reshape(n_cmds * c))
        return off0 + c * np.arange(n_cmds, dtype=np.int64)

    # -- reads --------------------------------------------------------------

    def read(self, zone: int, offset: int, n_blocks: int) -> np.ndarray:
        self._check_alive()
        return self.data[zone, offset : offset + n_blocks]

    def read_oob(self, zone: int, offset: int, n_blocks: int) -> np.ndarray:
        self._check_alive()
        return self.oob[zone, offset : offset + n_blocks]

    def read_blocks(self, zone: int, offsets: np.ndarray) -> np.ndarray:
        """Gather scattered blocks of one zone: (len(offsets), block_bytes)."""
        self._check_alive()
        return self.data[zone, np.asarray(offsets, dtype=np.int64)]

    def read_scattered(self, zones: np.ndarray, offsets: np.ndarray) -> np.ndarray:
        """Cross-zone gather: block ``offsets[i]`` of ``zones[i]`` for each i.

        The recovery scanner's primitive -- e.g. every zone's header block in
        one command instead of one read per zone."""
        self._check_alive()
        return self.data[
            np.asarray(zones, dtype=np.int64), np.asarray(offsets, dtype=np.int64)
        ]

    def read_oob_blocks(self, zone: int, offsets: np.ndarray) -> np.ndarray:
        """Gather scattered OOB entries of one zone."""
        self._check_alive()
        return self.oob[zone, np.asarray(offsets, dtype=np.int64)]

    # -- integrity: checksum store + UNC mask --------------------------------

    def crc_blocks(self, zone: int, offsets: np.ndarray) -> np.ndarray:
        """Gather stored checksums of one zone's blocks (host DIF lane)."""
        self._check_alive()
        return self.crc[zone, np.asarray(offsets, dtype=np.int64)]

    def crc_scattered(self, zones: np.ndarray, offsets: np.ndarray) -> np.ndarray:
        self._check_alive()
        return self.crc[
            np.asarray(zones, dtype=np.int64), np.asarray(offsets, dtype=np.int64)
        ]

    def unc_blocks(self, zone: int, offsets: np.ndarray) -> np.ndarray:
        """Unreadable-sector mask for a gather (True => UNC on read)."""
        self._check_alive()
        return self.unc[zone, np.asarray(offsets, dtype=np.int64)]

    def unc_scattered(self, zones: np.ndarray, offsets: np.ndarray) -> np.ndarray:
        self._check_alive()
        return self.unc[
            np.asarray(zones, dtype=np.int64), np.asarray(offsets, dtype=np.int64)
        ]

    def read_verified(self, zone: int, offset: int, n_blocks: int) -> np.ndarray:
        """Checked contiguous read: raises :class:`UncorrectableError` on a
        UNC sector instead of returning whatever is on the media."""
        self._check_alive()
        if self.unc[zone, offset : offset + n_blocks].any():
            raise UncorrectableError(
                f"drive {self.drive_id}: UNC in zone {zone} "
                f"[{offset}, {offset + n_blocks})"
            )
        return self.data[zone, offset : offset + n_blocks]

    def repair_blocks(self, zone: int, offsets: np.ndarray, blocks: np.ndarray) -> None:
        """In-place media repair: rewrite blocks that parity reconstructed.

        Unlike a log append this does *not* move the write pointer or touch
        the OOB area -- the logical location (L2P, CST) of the block is
        unchanged; only the rotted payload is replaced, its checksum
        recomputed, and any UNC flag cleared (a successful rewrite
        reallocates the sector, like a NAND read-retry + rewrite)."""
        self._check_alive()
        offs = np.asarray(offsets, dtype=np.int64)
        blocks = np.asarray(blocks, dtype=np.uint8).reshape(
            offs.size, self.cfg.block_bytes
        )
        self.data[zone, offs] = blocks
        self.crc[zone, offs] = crc32c_many(blocks)
        self.unc[zone, offs] = False
        self.blocks_repaired += int(offs.size)

    def written_mask(self) -> np.ndarray:
        """(n_zones, cap) bool: True where a block has been committed."""
        return (
            np.arange(self.cfg.zone_cap_blocks, dtype=np.int64)[None, :]
            < self.wp[:, None]
        )

    # -- integrity: media-fault application ----------------------------------
    #
    # All fault hooks perturb the data plane / UNC mask only -- never the
    # checksum store, which models the host-written DIF lane.  That is what
    # makes every injected fault *detectable*: a verify pass sees a stored
    # checksum that no longer matches the media (or an UNC flag).

    def corrupt_bit_rot(self, zone: int, off: int, byte: int = 0, bit: int = 0) -> None:
        """Flip one bit of a committed block (retention/read-disturb rot)."""
        self.data[zone, off, byte] ^= np.uint8(1 << bit)
        self.media_faults += 1

    def corrupt_torn_write(self, zone: int, n_blocks: int) -> int:
        """Lose the tail of the most recent commit to this zone: the last
        ``n_blocks`` before the write pointer revert to erased (zeros) while
        wp/OOB/checksums still reflect the intended write -- the classic
        torn/partial-write fault.  Returns how many blocks were torn."""
        end = int(self.wp[zone])
        lo = max(0, end - n_blocks)
        if end > lo:
            self.data[zone, lo:end] = 0
            self.media_faults += end - lo
        return end - lo

    def corrupt_misdirected_write(
        self, zone: int, off: int, src_zone: int, src_off: int
    ) -> None:
        """A write aimed elsewhere landed here: the victim block's media is
        overwritten with another block's payload (its stored checksum now
        mismatches), modeling a firmware misdirected write."""
        self.data[zone, off] = self.data[src_zone, src_off]
        self.media_faults += 1

    def mark_unreadable(self, zone: int, off: int) -> None:
        """Latent sector error: reads of this block return UNC."""
        self.unc[zone, off] = True
        self.media_faults += 1

    # -- failure ------------------------------------------------------------

    def fail(self) -> None:
        """Full-drive failure: all data is gone."""
        self.failed = True

    def replace(self) -> None:
        """Swap in a fresh drive (same identity, empty media).

        Lifetime counters (``blocks_written``, ``zone_resets``) are carried
        over: they account the *array slot's* device traffic, and resetting
        them on a swap would corrupt write-amplification accounting across a
        rebuild."""
        self.data[:] = 0
        self.oob[:] = np.zeros((), dtype=OOB_DTYPE)
        self.oob["lba"] = INVALID_LBA
        self.crc[:] = 0
        self.unc[:] = False
        self.wp[:] = 0
        self.state[:] = ZoneState.EMPTY
        self.failed = False


def make_array_drives(
    n_drives: int, cfg: ZnsConfig, budget: Optional[CrashBudget] = None
) -> list[SimZnsDrive]:
    budget = budget or CrashBudget(None)
    return [SimZnsDrive(cfg, i, budget) for i in range(n_drives)]
