"""Crash recovery (paper §3.4).

``recover_array(drives, cfg, zns_cfg)`` rebuilds a consistent ZapRAIDArray
from the persistent state of the drives after a crash, in the paper's order:

1. **Segment table** -- scan zone headers; a segment is valid iff every one
   of its zones has at least the header persisted (Case 1); segments with
   any missing-header zone are discarded and their zones reset (Case 2).
2. **Stripes** -- for every open segment, count persisted chunks per stripe
   id (OOB scan); stripes with fewer than k+m chunks are *partial*.  A
   segment holding partial stripes is *dirty*: its fully-persisted winning
   blocks are rewritten into a fresh segment and the old zones reclaimed
   (ZNS cannot patch in place).  Data-complete-but-unfooted segments get
   their footer recomputed and are sealed.
3. **L2P + CST** -- sealed segments replay their footers (fast path), open
   segments their OOB areas; the latest write-timestamp wins per LBA.
   Mapping blocks (LSB-tagged LBA field) feed a temporary table; entry
   groups whose mapping block is newer than every user entry in the group
   stay offloaded on the SSD (paper §3.1/§3.4).

Because writes are acknowledged only after the whole stripe persists,
discarding partial stripes never loses acknowledged data.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.array import ZapRaidConfig, ZapRAIDArray, _OpenSegment, _SegmentRecord
from repro.core.group_layout import CompactStripeTable
from repro.core.l2p import NO_PBA, pack_pba, unpack_pba
from repro.core.segment import (
    SegmentClass,
    SegmentInfo,
    SegmentState,
    solve_stripes_per_segment,
    unpack_footer,
    unpack_header,
)
from repro.core.zns import INVALID_LBA, SimZnsDrive, ZnsConfig, ZoneState


@dataclasses.dataclass
class _FoundSegment:
    info: SegmentInfo
    wps: list[int]
    footer_blocks: int = 0
    sealed: bool = False
    dirty: bool = False
    complete_seqs: set = dataclasses.field(default_factory=set)
    chunk_meta: dict = dataclasses.field(default_factory=dict)  # (drive, chunk) -> oob rows

    def data_end(self) -> int:
        return self.info.data_start() + self.info.n_stripes * self.info.chunk_blocks

    def seal_end(self) -> int:
        return self.data_end() + self.footer_blocks

    def data_complete(self) -> bool:
        return all(wp >= self.data_end() for wp in self.wps)


def _scan_headers(drives, zns_cfg, stats) -> dict[int, _FoundSegment]:
    found: dict[int, _FoundSegment] = {}
    for d in drives:
        for z in range(zns_cfg.n_zones):
            if d.state[z] == ZoneState.EMPTY or d.wp[z] == 0:
                continue
            info = unpack_header(d.read(z, 0, 1)[0])
            stats.recovery_blocks_read += 1
            if info is None or info.seg_id in found:
                continue
            s, foot = solve_stripes_per_segment(
                zns_cfg.zone_cap_blocks, info.chunk_blocks, zns_cfg.block_bytes
            )
            info.n_stripes = s
            fs = _FoundSegment(
                info=info, wps=[0] * len(info.zone_ids), footer_blocks=foot
            )
            for drive_idx, zid in enumerate(info.zone_ids):
                fs.wps[drive_idx] = int(drives[drive_idx].wp[zid])
            found[info.seg_id] = fs
    return found


def _scan_stripes(fs: _FoundSegment, drives, stats) -> None:
    """OOB-scan the data region; classify complete vs partial stripes."""
    info = fs.info
    c = info.chunk_blocks
    data_start = info.data_start()
    per_seq_count: dict[int, int] = {}
    for drive_idx, z in enumerate(info.zone_ids):
        usable = min(fs.wps[drive_idx], fs.data_end()) - data_start
        n_chunks = max(0, usable) // c  # trailing partial chunks are dropped
        if n_chunks <= 0:
            continue
        oob = drives[drive_idx].read_oob(z, data_start, n_chunks * c)
        stats.recovery_blocks_read += n_chunks * c
        for chunk in range(n_chunks):
            rows = oob[chunk * c : (chunk + 1) * c].copy()
            seq = int(rows["stripe"][0])
            per_seq_count[seq] = per_seq_count.get(seq, 0) + 1
            fs.chunk_meta[(drive_idx, chunk)] = rows
    n = info.n_drives
    fs.complete_seqs = {s for s, cnt in per_seq_count.items() if cnt == n}
    fs.dirty = any(cnt != n for cnt in per_seq_count.values())
    # a drive with committed blocks beyond complete chunks is also dirty
    for drive_idx in range(n):
        usable = min(fs.wps[drive_idx], fs.data_end()) - data_start
        if usable > 0 and usable % c != 0:
            fs.dirty = True


def _read_sealed_meta(fs: _FoundSegment, drives, zns_cfg, stats) -> None:
    """Fast path: replay footers instead of scanning the whole OOB area."""
    info = fs.info
    c = info.chunk_blocks
    n_entries = info.n_stripes * c
    for drive_idx, z in enumerate(info.zone_ids):
        foot = drives[drive_idx].read(z, fs.data_end(), fs.footer_blocks)
        stats.recovery_blocks_read += foot.shape[0]
        entries = unpack_footer(foot, n_entries, zns_cfg.block_bytes)
        for chunk in range(info.n_stripes):
            fs.chunk_meta[(drive_idx, chunk)] = entries[chunk * c : (chunk + 1) * c]
    fs.complete_seqs = {
        int(rows["stripe"][0]) for rows in fs.chunk_meta.values()
    }
    fs.sealed = True
    fs.dirty = False


def recover_array(
    drives: list[SimZnsDrive], cfg: ZapRaidConfig, zns_cfg: ZnsConfig
) -> ZapRAIDArray:
    arr = ZapRAIDArray(cfg, zns_cfg, drives, _recovering=True)
    arr.disarm_crash()
    stats = arr.stats

    found = _scan_headers(drives, zns_cfg, stats)
    valid, discard = [], []
    for fs in found.values():
        # paper Case 2: any zone below the header size => discard segment
        (discard if any(wp < fs.info.chunk_blocks for wp in fs.wps) else valid).append(fs)
    for fs in discard:
        for drive_idx, z in enumerate(fs.info.zone_ids):
            if drives[drive_idx].wp[z] > 0:
                drives[drive_idx].reset_zone(z)

    for fs in valid:
        fully_sealed = all(wp >= fs.seal_end() for wp in fs.wps)
        if fully_sealed:
            _read_sealed_meta(fs, drives, zns_cfg, stats)
        else:
            _scan_stripes(fs, drives, stats)

    clean = [fs for fs in valid if not fs.dirty]
    dirty = [fs for fs in valid if fs.dirty]
    arr.next_seg_id = max((fs.info.seg_id for fs in valid), default=-1) + 1

    for fs in clean:
        _install_segment(arr, fs, zns_cfg)

    # free-zone lists = complement of zones referenced by live segments
    used = [set() for _ in drives]
    for fs in valid:
        for drive_idx, z in enumerate(fs.info.zone_ids):
            used[drive_idx].add(z)
    arr.free_zones = [
        [z for z in range(zns_cfg.n_zones - 1, -1, -1) if z not in used[i]]
        for i in range(len(drives))
    ]
    for i, d in enumerate(drives):
        for z in arr.free_zones[i]:
            if d.wp[z] > 0:
                d.reset_zone(z)

    _restore_open_slots(arr)

    # ---- latest-wins metadata resolution over ALL valid segments ----------
    user_wins: dict[int, tuple[int, int]] = {}
    map_wins: dict[int, tuple[int, int]] = {}
    for fs in valid:
        _harvest_meta(arr, fs, user_wins, map_wins)

    # Fast-forward the timestamp clock past everything on disk, and seed the
    # per-LBA commit timestamps so post-recovery writes are never "stale".
    max_ts = max(
        [ts for ts, _ in user_wins.values()] + [ts for ts, _ in map_wins.values()],
        default=0,
    )
    arr.ts_counter = max(arr.ts_counter, max_ts + 1)
    for lba, (ts, _) in user_wins.items():
        arr._lba_ts[lba] = ts
    for gid, (ts, _) in map_wins.items():
        arr._gid_ts[gid] = ts

    dirty_ids = {fs.info.seg_id for fs in dirty}
    # ---- re-inject winning blocks that live in dirty segments -------------
    reinjected_gids = _reinject(arr, dirty, user_wins, map_wins, dirty_ids, drives)
    arr.flush()
    for fs in dirty:
        for drive_idx, z in enumerate(fs.info.zone_ids):
            drives[drive_idx].reset_zone(z)
            arr.free_zones[drive_idx].append(z)

    # ---- apply the remaining (clean-segment) wins --------------------------
    _apply_wins(arr, user_wins, map_wins, dirty_ids, reinjected_gids)

    # ---- re-seal data-complete segments missing their footers --------------
    for ost in list(arr.open_segments.values()):
        if ost.info.stripes_written >= ost.info.n_stripes:
            arr._seal_segment(ost)
    arr._drain_meta()
    return arr


def _install_segment(arr: ZapRAIDArray, fs: _FoundSegment, zns_cfg) -> None:
    info = fs.info
    rec = _SegmentRecord(info)
    arr.segments[info.seg_id] = rec
    c = info.chunk_blocks
    if fs.sealed or fs.data_complete():
        info.state = int(SegmentState.SEALED)
        info.stripes_written = info.n_stripes
        if not fs.sealed:
            # data region complete, footer missing: keep as open so the
            # re-seal pass below writes the footer.
            info.state = int(SegmentState.OPEN)
            ost = _OpenSegment(info, zns_cfg.block_bytes)
            for (d, chunk), rows in fs.chunk_meta.items():
                ost.meta[d, chunk * c : (chunk + 1) * c] = rows
            arr.open_segments[info.seg_id] = ost
            rec.cst = ost.cst
    else:
        info.state = int(SegmentState.OPEN)
        per_drive: dict[int, int] = {}
        for (d, chunk) in fs.chunk_meta:
            per_drive[d] = max(per_drive.get(d, -1), chunk)
        info.stripes_written = min((v + 1 for v in per_drive.values()), default=0)
        ost = _OpenSegment(info, zns_cfg.block_bytes)
        for (d, chunk), rows in fs.chunk_meta.items():
            ost.meta[d, chunk * c : (chunk + 1) * c] = rows
        arr.open_segments[info.seg_id] = ost
        rec.cst = ost.cst
    if info.uses_append:
        if rec.cst is None:
            rec.cst = CompactStripeTable(info.n_drives, info.n_stripes, info.group_size)
        for (d, chunk), rows in fs.chunk_meta.items():
            rec.cst.record(d, chunk, int(rows["stripe"][0]) % info.group_size)
        if info.seg_id in arr.open_segments:
            arr.open_segments[info.seg_id].cst = rec.cst


def _restore_open_slots(arr: ZapRAIDArray) -> None:
    cfg = arr.cfg
    by_class: dict[tuple[int, bool], list[int]] = {}
    for sid, ost in arr.open_segments.items():
        if ost.info.stripes_written >= ost.info.n_stripes:
            continue  # data-complete, awaiting re-seal; not reusable
        key = (int(ost.info.seg_class), ost.info.group_size > 1)
        by_class.setdefault(key, []).append(sid)

    def take(seg_class: int, chunk_blocks: int, group: int) -> int:
        key = (int(seg_class), group > 1)
        if by_class.get(key):
            return by_class[key].pop(0)
        return arr._open_segment(SegmentClass(seg_class), chunk_blocks, group)

    arr.small_ids, arr.large_ids = [], []
    if not cfg.hybrid:
        arr.small_ids.append(
            take(int(SegmentClass.SMALL), cfg.chunk_blocks, cfg.group_size)
        )
    else:
        for i in range(cfg.n_small):
            g = cfg.group_size if i == 0 else 1
            arr.small_ids.append(take(int(SegmentClass.SMALL), cfg.small_chunk_blocks, g))
        for _ in range(cfg.n_large):
            arr.large_ids.append(take(int(SegmentClass.LARGE), cfg.large_chunk_blocks, 1))


def _harvest_meta(arr, fs: _FoundSegment, user_wins, map_wins) -> None:
    info = fs.info
    c = info.chunk_blocks
    scheme = arr.scheme
    for (d, chunk), rows in fs.chunk_meta.items():
        seq = int(rows["stripe"][0])
        if not fs.sealed and seq not in fs.complete_seqs:
            continue
        if scheme.drive_to_role(d, seq) >= scheme.k:
            continue  # parity chunk
        for b in range(c):
            lba_field = int(rows["lba"][b])
            if lba_field == int(INVALID_LBA):
                continue
            ts = int(rows["ts"][b])
            pba = pack_pba(info.seg_id, d, info.data_start() + chunk * c + b)
            if lba_field & 1:
                gid = lba_field >> 1
                if gid not in map_wins or map_wins[gid][0] < ts:
                    map_wins[gid] = (ts, pba)
            else:
                lba = lba_field >> 1
                if lba >= arr.cfg.logical_blocks:
                    continue
                if lba not in user_wins or user_wins[lba][0] < ts:
                    user_wins[lba] = (ts, pba)


def _reinject(arr, dirty, user_wins, map_wins, dirty_ids, drives) -> set[int]:
    """Rewrite winning blocks whose only copy lives in a dirty segment."""
    by_seg: dict[int, _FoundSegment] = {fs.info.seg_id: fs for fs in dirty}
    reinjected_gids: set[int] = set()

    def read_from_dirty(pba: int) -> np.ndarray:
        seg_id, d, off = unpack_pba(pba)
        fs = by_seg[seg_id]
        return drives[d].read(fs.info.zone_ids[d], off, 1)[0].copy()

    items = [
        (ts, lba, pba, 0) for lba, (ts, pba) in user_wins.items()
        if unpack_pba(pba)[0] in dirty_ids
    ] + [
        (ts, gid, pba, 1) for gid, (ts, pba) in map_wins.items()
        if unpack_pba(pba)[0] in dirty_ids
    ]
    items.sort()
    for ts, key, pba, is_map in items:
        payload = read_from_dirty(pba)
        arr.stats.recovery_blocks_read += 1
        if is_map:
            arr._append_block(arr._classify(1), -1, payload, ts, meta_gid=key)
            reinjected_gids.add(key)
        else:
            arr._append_block(arr._classify(1), key, payload, ts)
    return reinjected_gids


def _apply_wins(arr: ZapRAIDArray, user_wins, map_wins, dirty_ids, reinjected_gids) -> None:
    epg = arr.l2p.epg
    group_max_ts: dict[int, int] = {}
    dirty_winner_gids: set[int] = set()
    for lba, (ts, pba) in user_wins.items():
        gid = lba // epg
        group_max_ts[gid] = max(group_max_ts.get(gid, 0), ts)
        if unpack_pba(pba)[0] in dirty_ids:
            # the group's authoritative copy moved during re-injection; the
            # on-SSD mapping block is stale, so the group must stay resident.
            dirty_winner_gids.add(gid)
    offloaded: set[int] = set()
    for gid, (mts, pba) in map_wins.items():
        if gid not in reinjected_gids and unpack_pba(pba)[0] not in dirty_ids:
            arr.mapping_table[gid] = pba
            _mark_valid(arr, pba)
        if (
            arr.l2p.offload
            and mts >= group_max_ts.get(gid, -1)
            and gid not in dirty_winner_gids
            and gid not in reinjected_gids
        ):
            offloaded.add(gid)
    for lba, (ts, pba) in user_wins.items():
        if unpack_pba(pba)[0] in dirty_ids:
            continue  # re-injected already; L2P points at the new copy
        if lba // epg in offloaded:
            _mark_valid(arr, pba)  # entry stays on the SSD mapping block
            continue
        arr.l2p.set(lba, pba)
        _mark_valid(arr, pba)
    # ensure offloaded groups' referenced blocks are marked valid, then drop
    # the in-memory copies (the paper keeps them on SSD).
    for gid in offloaded:
        entries = arr._read_mapping_block(gid)
        if entries is None:
            continue
        for pba in entries:
            if int(pba) != int(NO_PBA):
                _mark_valid(arr, int(pba))
        arr.l2p.drop_group(gid)
    arr._drain_meta()


def _mark_valid(arr: ZapRAIDArray, pba: int) -> None:
    seg_id, d, off = unpack_pba(pba)
    rec = arr.segments.get(seg_id)
    if rec is None:
        return
    didx = off - rec.info.data_start()
    if 0 <= didx < rec.valid.shape[1] and not rec.valid[d, didx]:
        rec.valid[d, didx] = True
        rec.valid_count += 1
