"""Crash recovery (paper §3.4).

``recover_array(drives, cfg, zns_cfg)`` rebuilds a consistent ZapRAIDArray
from the persistent state of the drives after a crash, in the paper's order:

1. **Segment table** -- scan zone headers; a segment is valid iff every one
   of its zones has at least the header persisted (Case 1); segments with
   any missing-header zone are discarded and their zones reset (Case 2).
2. **Stripes** -- for every open segment, count persisted chunks per stripe
   id (OOB scan); stripes with fewer than k+m chunks are *partial*.  A
   segment holding partial stripes is *dirty*: its fully-persisted winning
   blocks are rewritten into a fresh segment and the old zones reclaimed
   (ZNS cannot patch in place).  Data-complete-but-unfooted segments get
   their footer recomputed and are sealed.
3. **L2P + CST** -- sealed segments replay their footers (fast path), open
   segments their OOB areas; the latest write-timestamp wins per LBA.
   Mapping blocks (LSB-tagged LBA field) feed a temporary table; entry
   groups whose mapping block is newer than every user entry in the group
   stay offloaded on the SSD (paper §3.1/§3.4).

Because writes are acknowledged only after the whole stripe persists,
discarding partial stripes never loses acknowledged data.

With ``cfg.batched`` (the default) the scan pipeline is vectorized end to
end: one cross-zone header gather per drive with a vectorized magic
pre-filter, whole-data-region OOB scans resolved with numpy (no per-chunk
Python loops), winner resolution as one lexsort over every harvested
``(key, ts, pba)`` triple (latest ts wins, first-encountered wins ties --
exactly the scalar dict semantics), and bulk L2P/validity installation via
``set_many`` / ``_mark_valid_many``.  ``cfg.batched=False`` keeps the
per-chunk/per-block scan loops as the bit-identical scalar baseline; both
paths share the vectorized installer, so recovered state is identical by
construction.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.array import ZapRaidConfig, ZapRAIDArray, _OpenSegment, _SegmentRecord
from repro.core.group_layout import CompactStripeTable
from repro.core.l2p import NO_PBA, pack_pba, pack_pba_many, unpack_pba, unpack_pba_many
from repro.core.segment import (
    FooterError,
    SegmentInfo,
    SegmentState,
    header_candidates,
    solve_stripes_per_segment,
    unpack_footer,
    unpack_header,
)
from repro.integrity.checksum import crc32c_many
from repro.core.zns import (
    INVALID_LBA,
    OOB_DTYPE,
    SimZnsDrive,
    ZnsConfig,
    ZoneState,
)


class RecoveryError(RuntimeError):
    """Crash state the scanner cannot safely resolve (fail-loud path)."""


@dataclasses.dataclass
class _FoundSegment:
    info: SegmentInfo
    wps: list[int]
    footer_blocks: int = 0
    sealed: bool = False
    dirty: bool = False
    complete_seqs: set = dataclasses.field(default_factory=set)
    # member -> (n_chunks, C) OOB rows for the persisted data-region prefix
    meta: dict = dataclasses.field(default_factory=dict)
    # members whose physical drive is failed: media unreadable, metadata is
    # synthesized from the survivors' parity OOB after install
    absent: set = dataclasses.field(default_factory=set)
    # member whose zone a crashed rebuild left behind the sealed others;
    # its zone is reset and rewritten from survivors after install
    rebuild_member: int | None = None

    def present(self) -> list[int]:
        skip = self.absent
        if self.rebuild_member is not None:
            skip = skip | {self.rebuild_member}
        return [d for d in range(self.info.n_drives) if d not in skip]

    def data_end(self) -> int:
        return self.info.data_start() + self.info.n_stripes * self.info.chunk_blocks

    def seal_end(self) -> int:
        return self.data_end() + self.footer_blocks

    def data_complete(self) -> bool:
        return all(self.wps[d] >= self.data_end() for d in self.present())

    def complete_arr(self) -> np.ndarray:
        return np.fromiter(sorted(self.complete_seqs), np.int64, len(self.complete_seqs))


def _note_segment(found, info, drives, zns_cfg) -> None:
    s, foot = solve_stripes_per_segment(
        zns_cfg.zone_cap_blocks, info.chunk_blocks, zns_cfg.block_bytes
    )
    info.n_stripes = s
    fs = _FoundSegment(info=info, wps=[0] * len(info.zone_ids), footer_blocks=foot)
    for member, zid in enumerate(info.zone_ids):
        d = drives[info.drive_ids[member]]
        if d.failed:
            fs.absent.add(member)  # stale media; never trust a dead drive
            fs.wps[member] = -1
        else:
            fs.wps[member] = int(d.wp[zid])
    found[info.seg_id] = fs


def _scan_headers(drives, zns_cfg, stats) -> dict[int, _FoundSegment]:
    """Per-zone header reads + unpack (the scalar baseline).

    A header copy whose media checksum mismatches (or that reads UNC) is
    skipped, so a rotted copy loses to an intact replica on another
    member instead of installing garbage geometry."""
    found: dict[int, _FoundSegment] = {}
    for d in drives:
        if d.failed:
            continue
        for z in range(zns_cfg.n_zones):
            if d.state[z] == ZoneState.EMPTY or d.wp[z] == 0:
                continue
            block = d.read(z, 0, 1)
            stats.recovery_blocks_read += 1
            zero = np.zeros(1, np.int64)
            if (
                bool(d.unc_blocks(z, zero)[0])
                or int(d.crc_blocks(z, zero)[0]) != int(crc32c_many(block)[0])
            ):
                continue  # rotted copy: an intact replica must win
            info = unpack_header(block[0])
            if info is None or info.seg_id in found:
                continue
            _note_segment(found, info, drives, zns_cfg)
    return found


def _scan_headers_batched(drives, zns_cfg, stats) -> dict[int, _FoundSegment]:
    """One cross-zone header gather per drive + vectorized magic pre-filter.

    Checksum validation is part of the same bulk pass: copies whose media
    CRC mismatches or that read UNC are dropped before unpacking."""
    found: dict[int, _FoundSegment] = {}
    for d in drives:
        if d.failed:
            continue
        zs = np.flatnonzero((np.asarray(d.state) != ZoneState.EMPTY) & (d.wp > 0))
        if zs.size == 0:
            continue
        zeros = np.zeros(zs.size, np.int64)
        blocks = d.read_scattered(zs, zeros)
        stats.recovery_blocks_read += int(zs.size)
        intact = (
            (crc32c_many(blocks) == d.crc_scattered(zs, zeros))
            & ~d.unc_scattered(zs, zeros)
        )
        for i in np.flatnonzero(header_candidates(blocks) & intact):
            info = unpack_header(blocks[i])
            if info is None or info.seg_id in found:
                continue
            _note_segment(found, info, drives, zns_cfg)
    return found


def _read_zone_oob(fs: _FoundSegment, drives, member: int, stats):
    """(n_chunks, C) OOB rows of one zone's persisted data prefix, or None."""
    info = fs.info
    c = info.chunk_blocks
    data_start = info.data_start()
    usable = min(fs.wps[member], fs.data_end()) - data_start
    n_chunks = max(0, usable) // c  # trailing partial chunks are dropped
    if n_chunks <= 0:
        return None
    z = info.zone_ids[member]
    oob = drives[info.drive_ids[member]].read_oob(z, data_start, n_chunks * c)
    stats.recovery_blocks_read += n_chunks * c
    return oob.reshape(n_chunks, c).copy()


def _ragged_tail(fs: _FoundSegment) -> bool:
    """A drive with committed blocks beyond whole chunks is also dirty."""
    c = fs.info.chunk_blocks
    data_start = fs.info.data_start()
    for member in fs.present():
        usable = min(fs.wps[member], fs.data_end()) - data_start
        if usable > 0 and usable % c != 0:
            return True
    return False


def _scan_stripes(fs: _FoundSegment, drives, stats) -> None:
    """OOB-scan the data region; classify complete vs partial stripes
    (scalar baseline: per-chunk Python loop).  Completeness is judged over
    the *present* members: chunks on a failed drive are reconstructible
    from parity, so they never gate a stripe."""
    per_seq_count: dict[int, int] = {}
    for member in fs.present():
        rows = _read_zone_oob(fs, drives, member, stats)
        if rows is None:
            continue
        fs.meta[member] = rows
        for chunk in range(rows.shape[0]):
            seq = int(rows["stripe"][chunk, 0])
            per_seq_count[seq] = per_seq_count.get(seq, 0) + 1
    n = len(fs.present())
    fs.complete_seqs = {s for s, cnt in per_seq_count.items() if cnt == n}
    fs.dirty = any(cnt != n for cnt in per_seq_count.values()) or _ragged_tail(fs)


def _scan_stripes_batched(fs: _FoundSegment, drives, stats) -> None:
    """Vectorized ``_scan_stripes``: per-drive bulk OOB read, stripe-id
    completeness via one ``np.unique`` count over all drives' chunks."""
    seq_parts: list[np.ndarray] = []
    for member in fs.present():
        rows = _read_zone_oob(fs, drives, member, stats)
        if rows is None:
            continue
        fs.meta[member] = rows
        seq_parts.append(rows["stripe"][:, 0].astype(np.int64))
    n = len(fs.present())
    if seq_parts:
        seqs, counts = np.unique(np.concatenate(seq_parts), return_counts=True)
        fs.complete_seqs = set(seqs[counts == n].tolist())
        fs.dirty = bool((counts != n).any())
    fs.dirty = fs.dirty or _ragged_tail(fs)


def _read_sealed_meta(fs: _FoundSegment, drives, zns_cfg, stats) -> None:
    """Fast path: replay footers instead of scanning the whole OOB area.

    Each member's footer is validated before its mappings are trusted:
    the media checksum store first, then the in-band footer CRC
    (``unpack_footer(strict=True)``).  A member whose footer is rotted,
    torn, or UNC falls back to that zone's OOB-area scan -- same
    entries, slower path -- rather than installing garbage mappings."""
    info = fs.info
    c = info.chunk_blocks
    n_entries = info.n_stripes * c
    all_seqs: list[np.ndarray] = []
    for member in fs.present():
        z = info.zone_ids[member]
        d = drives[info.drive_ids[member]]
        foot = d.read(z, fs.data_end(), fs.footer_blocks)
        stats.recovery_blocks_read += foot.shape[0]
        offs = fs.data_end() + np.arange(fs.footer_blocks, dtype=np.int64)
        try:
            if (
                d.unc_blocks(z, offs).any()
                or (crc32c_many(foot) != d.crc_blocks(z, offs)).any()
            ):
                raise FooterError(
                    f"segment {info.seg_id} member {member}: footer fails "
                    "the media checksum"
                )
            entries = unpack_footer(
                foot, n_entries, zns_cfg.block_bytes, strict=True
            )
        except FooterError:
            # rotted footer: the OOB area holds the same per-block
            # metadata (the footer is a serialization of it)
            entries = d.read_oob(z, info.data_start(), n_entries).copy()
            stats.recovery_blocks_read += n_entries
        rows = entries.reshape(info.n_stripes, c)
        fs.meta[member] = rows
        all_seqs.append(rows["stripe"][:, 0].astype(np.int64))
    fs.complete_seqs = set(np.unique(np.concatenate(all_seqs)).tolist())
    fs.sealed = True
    fs.dirty = False


def recover_array(
    drives: list[SimZnsDrive], cfg: ZapRaidConfig, zns_cfg: ZnsConfig
) -> ZapRAIDArray:
    arr = ZapRAIDArray(cfg, zns_cfg, drives, _recovering=True)
    arr.disarm_crash()
    stats = arr.stats
    batched = cfg.batched

    found = (
        _scan_headers_batched(drives, zns_cfg, stats)
        if batched
        else _scan_headers(drives, zns_cfg, stats)
    )
    valid, discard = [], []
    for fs in found.values():
        healthy = [d for d in range(fs.info.n_drives) if d not in fs.absent]
        behind = [d for d in healthy if fs.wps[d] < fs.data_end()]
        rest_sealed = all(
            fs.wps[d] >= fs.seal_end() for d in healthy if d not in behind
        )
        if behind and rest_sealed and len(healthy) > len(behind):
            # Some members are mid-zone while every other member carries a
            # finished footer: normal commit order (seal starts only after
            # ALL members are data-complete) cannot produce this -- a crash
            # interrupted a rebuild rewriting those zones.
            if len(behind) > 1:
                raise RecoveryError(
                    f"segment {fs.info.seg_id}: {len(behind)} members are "
                    "mid-zone while the rest are sealed -- crash during a "
                    "rebuild left multiple zones inconsistent; restore from "
                    "the replica or re-run rebuild from a healthy mirror"
                )
            if len(healthy) - 1 < fs.info.k:
                raise RecoveryError(
                    f"segment {fs.info.seg_id}: crash during rebuild and "
                    "not enough surviving members to reconstruct"
                )
            fs.rebuild_member = behind[0]
            valid.append(fs)
            continue
        # Crash while a rebuild was rewriting an *open* segment's zone: the
        # replaced member's zone is wiped (no header) while survivors carry
        # headers and possibly data.  A crash during _open_segment leaves
        # the same shape with an empty prefix -- rewriting the header from
        # the survivors is correct (and harmless) for both.
        headerless = [d for d in healthy if fs.wps[d] < fs.info.chunk_blocks]
        if headerless and len(headerless) < len(healthy):
            if not any(fs.wps[d] > fs.info.data_start() for d in healthy):
                # no survivor holds data: crash during _open_segment itself
                # (paper Case 2) -- the segment is empty, discard it
                discard.append(fs)
                continue
            if len(headerless) > 1:
                raise RecoveryError(
                    f"segment {fs.info.seg_id}: {len(headerless)} member "
                    "zones have no header while others hold data -- crash "
                    "left multiple zones wiped; restore from the replica"
                )
            if len(healthy) - 1 < fs.info.k:
                raise RecoveryError(
                    f"segment {fs.info.seg_id}: a member zone is wiped and "
                    "not enough surviving members to reconstruct it"
                )
            fs.rebuild_member = headerless[0]
            valid.append(fs)
            continue
        if behind and len(behind) == len(healthy):
            # Fully-unsealed segment: normal commits advance members one
            # group at a time, so write pointers can never spread by more
            # than one group span.  A wider spread means a rebuild crashed
            # mid-way through rewriting one member's zone -- data beyond
            # the laggard's pointer is reconstructible but not attributable,
            # so fail loudly rather than silently drop those stripes.
            lead = max(fs.wps[d] for d in healthy)
            lag = min(fs.wps[d] for d in healthy)
            span = max(1, fs.info.group_size) * fs.info.chunk_blocks
            if lag >= fs.info.chunk_blocks and lead - lag > span:
                raise RecoveryError(
                    f"segment {fs.info.seg_id}: member write pointers "
                    f"spread {lead - lag} blocks (> one group span) -- "
                    "crash mid-rebuild left a zone partially rewritten; "
                    "re-run the rebuild from a healthy mirror"
                )
        # paper Case 2: any zone below the header size => discard segment
        if any(fs.wps[d] < fs.info.chunk_blocks for d in healthy):
            discard.append(fs)
        else:
            valid.append(fs)
    for fs in discard:
        for member, z in enumerate(fs.info.zone_ids):
            p = fs.info.drive_ids[member]
            if not drives[p].failed and drives[p].wp[z] > 0:
                drives[p].reset_zone(z)

    for fs in valid:
        if fs.rebuild_member is not None:
            if all(fs.wps[d] >= fs.seal_end() for d in fs.present()):
                _read_sealed_meta(fs, drives, zns_cfg, stats)  # survivors only
            else:
                # open segment with a wiped member: scan the survivors'
                # OOB prefix; the zone rewrite below restores the member
                if batched:
                    _scan_stripes_batched(fs, drives, stats)
                else:
                    _scan_stripes(fs, drives, stats)
                if fs.dirty:
                    raise RecoveryError(
                        f"segment {fs.info.seg_id}: partial stripes on "
                        "the survivors of a crashed rebuild -- winners "
                        "cannot be safely re-read; re-run the rebuild"
                    )
            continue
        fully_sealed = all(fs.wps[d] >= fs.seal_end() for d in fs.present())
        if fully_sealed:
            _read_sealed_meta(fs, drives, zns_cfg, stats)
        elif batched:
            _scan_stripes_batched(fs, drives, stats)
        else:
            _scan_stripes(fs, drives, stats)
        if fs.dirty and fs.absent:
            raise RecoveryError(
                f"segment {fs.info.seg_id}: partial stripes on a degraded "
                "segment (member drive failed) -- winners cannot be "
                "re-read; replace the drive and rebuild before recovering"
            )

    clean = [fs for fs in valid if not fs.dirty]
    dirty = [fs for fs in valid if fs.dirty]
    arr.next_seg_id = max((fs.info.seg_id for fs in valid), default=-1) + 1

    for fs in clean:
        _install_segment(arr, fs, zns_cfg)

    # free-zone lists = complement of zones referenced by live segments
    used = [set() for _ in drives]
    for fs in valid:
        for member, z in enumerate(fs.info.zone_ids):
            used[fs.info.drive_ids[member]].add(z)
    arr.free_zones = [
        [z for z in range(zns_cfg.n_zones - 1, -1, -1) if z not in used[i]]
        for i in range(len(drives))
    ]
    for i, d in enumerate(drives):
        if d.failed:
            continue
        for z in arr.free_zones[i]:
            if d.wp[z] > 0:
                d.reset_zone(z)

    _restore_open_slots(arr)

    # ---- crashed-rebuild zones: rewrite from survivors --------------------
    scaffold: dict = {}
    for fs in clean:
        if fs.rebuild_member is not None:
            _rewrite_rebuild_zone(arr, fs, drives, zns_cfg, scaffold)
    # ---- failed-drive members: synthesize metadata from parity OOB --------
    for fs in clean:
        if fs.absent:
            _synthesize_absent_meta(arr, fs)

    # ---- latest-wins metadata resolution over ALL valid segments ----------
    if batched:
        u_keys, u_ts, u_pbas, m_keys, m_ts, m_pbas = _harvest_meta_batched(arr, valid)
    else:
        user_wins: dict[int, tuple[int, int]] = {}
        map_wins: dict[int, tuple[int, int]] = {}
        for fs in valid:
            _harvest_meta(arr, fs, user_wins, map_wins)
        u_keys, u_ts, u_pbas = _wins_arrays(user_wins)
        m_keys, m_ts, m_pbas = _wins_arrays(map_wins)

    # Fast-forward the timestamp clock past everything on disk, and seed the
    # per-LBA commit timestamps so post-recovery writes are never "stale".
    max_ts = max(int(np.max(u_ts, initial=0)), int(np.max(m_ts, initial=0)))
    arr.ts_counter = max(arr.ts_counter, max_ts + 1)
    arr._lba_ts[u_keys] = u_ts.astype(np.uint64)
    for i in range(m_keys.size):
        arr._gid_ts[int(m_keys[i])] = int(m_ts[i])

    dirty_ids = {fs.info.seg_id for fs in dirty}
    # ---- re-inject winning blocks that live in dirty segments -------------
    reinjected_gids = _reinject(
        arr, dirty, u_keys, u_ts, u_pbas, m_keys, m_ts, m_pbas, dirty_ids, drives
    )
    arr.flush()
    for fs in dirty:
        for member, z in enumerate(fs.info.zone_ids):
            p = fs.info.drive_ids[member]
            if not drives[p].failed:
                drives[p].reset_zone(z)
            arr.free_zones[p].append(z)

    # ---- apply the remaining (clean-segment) wins --------------------------
    _apply_wins(
        arr, u_keys, u_ts, u_pbas, m_keys, m_ts, m_pbas, dirty_ids, reinjected_gids
    )

    # ---- re-seal data-complete segments missing their footers --------------
    for ost in list(arr.open_segments.values()):
        if ost.info.stripes_written >= ost.info.n_stripes:
            arr._seal_segment(ost)
    # a crash between a rebuild's scaffold phase and its re-widening pass
    # leaves survivor-width segments behind: finish the relocation now
    arr._rewiden()
    arr._drain_meta()
    return arr


def _rewrite_rebuild_zone(arr, fs: _FoundSegment, drives, zns_cfg, scaffold) -> None:
    """Finish a crashed rebuild: the mid-zone member is reset and rewritten
    from the sealed survivors.  The lost zone's original append order is
    unknowable, so it is rewritten in canonical stripe order and that layout
    recorded in the CST -- self-consistent with every later read/rebuild."""
    info = fs.info
    b = fs.rebuild_member
    p = info.drive_ids[b]
    z = info.zone_ids[b]
    if drives[p].wp[z] > 0 or drives[p].state[z] != ZoneState.EMPTY:
        drives[p].reset_zone(z)
    rec = arr.segments[info.seg_id]
    n_stripes = info.n_stripes if fs.sealed else int(rec.info.stripes_written)
    if info.uses_append and rec.cst is not None and n_stripes:
        idx = np.arange(n_stripes)
        rec.cst.record_many(b, idx, idx % info.group_size)
    arr._rebuild_segment(rec, p, scaffold)
    c = info.chunk_blocks
    if fs.sealed:
        # read back the rewritten footer so winner harvesting sees member b
        foot = drives[p].read(z, fs.data_end(), fs.footer_blocks)
        arr.stats.recovery_blocks_read += foot.shape[0]
        entries = unpack_footer(foot, info.n_stripes * c, zns_cfg.block_bytes)
        fs.meta[b] = entries.reshape(info.n_stripes, c)
    elif n_stripes:
        # open segment: read back the rewritten OOB prefix instead
        rows = drives[p].read_oob(z, info.data_start(), n_stripes * c)
        arr.stats.recovery_blocks_read += n_stripes * c
        fs.meta[b] = rows.reshape(n_stripes, c).copy()
        ost = arr.open_segments.get(info.seg_id)
        if ost is not None:
            ost.meta[b, : n_stripes * c] = fs.meta[b].reshape(-1)


def _synthesize_absent_meta(arr, fs: _FoundSegment) -> None:
    """Reconstruct a failed member's OOB rows from the survivors' parity
    OOB so its winners still install (reads reconstruct through parity).
    Append segments get canonical CST rows for the absent member: the dead
    zone's real arrival order is unknowable, and the replacement rebuild
    will rewrite the zone in exactly this order."""
    info = fs.info
    rec = arr.segments[info.seg_id]
    c = info.chunk_blocks
    n_chunks = info.n_stripes if fs.sealed else int(info.stripes_written)
    if n_chunks <= 0:
        return
    ost = arr.open_segments.get(info.seg_id)
    for b in sorted(fs.absent):
        if info.uses_append and rec.cst is not None:
            idx = np.arange(n_chunks)
            rec.cst.record_many(b, idx, idx % info.group_size)
        rows = np.zeros((n_chunks, c), dtype=OOB_DTYPE)
        for chunk_idx in range(n_chunks):
            rows[chunk_idx] = arr._reconstruct_oob(rec, b, chunk_idx)
        fs.meta[b] = rows
        if ost is not None:
            ost.meta[b, : n_chunks * c] = rows.reshape(-1)


def _install_segment(arr: ZapRAIDArray, fs: _FoundSegment, zns_cfg) -> None:
    info = fs.info
    rec = _SegmentRecord(info)
    arr.segments[info.seg_id] = rec
    c = info.chunk_blocks

    def fill_open_meta(ost: _OpenSegment) -> None:
        for d, rows in fs.meta.items():
            ost.meta[d, : rows.shape[0] * c] = rows.reshape(-1)

    if fs.sealed or fs.data_complete():
        info.state = int(SegmentState.SEALED)
        info.stripes_written = info.n_stripes
        if not fs.sealed:
            # data region complete, footer missing: keep as open so the
            # re-seal pass below writes the footer.
            info.state = int(SegmentState.OPEN)
            ost = _OpenSegment(info, zns_cfg.block_bytes)
            fill_open_meta(ost)
            arr.open_segments[info.seg_id] = ost
            rec.cst = ost.cst
    else:
        info.state = int(SegmentState.OPEN)
        info.stripes_written = min(
            (rows.shape[0] for rows in fs.meta.values()), default=0
        )
        ost = _OpenSegment(info, zns_cfg.block_bytes)
        fill_open_meta(ost)
        arr.open_segments[info.seg_id] = ost
        rec.cst = ost.cst
    if info.uses_append:
        if rec.cst is None:
            rec.cst = CompactStripeTable(info.n_drives, info.n_stripes, info.group_size)
        for d, rows in fs.meta.items():
            rec.cst.record_many(
                d,
                np.arange(rows.shape[0]),
                rows["stripe"][:, 0].astype(np.int64) % info.group_size,
            )
        if info.seg_id in arr.open_segments:
            arr.open_segments[info.seg_id].cst = rec.cst


def _restore_open_slots(arr: ZapRAIDArray) -> None:
    """Re-adopt scanned open segments as the active write slots.

    Delegates to the array's degraded-aware rotation: open segments spanning
    exactly the active (healthy) drive set are reused in segment-id order;
    anything else -- including survivor-width segments once the drive set is
    healthy again -- is left in place and fresh segments open at the active
    width (``_rewiden`` relocates the narrow leftovers at the end)."""
    arr._rebuild_rotation()


def _harvest_meta(arr, fs: _FoundSegment, user_wins, map_wins) -> None:
    """Scalar harvest baseline: per-chunk/per-block loops into win dicts."""
    info = fs.info
    c = info.chunk_blocks
    scheme = arr._scheme_for(info)  # per-segment: widths may be mixed
    for d, rows_all in fs.meta.items():
        for chunk in range(rows_all.shape[0]):
            rows = rows_all[chunk]
            seq = int(rows["stripe"][0])
            if not fs.sealed and seq not in fs.complete_seqs:
                continue
            if scheme.drive_to_role(d, seq) >= scheme.k:
                continue  # parity chunk
            for b in range(c):
                lba_field = int(rows["lba"][b])
                if lba_field == int(INVALID_LBA):
                    continue
                ts = int(rows["ts"][b])
                pba = pack_pba(info.seg_id, d, info.data_start() + chunk * c + b)
                if lba_field & 1:
                    gid = lba_field >> 1
                    if gid not in map_wins or map_wins[gid][0] < ts:
                        map_wins[gid] = (ts, pba)
                else:
                    lba = lba_field >> 1
                    if lba >= arr.cfg.logical_blocks:
                        continue
                    if lba not in user_wins or user_wins[lba][0] < ts:
                        user_wins[lba] = (ts, pba)


def _harvest_meta_batched(arr, valid):
    """Vectorized harvest + winner resolution over every valid segment.

    Gathers one ``(lba_field, ts, pba)`` triple per live data-region block
    with numpy masks (complete-stripe filter, parity-role filter), then
    resolves the per-key winner with a single lexsort: latest ts wins, and
    among equal timestamps the first-encountered entry wins -- exactly the
    scalar dict's strict-greater update semantics."""
    fields, tss, pbas = [], [], []
    for fs in valid:
        info = fs.info
        scheme = arr._scheme_for(info)  # per-segment: widths may be mixed
        k = scheme.k
        c = info.chunk_blocks
        ds = info.data_start()
        comp = fs.complete_arr() if not fs.sealed else None
        for d, rows in fs.meta.items():
            seqs = rows["stripe"][:, 0].astype(np.int64)
            keep = scheme.drive_to_role_many(d, seqs) < k
            if comp is not None:
                keep &= np.isin(seqs, comp)
            ci = np.flatnonzero(keep)
            if ci.size == 0:
                continue
            f = rows["lba"][ci].ravel().astype(np.uint64)
            live = f != INVALID_LBA
            if not live.any():
                continue
            offs = (ds + ci[:, None] * c + np.arange(c)[None, :]).ravel()
            fields.append(f[live])
            tss.append(rows["ts"][ci].ravel().astype(np.int64)[live])
            pbas.append(pack_pba_many(info.seg_id, d, offs)[live])
    empty = np.zeros(0, np.int64)
    if not fields:
        return empty, empty, empty, empty, empty, empty
    f = np.concatenate(fields)
    t = np.concatenate(tss)
    p = np.concatenate(pbas)
    is_map = (f & np.uint64(1)) != 0
    keys = (f >> np.uint64(1)).astype(np.int64)
    um = ~is_map & (keys < arr.cfg.logical_blocks)
    u = _resolve_winners(keys[um], t[um], p[um])
    m = _resolve_winners(keys[is_map], t[is_map], p[is_map])
    return (*u, *m)


def _resolve_winners(keys, ts, pbas):
    """Latest-ts-wins per key; first-encountered wins ties."""
    if keys.size == 0:
        return keys, ts, pbas
    idx = np.arange(keys.size)
    order = np.lexsort((-idx, ts, keys))
    kk = keys[order]
    last = np.flatnonzero(np.r_[kk[1:] != kk[:-1], True])
    w = order[last]
    return keys[w], ts[w], pbas[w]


def _wins_arrays(wins: dict) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Win dict -> (keys, ts, pbas) arrays (scalar harvest adapter)."""
    n = len(wins)
    keys = np.fromiter(wins.keys(), np.int64, n)
    ts = np.fromiter((v[0] for v in wins.values()), np.int64, n)
    pbas = np.fromiter((v[1] for v in wins.values()), np.int64, n)
    return keys, ts, pbas


def _reinject(
    arr, dirty, u_keys, u_ts, u_pbas, m_keys, m_ts, m_pbas, dirty_ids, drives
) -> set[int]:
    """Rewrite winning blocks whose only copy lives in a dirty segment."""
    by_seg: dict[int, _FoundSegment] = {fs.info.seg_id: fs for fs in dirty}
    reinjected_gids: set[int] = set()
    if not dirty_ids:
        return reinjected_gids

    def read_from_dirty(pba: int) -> np.ndarray:
        seg_id, d, off = unpack_pba(pba)
        fs = by_seg[seg_id]
        p = fs.info.drive_ids[d]  # d is the segment-member index
        return drives[p].read(fs.info.zone_ids[d], off, 1)[0].copy()

    dirty_arr = np.fromiter(sorted(dirty_ids), np.int64, len(dirty_ids))
    ud = np.flatnonzero(np.isin(unpack_pba_many(u_pbas)[0], dirty_arr))
    md = np.flatnonzero(np.isin(unpack_pba_many(m_pbas)[0], dirty_arr))
    items = [
        (int(u_ts[i]), int(u_keys[i]), int(u_pbas[i]), 0) for i in ud
    ] + [
        (int(m_ts[i]), int(m_keys[i]), int(m_pbas[i]), 1) for i in md
    ]
    items.sort()
    for ts, key, pba, is_map in items:
        payload = read_from_dirty(pba)
        arr.stats.recovery_blocks_read += 1
        if is_map:
            arr._append_block(arr._classify(1), -1, payload, ts, meta_gid=key)
            reinjected_gids.add(key)
        else:
            arr._append_block(arr._classify(1), key, payload, ts)
    return reinjected_gids


def _apply_wins(
    arr: ZapRAIDArray,
    u_keys, u_ts, u_pbas, m_keys, m_ts, m_pbas,
    dirty_ids, reinjected_gids,
) -> None:
    """Install the surviving winners: mapping table + bulk L2P (``set_many``)
    + bulk validity (``_mark_valid_many``), preserving the paper's stay-
    offloaded rule for entry groups whose mapping block is newest."""
    epg = arr.l2p.epg
    dirty_arr = (
        np.fromiter(sorted(dirty_ids), np.int64, len(dirty_ids))
        if dirty_ids else np.zeros(0, np.int64)
    )
    u_dirty = np.isin(unpack_pba_many(u_pbas)[0], dirty_arr)
    gids_of = u_keys // epg
    n_groups = arr.l2p.n_groups
    gmax = np.full(n_groups, -1, np.int64)
    ub = gids_of < n_groups
    np.maximum.at(gmax, gids_of[ub], u_ts[ub])
    # groups whose authoritative copy moved during re-injection: the on-SSD
    # mapping block is stale, so the group must stay resident
    dirty_winner_gids = set(np.unique(gids_of[u_dirty]).tolist())
    m_dirty = np.isin(unpack_pba_many(m_pbas)[0], dirty_arr)
    offloaded: list[int] = []
    map_installed: list[int] = []
    for i in range(m_keys.size):
        gid, mts, pba = int(m_keys[i]), int(m_ts[i]), int(m_pbas[i])
        if gid not in reinjected_gids and not m_dirty[i]:
            arr.mapping_table[gid] = pba
            map_installed.append(pba)
        if (
            arr.l2p.offload
            and mts >= (int(gmax[gid]) if gid < n_groups else -1)
            and gid not in dirty_winner_gids
            and gid not in reinjected_gids
        ):
            offloaded.append(gid)
    _mark_valid_many(arr, np.fromiter(map_installed, np.int64, len(map_installed)))
    off_arr = np.fromiter(offloaded, np.int64, len(offloaded))
    u_off = np.isin(gids_of, off_arr)
    install = ~u_dirty & ~u_off
    arr.l2p.set_many(u_keys[install], u_pbas[install])
    # dirty winners were re-injected (L2P points at the new copy already);
    # offloaded-group entries stay on the SSD but their blocks are live
    _mark_valid_many(arr, u_pbas[~u_dirty])
    for gid in offloaded:
        entries = arr._read_mapping_block(gid)
        if entries is None:
            continue
        live = np.asarray(entries, np.int64)
        _mark_valid_many(arr, live[live != int(NO_PBA)])
        arr.l2p.drop_group(gid)
    arr._drain_meta()


def _mark_valid_many(arr: ZapRAIDArray, pbas: np.ndarray) -> None:
    """Vectorized ``_mark_valid``: set validity bits + counts per segment."""
    pbas = np.unique(np.asarray(pbas, np.int64))
    if pbas.size == 0:
        return
    segs, drvs, offs = unpack_pba_many(pbas)
    for seg_id in np.unique(segs):
        rec = arr.segments.get(int(seg_id))
        if rec is None:
            continue
        sel = segs == seg_id
        didx = offs[sel] - rec.info.data_start()
        d = drvs[sel]
        inb = (didx >= 0) & (didx < rec.valid.shape[1])
        d, didx = d[inb], didx[inb]
        cur = rec.valid[d, didx]
        rec.valid[d, didx] = True
        rec.valid_count += int((~cur).sum())
