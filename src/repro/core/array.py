"""ZapRAID controller: a log-structured RAID volume over simulated ZNS drives.

Implements the paper end to end:

* log-structured segments over k+m zones with header/data/footer regions
  (§3.1) and replicated header descriptors;
* group-based data layout (§3.2): Zone-Append segments commit stripes in
  groups of G with a *globally shuffled* completion order (modeling device
  reordering) and record placements in a byte-rounded compact stripe table;
* hybrid data management (§3.3): small-chunk vs large-chunk open segments,
  one small segment reserved for Zone Append, write-size threshold C_l;
* block metadata in OOB + footer, parity-redundant LBA/ts on parity chunks;
* crash consistency (§3.4): header scan -> partial-stripe discard ->
  full-stripe rewrite -> L2P/CST rebuild (footers for sealed, OOB scan for
  open segments), mapping-block-aware L2P recovery;
* degraded reads (CST group search), full-drive recovery (§3.5);
* greedy garbage collection with validity bitmaps (§4);
* L2P offloading with CLOCK eviction into LSB-tagged mapping blocks (§3.1).

The LBA field stored in block metadata is shifted left by one bit: user
blocks use ``lba << 1`` and mapping blocks ``(gid << 1) | 1`` -- the same
LSB-discrimination trick as the paper (which relies on 4 KiB alignment).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core import segment as seg_mod
from repro.core.group_layout import CompactStripeTable
from repro.core.l2p import (
    NO_PBA,
    L2PTable,
    pack_pba,
    pack_pba_many,
    unpack_pba,
    unpack_pba_many,
)
from repro.kernels import ops as kops
from repro.core.raid import (
    StripeCodec,
    decode_meta,
    decode_meta_batch,
    make_scheme,
    parity_oob,
    parity_oob_batch,
)
from repro.core.segment import (
    SegmentClass,
    SegmentInfo,
    SegmentState,
    pack_footer,
    pack_header,
    solve_stripes_per_segment,
    unpack_footer,
    unpack_header,
)
from repro.core.zns import (
    INVALID_LBA,
    OOB_DTYPE,
    CrashBudget,
    DeviceCrashed,
    DriveFailed,
    SimZnsDrive,
    ZnsConfig,
    ZoneState,
    make_array_drives,
)
from repro.integrity.checksum import crc32c_many


class IntegrityError(RuntimeError):
    """Unrepairable corruption: a stripe has lost more blocks (corrupt or
    unreadable media, on top of failed/rebuilding drives) than its parity
    can reconstruct.  Raised *instead of* ever returning wrong bytes to a
    reader -- the loud-failure contract of the verify-on-read and scrub
    paths."""


@dataclasses.dataclass
class ZapRaidConfig:
    scheme: str = "raid5"
    n_drives: int = 4
    group_size: int = 256          # G (>=2 => Zone Append; ==1 => Zone Write)
    chunk_blocks: int = 1          # C in single-class mode
    logical_blocks: int = 2048
    # hybrid data management (§3.3); when enabled, single-class fields unused
    hybrid: bool = False
    n_small: int = 1               # N_s open small-chunk segments
    n_large: int = 0               # N_l open large-chunk segments
    small_chunk_blocks: int = 1    # C_s
    large_chunk_blocks: int = 4    # C_l (also the write-size threshold)
    # L2P offloading
    l2p_memory_limit_entries: Optional[int] = None
    # GC
    gc_free_segments_low: int = 1  # trigger GC when free segments/drive < this
    # Reserved-zone escrow: zones per drive only GC restage may consume.
    # Foreground segment opens refuse to dip below this floor, so a GC pass
    # at very high utilization always has somewhere to restage survivors
    # (fixes the zone-exhaustion deadlock).  Left at 0, the escrow
    # auto-sizes from group geometry on near-full arrays -- see
    # ZapRAIDArray.reserved_zones().
    gc_reserved_zones: int = 0
    # integrity: verify checksums on every read datapath (scalar + batched);
    # a mismatching or unreadable block is treated as erased, reconstructed
    # through parity, and repaired in place.  Off by default: the checksum
    # *store* is always maintained at commit time, only the read-side verify
    # pass is optional (bit-identity with pre-integrity baselines).
    verify_reads: bool = False
    # datapath
    use_pallas: bool = False
    interpret: bool = True
    batched: bool = True           # group-level fused encode + vectorized I/O
    # double-buffered group commits: the fused encode for group g+1 is
    # dispatched (JAX async, donated buffers) before group g's chunks are
    # committed to the drives, with explicit syncs at reads, flush, seal, GC
    # and crash-arming.  Only active on the untimed functional path (the
    # timed pipeline's group barrier is already a sync point).
    overlap: bool = True
    append_seed: int = 1234
    # Zone-Append completion-order source: "timed" derives the disorder from
    # the discrete-event device model (fastest command wins the write
    # pointer; requires a timed pipeline, repro.sim); "rng" is the seeded
    # permutation fallback used by the standalone functional simulator.
    append_order: str = "timed"

    def chunk_sizes(self) -> list[tuple[int, int]]:
        """[(seg_class, chunk_blocks)] for the open-segment classes in use."""
        if not self.hybrid:
            return [(int(SegmentClass.SMALL), self.chunk_blocks)]
        out = []
        if self.n_small:
            out.append((int(SegmentClass.SMALL), self.small_chunk_blocks))
        if self.n_large:
            out.append((int(SegmentClass.LARGE), self.large_chunk_blocks))
        return out


@dataclasses.dataclass
class Stats:
    host_blocks_written: int = 0
    device_blocks_written: int = 0
    stripes_committed: int = 0
    padded_blocks: int = 0
    reads: int = 0
    degraded_reads: int = 0
    cst_entries_accessed: int = 0
    gc_runs: int = 0
    gc_blocks_moved: int = 0
    recovery_blocks_read: int = 0
    meta_blocks_written: int = 0
    # host<->device transfer accounting (bumped by the codec): the
    # device-resident datapath's figure of merit is copies *per group*, not
    # per stripe -- see bench_read_batched / DESIGN.md §9.
    h2d_copies: int = 0
    h2d_bytes: int = 0
    d2h_copies: int = 0
    d2h_bytes: int = 0
    # cache tier (repro.cache), all zero when no cache is attached
    cache_hits: int = 0
    cache_misses: int = 0
    l2p_cache_hits: int = 0      # mapping-block fault-ins served by the cache
    l2p_cache_misses: int = 0    # ... that had to read media
    l2p_cache_offloads: int = 0  # CLOCK evictions spilled into the cache
    # integrity (verify-on-read + scrub), all zero with verification off
    integrity_corruptions_detected: int = 0  # checksum-mismatch blocks seen
    integrity_unreadable_hits: int = 0       # UNC sectors encountered
    integrity_blocks_repaired: int = 0       # blocks rewritten in place
    integrity_scrub_passes: int = 0          # completed scrub_once() sweeps
    integrity_scrub_blocks: int = 0          # blocks bulk-verified by scrub

    def write_amp(self) -> float:
        if self.host_blocks_written == 0:
            return 0.0
        return self.device_blocks_written / self.host_blocks_written


class _StripeArena:
    """Preallocated int32-packed staging arena for one segment class.

    Host blocks are packed exactly once: ``write()`` slice-assigns payload
    bytes into ``pay_u8``, which is a dtype *view* of the int32 lane buffer
    ``pay_i32`` the fused group encode consumes -- no ``np.stack``, no
    re-packing, no per-stripe allocation on the steady-state path.  Slot 0 is
    a permanently-zero row used to pad partial groups up to the codec's
    power-of-two shape buckets with a single fancy-index gather.

    Sized for two full stripe groups plus slack: one group staged in the
    segment's ``group_buffer`` while the previous (double-buffered) group is
    still pending commit, plus the in-flight stripe.
    """

    def __init__(self, k: int, chunk_blocks: int, block_bytes: int, group_size: int):
        assert block_bytes % 4 == 0, "int32 lane packing needs 4-byte blocks"
        self.k = k
        self.c = chunk_blocks
        self.n_slots = 2 * max(group_size, 1) + 4
        lanes = chunk_blocks * block_bytes // 4
        self.pay_i32 = np.zeros((self.n_slots, k, lanes), dtype=np.int32)
        self.pay_u8 = self.pay_i32.view(np.uint8).reshape(
            self.n_slots, k * chunk_blocks, block_bytes
        )
        cap = k * chunk_blocks
        self.lbas = np.full((self.n_slots, cap), -1, dtype=np.int64)
        self.ts = np.zeros((self.n_slots, cap), dtype=np.uint64)
        self.gids = np.full((self.n_slots, cap), -1, dtype=np.int64)
        self._free = list(range(self.n_slots - 1, 0, -1))  # slot 0 = zero pad

    def acquire(self) -> Optional[int]:
        return self._free.pop() if self._free else None

    def release(self, slot: int) -> None:
        self._free.append(slot)

    def gather_packed(self, slots: np.ndarray) -> np.ndarray:
        """(len(slots), k, lanes) int32 gather -- the fused-encode input."""
        return self.pay_i32[slots]


class _InFlightStripe:
    """Accumulates k*C data blocks before encode+commit (paper §3.1).

    Backed by a :class:`_StripeArena` slot when one is available (the
    batched datapath), falling back to private arrays otherwise (legacy
    datapath, or a drained arena)."""

    def __init__(
        self,
        k: int,
        chunk_blocks: int,
        block_bytes: int,
        arena: Optional[_StripeArena] = None,
    ):
        self.k = k
        self.c = chunk_blocks
        self.capacity = k * chunk_blocks
        self.arena = None
        self.slot = None
        if arena is not None:
            slot = arena.acquire()
            if slot is not None:
                self.arena, self.slot = arena, slot
                self.blocks = arena.pay_u8[slot]
                self.lbas = arena.lbas[slot]
                self.ts = arena.ts[slot]
                self.meta_gids = arena.gids[slot]
                # reused slot: reset staging metadata in place (payload bytes
                # are overwritten on add / zeroed by pad_to_full)
                self.lbas[:] = -1
                self.ts[:] = 0
                self.meta_gids[:] = -1
        if self.arena is None:
            self.blocks = np.zeros((self.capacity, block_bytes), dtype=np.uint8)
            self.lbas = np.full(self.capacity, -1, dtype=np.int64)  # -1 = padding
            self.ts = np.zeros(self.capacity, dtype=np.uint64)
            self.meta_gids = np.full(self.capacity, -1, dtype=np.int64)
        self.fill = 0

    def release(self) -> None:
        if self.arena is not None:
            self.arena.release(self.slot)
            self.arena = None

    def add(self, lba: int, block: np.ndarray, ts: int, meta_gid: int = -1) -> None:
        i = self.fill
        self.blocks[i] = block
        self.lbas[i] = lba
        self.ts[i] = ts
        self.meta_gids[i] = meta_gid
        self.fill += 1

    def add_many(
        self, lbas: np.ndarray, blocks: np.ndarray, ts: int,
        meta_gids: Optional[np.ndarray] = None,
    ) -> None:
        """Bulk slice-assign a run of blocks (must fit in remaining capacity)."""
        n = lbas.shape[0]
        i = self.fill
        assert i + n <= self.capacity, (i, n, self.capacity)
        self.blocks[i : i + n] = blocks
        self.lbas[i : i + n] = lbas
        self.ts[i : i + n] = ts
        self.meta_gids[i : i + n] = -1 if meta_gids is None else meta_gids
        self.fill += n

    @property
    def full(self) -> bool:
        return self.fill == self.capacity

    def pad_to_full(self) -> int:
        """Flush path: pad in place -- zero the unfilled arena tail directly
        instead of staging explicit padding blocks through a second copy."""
        pad = self.capacity - self.fill
        if pad and self.arena is not None:
            self.blocks[self.fill :] = 0  # reused slot may hold stale payload
        self.fill = self.capacity
        return pad


class _OpenSegment:
    """Runtime state of one open segment."""

    def __init__(self, info: SegmentInfo, block_bytes: int):
        self.info = info
        self.block_bytes = block_bytes
        n, s, c = info.n_drives, info.n_stripes, info.chunk_blocks
        self.cst = CompactStripeTable(n, s, info.group_size) if info.uses_append else None
        # full per-zone metadata buffer (for footer writes at seal time)
        self.meta = np.zeros((n, s * c), dtype=OOB_DTYPE)
        self.meta["lba"] = INVALID_LBA
        self.group_buffer: list[dict] = []  # staged stripes of the current group

    @property
    def seg_id(self) -> int:
        return self.info.seg_id


class _SegmentRecord:
    """Controller-side record for any live (open or sealed) segment."""

    def __init__(self, info: SegmentInfo):
        self.info = info
        n, s, c = info.n_drives, info.n_stripes, info.chunk_blocks
        self.valid = np.zeros((n, s * c), dtype=bool)  # data-region validity
        self.valid_count = 0
        self.cst: Optional[CompactStripeTable] = None

    def data_capacity(self) -> int:
        k = self.info.k
        return self.info.n_stripes * self.info.chunk_blocks * k


class ZapRAIDArray:
    """The user-facing block volume (paper Figure 3)."""

    def __init__(
        self,
        cfg: ZapRaidConfig,
        zns_cfg: ZnsConfig,
        drives: Optional[list[SimZnsDrive]] = None,
        *,
        _recovering: bool = False,
    ):
        self.cfg = cfg
        self.zns_cfg = zns_cfg
        self.scheme = make_scheme(cfg.scheme, cfg.n_drives)
        self.codec = StripeCodec(
            self.scheme, use_pallas=cfg.use_pallas, interpret=cfg.interpret
        )
        self.stats = Stats()
        self.codec.copy_stats = self.stats
        self.budget = CrashBudget(None)
        self.drives = drives or make_array_drives(cfg.n_drives, zns_cfg, self.budget)
        for d in self.drives:
            d.budget = self.budget
        self.ts_counter = 1
        self.next_seg_id = 0
        self.rng = np.random.default_rng(cfg.append_seed)
        # Timed-pipeline hooks (repro.sim / repro.core.handlers).  When a
        # discrete-event engine drives this array, ``append_plan_fn`` maps a
        # Zone-Append group's ops to their timing-derived completion order
        # (replacing the RNG permutation), and ``commit_listener`` observes
        # every persisted stripe for latency attribution.  Both default to
        # None: the standalone functional array is unchanged.
        self.append_plan_fn = None   # (info, [(s_i, drive_idx)]) -> issue order
        self.commit_listener = None  # (info, built, per_drive_off) -> None
        # Observes every fused-encode sync: (info, n_stripes, host_us).  The
        # timed pipeline uses it to thread encode completions through the
        # engine's accounting so latency stats stay honest about host-side
        # codec stalls (virtual time is unaffected: the encode is host work).
        self.encode_listener = None
        # Observability hook (repro.obs via repro.core.handlers): called as
        # ``obs_event(name, **args)`` at instrumentation points the array
        # alone can see -- degraded decodes, GC pass begin/end.  None (the
        # default) keeps every fast path at one attribute test.
        self.obs_event = None

        # zone allocation: per-drive free zone list (LIFO)
        self.free_zones: list[list[int]] = [
            list(range(zns_cfg.n_zones - 1, -1, -1)) for _ in range(cfg.n_drives)
        ]
        self.segments: dict[int, _SegmentRecord] = {}
        self.open_segments: dict[int, _OpenSegment] = {}
        # open segment ids by class: small[0] is the Zone-Append one
        self.small_ids: list[int] = []
        self.large_ids: list[int] = []
        self._rr_small = 0
        self._rr_large = 0
        self._pending_meta: list[int] = []  # gids awaiting mapping-block write
        self._meta_staging: dict[int, np.ndarray] = {}  # gid -> entries in flight
        # In-flight image count per gid: pending-queue entries plus staged
        # mapping blocks not yet committed.  ``_meta_staging`` is dropped when
        # the count returns to zero (every queued image durable) -- stripe
        # commit re-stamps block timestamps, so a ts match cannot detect this.
        self._meta_refs: dict[int, int] = {}
        self._buffered: dict[int, tuple] = {}  # lba -> (stripe, slot), uncommitted
        self.mapping_table: dict[int, int] = {}  # gid -> pba of mapping block

        self.l2p = L2PTable(
            cfg.logical_blocks,
            memory_limit_entries=cfg.l2p_memory_limit_entries,
            write_mapping_block=self._queue_mapping_block,
            read_mapping_block=self._read_mapping_block,
            entries_per_group=zns_cfg.block_bytes // 4,
        )
        self._in_flight: dict[int, _InFlightStripe] = {}  # per segment class
        # device-resident staging: one packed arena per segment class, and at
        # most one built-but-uncommitted (double-buffered) stripe group
        self._arenas: dict[int, _StripeArena] = {}
        self._pending_group: Optional[dict] = None
        # Latest committed write-timestamp per LBA / mapping group.  Commits
        # can complete out of order across segments (a buffered Zone-Append
        # group lands after a later Zone-Write stripe), so L2P updates are
        # timestamp-guarded.
        self._lba_ts = np.zeros(cfg.logical_blocks, dtype=np.uint64)
        self._gid_ts: dict[int, int] = {}
        # (seg_id, drive_idx) pairs whose zone is awaiting a paced rebuild:
        # the drive has been replaced (healthy but empty there), so reads of
        # those zones must route through reconstruction until the rebuild
        # actor reaches them.  Empty outside a paced rebuild.
        self._rebuild_pending: set[tuple[int, int]] = set()
        # Optional cache tier (repro.cache.ZnsCacheTier) -- see attach_cache.
        self.cache = None
        # True while gc_once() is restaging survivors: segment opens may dip
        # into the gc_reserved_zones escrow only then.
        self._gc_active = False
        # Degraded-mode write width: the physical drives new segments span.
        # Healthy arrays use every drive (member index == drive index, the
        # historical layout, bit-identical).  ``fail_drive`` re-rotates onto
        # the survivors so new stripe groups open at survivor width; rebuild
        # re-widens (see _rewiden).  Mixed widths coexist: every segment
        # carries its own ``drive_ids`` member map.
        self._active_ids: tuple[int, ...] = tuple(range(cfg.n_drives))
        # per-width scheme/codec caches (narrow survivor-width variants of
        # cfg.scheme; the kernel coeff matrices are already lru-cached)
        self._schemes: dict[int, object] = {cfg.n_drives: self.scheme}
        self._codecs: dict[int, StripeCodec] = {cfg.n_drives: self.codec}

        if not _recovering:
            self._open_initial_segments()

    # ------------------------------------------------------------------ util

    def _now(self) -> int:
        self.ts_counter += 1
        return self.ts_counter

    def _layout_for(self, chunk_blocks: int) -> tuple[int, int]:
        return solve_stripes_per_segment(
            self.zns_cfg.zone_cap_blocks, chunk_blocks, self.zns_cfg.block_bytes
        )

    # ---------------------------------------------- mixed-width scheme/codec

    def _scheme_for_width(self, width: int):
        """The cfg scheme instantiated at ``width`` drives (survivor width).

        Raises RuntimeError when the scheme cannot operate that narrow
        (raid6 below 3 drives, raid01 below 2)."""
        sch = self._schemes.get(width)
        if sch is None:
            min_w = 2 if self.scheme.mirror else self.scheme.m + 1
            if width < max(min_w, 1):
                raise RuntimeError(
                    f"{self.cfg.scheme} is not writable at width {width}"
                )
            sch = make_scheme(self.cfg.scheme, width)
            self._schemes[width] = sch
        return sch

    def _codec_for_width(self, width: int) -> StripeCodec:
        codec = self._codecs.get(width)
        if codec is None:
            codec = StripeCodec(
                self._scheme_for_width(width),
                use_pallas=self.cfg.use_pallas, interpret=self.cfg.interpret,
            )
            codec.copy_stats = self.stats
            self._codecs[width] = codec
        return codec

    def _scheme_for(self, info: SegmentInfo):
        return self._scheme_for_width(info.n_drives)

    def _codec_for(self, info: SegmentInfo) -> StripeCodec:
        return self._codec_for_width(info.n_drives)

    def _active_drive_ids(self) -> tuple[int, ...]:
        """Healthy drives new segments may span (mirror widths stay even)."""
        ids = tuple(i for i, d in enumerate(self.drives) if not d.failed)
        if self.scheme.mirror and len(ids) % 2:
            ids = ids[:-1]  # a mirror stripe needs drive pairs
        return ids

    def reserved_zones(self) -> int:
        """Effective GC escrow: zones/drive foreground opens must leave.

        An explicit ``cfg.gc_reserved_zones`` always wins.  Left at 0, the
        escrow *auto-sizes from group geometry* once the array runs
        near-full: when the scarcest drive is down to its last few free
        zones (within ``gc_free_segments_low + 1`` of the auto reserve),
        one restage destination per open segment class is reserved so a GC
        pass at high utilization always has somewhere to restage survivors
        (ROADMAP "smaller known issues").  Roomy arrays see an escrow of
        0 -- historical behavior, bit-identical.

        Auto-sizing needs a live GC watermark: with
        ``gc_free_segments_low == 0`` nothing would clean proactively
        before the floor binds mid-seal, so the escrow would starve
        foreground instead of protecting GC -- such configs (manual-GC
        benches, aging harnesses) keep escrow 0.  It also needs real
        zone headroom: on capacity-tight geometries (a handful of zones
        per drive, logical span close to physical) GC's steady state can
        sit *exactly* at the watermark, and reserving a zone there would
        push the array below its own GC exit threshold for good -- so
        drives with fewer than ``4 * (auto + watermark + 1)`` zones keep
        the historical auto-sizing behavior but still get the 1-zone
        minimum below.

        Manual-GC configs (``gc_free_segments_low == 0``) used to run
        escrow-less: nothing cleans proactively, so foreground could eat
        every last zone -- after which even a *manual* ``gc_once()`` would
        deadlock opening its restage destination.  They now fall back to a
        *1-zone minimum* whenever GC is possible at all (the geometry
        admits at least one segment beyond the open ones), so a GC pass
        always keeps one restage destination.  The fallback minimum gates
        *segment opens only*: it is excluded from ``free_segment_count()``
        so anything reading the watermark arithmetic is unchanged.
        Capacity-tight geometries with a live watermark keep historical
        behavior -- there the inline watermark GC is the protection, and a
        floor would push the array below its own GC exit threshold."""
        if self.cfg.gc_reserved_zones:
            return self.cfg.gc_reserved_zones
        auto = self._auto_reserved_zones()
        if auto:
            return auto
        # fallback: manual-GC configs keep one restage destination zone
        if (
            self.cfg.gc_free_segments_low < 1
            and self.zns_cfg.n_zones >= len(self.cfg.chunk_sizes()) + 2
        ):
            return 1
        return 0

    def _auto_reserved_zones(self) -> int:
        """Geometry-auto-sized escrow (the watermark-shifting part)."""
        if self.cfg.gc_free_segments_low < 1:
            return 0
        auto = len(self.cfg.chunk_sizes())
        headroom = auto + self.cfg.gc_free_segments_low + 1
        if self.zns_cfg.n_zones < 4 * headroom:
            return 0
        return auto if self._min_free_zones() <= headroom else 0

    def _min_free_zones(self) -> int:
        """Scarcest healthy drive's free-zone count (failed drives cannot
        gate foreground opens: new segments span survivors only)."""
        counts = [
            len(fz) for fz, d in zip(self.free_zones, self.drives) if not d.failed
        ]
        return min(counts) if counts else 0

    def free_segment_count(self) -> int:
        """Free segments available to *foreground* writes per drive.

        The GC escrow (``reserved_zones()``) is invisible here unless a
        GC pass is in flight, so GC-trigger watermarks fire before the
        escrow is all that is left.  Only the explicit / auto-sized escrow
        shifts this count; the 1-zone fallback open floor does not (it
        protects exhaustion without perturbing GC schedules)."""
        free = self._min_free_zones()
        if not self._gc_active:
            free -= self.cfg.gc_reserved_zones or self._auto_reserved_zones()
        return max(free, 0)

    def has_staged(self) -> bool:
        """True while foreground work sits in volatile staging: buffered
        blocks of partially filled stripes, a built-but-uncommitted stripe
        group (double buffering), or mapping blocks awaiting their metadata
        write.  The timed pipeline's timeout-flush tick and the service
        tier's idle detection use this to decide whether a ``flush()`` is
        still owed before the system may go quiet."""
        return (
            bool(self._buffered)
            or self._pending_group is not None
            or bool(self._pending_meta)
        )

    # ------------------------------------------------------------- cache tier

    def attach_cache(self, cache) -> None:
        """Install a read/write cache tier (``repro.cache.ZnsCacheTier``).

        The cache indexes *logical* keys (LBA for user blocks, mapping-group
        id for offloaded L2P blocks), so GC relocation and drive rebuild --
        which move physical copies only -- need no cache maintenance.  The
        coherence points are commit-time refresh on overwrite and
        mapping-block commit (both inside the timestamp guards), plus
        read-miss fills.  When the L2P offloads, CLOCK evictions spill the
        evicted group image into the cache so later fault-ins skip media."""
        self.cache = cache
        if self.l2p.offload:
            self.l2p.evict_listener = self._on_l2p_evict

    def _on_l2p_evict(self, gid: int, entries: np.ndarray) -> None:
        if self.cache is None:
            return
        self.stats.l2p_cache_offloads += 1
        self.cache.fill_one(
            (gid << 1) | 1, self._serialize_mapping(entries), force=True
        )

    # -------------------------------------------------------- segment opening

    def _open_initial_segments(self) -> None:
        if not self.cfg.hybrid:
            sid = self._open_segment(SegmentClass.SMALL, self.cfg.chunk_blocks,
                                     self.cfg.group_size)
            self.small_ids = [sid]
        else:
            for i in range(self.cfg.n_small):
                g = self.cfg.group_size if i == 0 else 1  # only one ZA segment
                self.small_ids.append(
                    self._open_segment(SegmentClass.SMALL,
                                       self.cfg.small_chunk_blocks, g)
                )
            for _ in range(self.cfg.n_large):
                self.large_ids.append(
                    self._open_segment(SegmentClass.LARGE,
                                       self.cfg.large_chunk_blocks, 1)
                )

    def _open_segment(self, seg_class: int, chunk_blocks: int, group_size: int) -> int:
        # New segments span the current active drive set: every drive when
        # healthy (member index == drive index), the survivors when degraded.
        drive_ids = self._active_ids
        scheme = self._scheme_for_width(len(drive_ids))
        # Foreground opens stop short of the escrowed zones; only GC restage
        # (self._gc_active) may consume them, so a GC pass at full utilization
        # always has a destination segment (the deadlock fix, ROADMAP item 4).
        floor = 0 if self._gc_active else self.reserved_zones()
        for p in drive_ids:
            if len(self.free_zones[p]) <= floor:
                raise RuntimeError("out of free zones; GC required")
        zone_ids = tuple(self.free_zones[p].pop() for p in drive_ids)
        s, _ = self._layout_for(chunk_blocks)
        info = SegmentInfo(
            seg_id=self.next_seg_id,
            scheme_name=self.scheme.name,
            k=scheme.k,
            m=scheme.m,
            zone_ids=zone_ids,
            chunk_blocks=chunk_blocks,
            group_size=group_size,
            seg_class=int(seg_class),
            create_ts=self._now(),
            n_stripes=s,
            drive_ids=drive_ids,
        )
        self.next_seg_id += 1
        # write the replicated header chunk to every member zone
        hdr_block = pack_header(info, self.zns_cfg.block_bytes)
        hdr_chunk = np.zeros((chunk_blocks, self.zns_cfg.block_bytes), np.uint8)
        hdr_chunk[0] = hdr_block
        oobs = np.zeros(chunk_blocks, dtype=OOB_DTYPE)
        oobs["lba"] = INVALID_LBA
        for p, z in zip(drive_ids, zone_ids):
            self.drives[p].zone_write(z, 0, hdr_chunk, oobs)
            self.stats.device_blocks_written += chunk_blocks
        rec = _SegmentRecord(info)
        self.segments[info.seg_id] = rec
        ost = _OpenSegment(info, self.zns_cfg.block_bytes)
        rec.cst = ost.cst
        self.open_segments[info.seg_id] = ost
        return info.seg_id

    # ------------------------------------------------------------- write path

    def write(self, lba: int, data: np.ndarray) -> None:
        """Write ``data`` (n_blocks x block_bytes uint8) at logical ``lba``."""
        data = np.asarray(data, dtype=np.uint8)
        if data.ndim == 1:
            data = data.reshape(1, -1)
        n = data.shape[0]
        assert data.shape[1] == self.zns_cfg.block_bytes
        assert 0 <= lba and lba + n <= self.cfg.logical_blocks, (lba, n)
        seg_class = self._classify(n)
        if self.cfg.batched:
            self._append_blocks(
                seg_class, np.arange(lba, lba + n, dtype=np.int64), data, 0
            )
        else:
            for i in range(n):
                self._append_block(seg_class, lba + i, data[i], 0)
        self.stats.host_blocks_written += n
        self.maybe_gc()

    def _classify(self, n_blocks: int) -> int:
        if not self.cfg.hybrid or not self.large_ids:
            return int(SegmentClass.SMALL)
        if not self.small_ids:
            return int(SegmentClass.LARGE)
        return (
            int(SegmentClass.SMALL)
            if n_blocks < self.cfg.large_chunk_blocks
            else int(SegmentClass.LARGE)
        )

    def _chunk_blocks_for(self, seg_class: int) -> int:
        if not self.cfg.hybrid:
            return self.cfg.chunk_blocks
        return (
            self.cfg.small_chunk_blocks
            if seg_class == int(SegmentClass.SMALL)
            else self.cfg.large_chunk_blocks
        )

    def _group_size_for(self, seg_class: int) -> int:
        if not self.cfg.hybrid:
            return self.cfg.group_size
        return self.cfg.group_size if seg_class == int(SegmentClass.SMALL) else 1

    def _new_stripe(self, seg_class: int) -> _InFlightStripe:
        """Fresh in-flight stripe, arena-backed on the batched datapath.

        Stripe capacity follows the *active* write width (k shrinks while
        degraded); arenas are keyed per (class, k) so re-widening gets its
        full-width arena back without reallocating."""
        k = self._scheme_for_width(len(self._active_ids)).k
        arena = None
        if self.cfg.batched and self.zns_cfg.block_bytes % 4 == 0:
            arena = self._arenas.get((seg_class, k))
            if arena is None:
                arena = _StripeArena(
                    k, self._chunk_blocks_for(seg_class),
                    self.zns_cfg.block_bytes, self._group_size_for(seg_class),
                )
                self._arenas[(seg_class, k)] = arena
        return _InFlightStripe(
            k, self._chunk_blocks_for(seg_class),
            self.zns_cfg.block_bytes, arena,
        )

    def _append_block(
        self, seg_class: int, lba: int, block: np.ndarray, ts: int, meta_gid: int = -1
    ) -> None:
        # A new write supersedes any still-uncommitted buffered copy of the
        # same LBA (issue order must win even though commit order differs).
        if lba >= 0:
            buf = self._buffered.pop(lba, None)
            if buf is not None:
                old_stripe, slot = buf
                old_stripe.lbas[slot] = -1  # cancel: becomes padding
        stripe = self._in_flight.get(seg_class)
        if stripe is None:
            stripe = self._new_stripe(seg_class)
            self._in_flight[seg_class] = stripe
        if lba >= 0:
            self._buffered[lba] = (stripe, stripe.fill)
        if meta_gid >= 0:
            # staged-in-stripe mapping-block image holds a staging ref until
            # its stripe commits (see _meta_unref)
            self._meta_refs[meta_gid] = self._meta_refs.get(meta_gid, 0) + 1
        stripe.add(lba, block, ts, meta_gid)
        if stripe.full:
            self._dispatch_stripe(seg_class)

    def _append_blocks(
        self,
        seg_class: int,
        lbas: np.ndarray,
        blocks: np.ndarray,
        ts: int,
        meta_gids: Optional[np.ndarray] = None,
    ) -> None:
        """Bulk ``_append_block``: stage a run of blocks, dispatching each
        stripe as it fills.  Payload copies are vectorized slice assignments;
        only the per-LBA buffered-write bookkeeping stays scalar (dict ops).
        Mapping blocks ride the same path (``lbas`` entry -1 with the group
        id in ``meta_gids``); they never enter the buffered-write map.

        Semantically identical to calling ``_append_block`` per block in
        order (including superseding still-buffered copies of the same LBA).
        """
        n = lbas.shape[0]
        i = 0
        while i < n:
            stripe = self._in_flight.get(seg_class)
            if stripe is None:
                stripe = self._new_stripe(seg_class)
                self._in_flight[seg_class] = stripe
            take = min(stripe.capacity - stripe.fill, n - i)
            base = stripe.fill
            if meta_gids is not None:
                for g in meta_gids[i : i + take]:
                    if g >= 0:
                        g = int(g)
                        self._meta_refs[g] = self._meta_refs.get(g, 0) + 1
            stripe.add_many(
                lbas[i : i + take], blocks[i : i + take], ts,
                None if meta_gids is None else meta_gids[i : i + take],
            )
            # bookkeeping after the bulk copy so a duplicate LBA later in this
            # same slice correctly cancels the slot staged earlier in it
            for j in range(i, i + take):
                lba = int(lbas[j])
                if lba < 0:
                    continue  # mapping block / padding
                buf = self._buffered.pop(lba, None)
                if buf is not None:
                    old_stripe, slot = buf
                    old_stripe.lbas[slot] = -1  # cancel: becomes padding
                self._buffered[lba] = (stripe, base + (j - i))
            i += take
            if stripe.full:
                self._dispatch_stripe(seg_class)

    def _commit_all_staged(self) -> None:
        """Pad+commit every in-flight stripe and staged Zone-Append group."""
        progressed = True
        while progressed:
            progressed = False
            for seg_class, stripe in list(self._in_flight.items()):
                if stripe.fill > 0:
                    self.stats.padded_blocks += stripe.pad_to_full()
                    self._dispatch_stripe(seg_class)
                    progressed = True
            for ost in list(self.open_segments.values()):
                if ost.group_buffer:
                    self._commit_group(ost)
                    progressed = True
            if self._pending_group is not None:
                self._sync_pending()
                progressed = True

    def flush(self) -> None:
        """Timeout path (§3.5): pad partial in-flight stripes and commit, then
        flush staged Zone-Append groups, then persist pending mapping blocks.

        Mapping blocks are committed only when no user write is in flight and
        only in metadata-pure stripes: this guarantees a mapping block's
        content covers every user commit with a smaller timestamp, which is
        the invariant the crash-recovery freshness comparison relies on."""
        self._commit_all_staged()
        while self._pending_meta:
            self._drain_meta()
            self._commit_all_staged()

    # -- segment selection (paper §3.3 policy) --------------------------------

    def _select_segment(self, seg_class: int) -> _OpenSegment:
        if seg_class == int(SegmentClass.LARGE) and self.large_ids:
            i = self._rr_large % len(self.large_ids)
            self._rr_large += 1
            return self._rotation_slot(self.large_ids, i, SegmentClass.LARGE,
                                       self.cfg.large_chunk_blocks, 1)
        ids = self.small_ids
        cb = (self.cfg.small_chunk_blocks if self.cfg.hybrid
              else self.cfg.chunk_blocks)
        if len(ids) == 1:
            return self._rotation_slot(ids, 0, SegmentClass.SMALL, cb,
                                       self.cfg.group_size)
        # N_s > 1: round-robin the Zone-Write segments, spill to the reserved
        # Zone-Append segment every cycle (models "no idle ZW segment").
        i = (self._rr_small % len(ids) + 1) % len(ids)
        self._rr_small += 1
        gsz = self.cfg.group_size if i == 0 else 1
        return self._rotation_slot(ids, i, SegmentClass.SMALL, cb, gsz)

    def _rotation_slot(
        self, ids: list, i: int, seg_class, chunk_blocks: int, group_size: int
    ) -> _OpenSegment:
        """Rotation slot -> open segment, re-opening a stale slot.

        A segment roll-over that failed at the reserved-zone floor (loud
        RuntimeError mid-seal) leaves the slot pointing at the sealed
        segment.  Retrying the open here lets a later GC restage
        (floor-exempt via ``_gc_active``) heal the rotation and un-wedge the
        array, while a foreground retry hits the same loud error again."""
        sid = ids[i]
        ost = self.open_segments.get(sid)
        if ost is None:
            ids[i] = sid = self._open_segment(
                int(seg_class), chunk_blocks, group_size
            )
            ost = self.open_segments[sid]
        return ost

    def _pending_count(self, ost: _OpenSegment) -> int:
        """Stripes built-but-uncommitted (double-buffered) for this segment."""
        pend = self._pending_group
        if pend is not None and pend["ost"] is ost:
            return len(pend["seqs"])
        return 0

    def _dispatch_stripe(self, seg_class: int) -> None:
        stripe = self._in_flight.pop(seg_class)
        ost = self._select_segment(seg_class)
        if ost.info.uses_append:
            # stage the RAW stripe; parity encode + timestamping happen at
            # group-commit time so on-disk timestamps reflect commit order.
            ost.group_buffer.append(stripe)
            gsz = ost.info.group_size
            staged = (
                ost.info.stripes_written
                + self._pending_count(ost)
                + len(ost.group_buffer)
            )
            if staged % gsz == 0 or staged == ost.info.n_stripes:
                self._commit_group(ost)
        else:
            built = self._build_stripe(ost, stripe, ost.info.stripes_written)
            self._commit_zone_write(ost, built)
            stripe.release()
        self._maybe_seal(ost)

    # -- stripe construction ---------------------------------------------------

    def _build_stripe(
        self, ost: _OpenSegment, stripe: _InFlightStripe, stripe_seq: int
    ) -> dict:
        """Encode parity; return a commit-ready stripe dict (not yet placed).

        Block timestamps are (re)assigned here -- i.e., at commit time -- so
        the on-disk timestamp order equals the commit order; superseded
        buffered copies were already cancelled in ``_append_block``."""
        info = ost.info
        k, m, c = info.k, info.m, info.chunk_blocks
        bb = self.zns_cfg.block_bytes
        codec = self._codec_for(info)
        commit_ts = self._now()
        stripe.ts[:] = commit_ts
        for slot in range(stripe.capacity):
            lba = int(stripe.lbas[slot])
            if lba >= 0:
                buf = self._buffered.get(lba)
                if buf is not None and buf[0] is stripe and buf[1] == slot:
                    del self._buffered[lba]
        data = stripe.blocks.reshape(k, c * bb)
        parity = codec.encode_np(data).reshape(m, c, bb) if m else np.zeros(
            (0, c, bb), np.uint8
        )
        meta_mask = stripe.meta_gids >= 0
        pad_mask = (stripe.lbas < 0) & ~meta_mask
        lba_fields = np.empty(stripe.capacity, dtype=np.uint64)
        lba_fields[meta_mask] = (
            stripe.meta_gids[meta_mask].astype(np.uint64) << np.uint64(1)
        ) | np.uint64(1)
        lba_fields[pad_mask] = INVALID_LBA
        user_mask = ~meta_mask & ~pad_mask
        lba_fields[user_mask] = stripe.lbas[user_mask].astype(np.uint64) << np.uint64(1)
        data_oob = np.zeros((k, c), dtype=OOB_DTYPE)
        data_oob["lba"] = lba_fields.reshape(k, c)
        data_oob["ts"] = stripe.ts.reshape(k, c)
        data_oob["stripe"] = stripe_seq
        if m:
            p_lba, p_ts = parity_oob(
                codec, data_oob["lba"], data_oob["ts"]
            )
            par_oob = np.zeros((m, c), dtype=OOB_DTYPE)
            par_oob["lba"] = p_lba
            par_oob["ts"] = p_ts
            par_oob["stripe"] = stripe_seq
        else:
            par_oob = np.zeros((0, c), dtype=OOB_DTYPE)
        return {
            "seq": stripe_seq,
            "data": stripe.blocks.reshape(k, c, bb),
            "parity": parity,
            "data_oob": data_oob,
            "par_oob": par_oob,
            "lbas": stripe.lbas.reshape(k, c),
            "ts": stripe.ts.reshape(k, c),
            "meta_gids": stripe.meta_gids.reshape(k, c),
        }

    def _build_group(
        self, ost: _OpenSegment, raws: list[_InFlightStripe], seq0: int
    ) -> dict:
        """Build a whole stripe group and *dispatch* its fused parity encode.

        Bit-identical to the per-stripe ``_build_stripe`` loop -- same commit
        timestamp sequence, same cancellation of superseded buffered copies,
        same completion-order draw -- but the payload is gathered from the
        int32-packed staging arena in one fancy index (power-of-two bucketed
        via the arena's permanent zero slot) and handed to the codec's
        donating async entry point.  The returned group dict carries the
        un-materialized device parity; :meth:`_commit_built_group` syncs on
        it, which is what makes double-buffered commits overlap host commit
        work for group g with the encode of group g+1.
        """
        info = ost.info
        k, m, c = info.k, info.m, info.chunk_blocks
        bb = self.zns_cfg.block_bytes
        scheme = self._scheme_for(info)
        codec = self._codec_for(info)
        s_count = len(raws)
        # commit timestamps: the same values s_count sequential _now() calls
        # would produce, assigned in staging order
        ts0 = self.ts_counter
        self.ts_counter += s_count
        ts_vec = np.arange(ts0 + 1, ts0 + s_count + 1, dtype=np.uint64)
        arena = raws[0].arena
        if arena is not None and all(r.arena is arena for r in raws):
            slots = np.fromiter((r.slot for r in raws), np.int64, s_count)
            target = 1 << max(0, (s_count - 1).bit_length())
            if target != s_count:
                slots_padded = np.concatenate(
                    [slots, np.zeros(target - s_count, np.int64)]  # zero slot
                )
            else:
                slots_padded = slots
            packed = arena.gather_packed(slots_padded)  # (S_pad, k, lanes)
            lbas_all = arena.lbas[slots]                # gather: fresh copies
            gids_all = arena.gids[slots]
        else:  # arena drained / unaligned blocks: stack + host-side pack
            stacked = np.stack([r.blocks for r in raws]).reshape(s_count, k, c * bb)
            padded, _ = StripeCodec._pad_batch(stacked)
            packed = kops.pack_bytes_np(padded)
            lbas_all = np.stack([r.lbas for r in raws])
            gids_all = np.stack([r.meta_gids for r in raws])
        # data payload for the drive commits: a dtype view of the same gather
        data_all = kops.unpack_bytes_np(packed)[:s_count].reshape(s_count, k, c, bb)
        if m and not scheme.mirror:
            parity_dev = codec.encode_batch_async(packed)
        else:
            parity_dev = None  # mirror copies / RAID-0: no device work
        # superseded-copy cancellation marked these slots as padding already;
        # every still-nonnegative LBA is owned by its staging slot
        for lba in lbas_all.ravel():
            if lba >= 0:
                self._buffered.pop(int(lba), None)
        ts_all = np.broadcast_to(ts_vec[:, None], (s_count, k * c))
        seqs = np.arange(seq0, seq0 + s_count, dtype=np.int64)
        meta_mask = gids_all >= 0
        pad_mask = (lbas_all < 0) & ~meta_mask
        user_mask = ~meta_mask & ~pad_mask
        lba_fields = np.empty((s_count, k * c), dtype=np.uint64)
        lba_fields[meta_mask] = (
            gids_all[meta_mask].astype(np.uint64) << np.uint64(1)
        ) | np.uint64(1)
        lba_fields[pad_mask] = INVALID_LBA
        lba_fields[user_mask] = lbas_all[user_mask].astype(np.uint64) << np.uint64(1)
        data_oob = np.zeros((s_count, k, c), dtype=OOB_DTYPE)
        data_oob["lba"] = lba_fields.reshape(s_count, k, c)
        data_oob["ts"] = ts_all.reshape(s_count, k, c)
        data_oob["stripe"] = seqs[:, None, None]
        if m:
            p_lba, p_ts = parity_oob_batch(
                codec, data_oob["lba"], data_oob["ts"]
            )
            par_oob = np.zeros((s_count, m, c), dtype=OOB_DTYPE)
            par_oob["lba"] = p_lba
            par_oob["ts"] = p_ts
            par_oob["stripe"] = seqs[:, None, None]
        else:
            par_oob = np.zeros((s_count, 0, c), dtype=OOB_DTYPE)
        # Zone-Append completion order is drawn at build time so the RNG /
        # device-plan sequence matches the synchronous commit path even when
        # the drive commit itself is deferred one group.
        ops_list = [
            (s_i, d) for s_i in range(s_count) for d in range(info.n_drives)
        ]
        if self.append_plan_fn is not None:
            order = np.asarray(self.append_plan_fn(info, ops_list), np.int64)
        else:
            order = self.rng.permutation(len(ops_list)).astype(np.int64)
        return {
            "ost": ost,
            "raws": raws,
            "seqs": seqs,
            "data_all": data_all,
            "parity_dev": parity_dev,
            "data_oob": data_oob,
            "par_oob": par_oob,
            "lbas_all": lbas_all.reshape(s_count, k, c),
            "ts_all": np.ascontiguousarray(ts_all).reshape(s_count, k, c),
            "gids_all": gids_all.reshape(s_count, k, c),
            "order": order,
        }

    def _role_payload(self, built: dict, role: int):
        k = built["data"].shape[0]
        if role < k:
            return built["data"][role], built["data_oob"][role]
        return built["parity"][role - k], built["par_oob"][role - k]

    # -- commit paths -----------------------------------------------------------

    def _commit_zone_write(self, ost: _OpenSegment, built: dict) -> None:
        """Ordered Zone Write commit: every chunk lands at the static offset."""
        info = ost.info
        c = info.chunk_blocks
        scheme = self._scheme_for(info)
        seq = built["seq"]
        off = info.data_start() + seq * c
        for drive_idx in range(info.n_drives):
            role = scheme.drive_to_role(drive_idx, seq)
            payload, oobs = self._role_payload(built, role)
            zone = info.zone_ids[drive_idx]
            self.drives[info.drive_ids[drive_idx]].zone_write(zone, off, payload, oobs)
            self.stats.device_blocks_written += c
            ost.meta[drive_idx, off - c : off] = oobs  # data-region index = off - C
        info.stripes_written += 1
        self.stats.stripes_committed += 1
        self._finish_stripe_bookkeeping(ost, built, {d: off for d in range(info.n_drives)})

    def _commit_group(self, ost: _OpenSegment) -> None:
        """Zone-Append group commit with globally shuffled completion order.

        On the batched datapath this builds the group, dispatches its fused
        encode asynchronously, commits the *previous* deferred group (whose
        encode has been running meanwhile), and -- when overlap is on and no
        sync point forces otherwise -- leaves the new group pending for the
        next commit/sync, i.e. double-buffering."""
        info = ost.info
        if not ost.group_buffer:
            return
        if not self.cfg.batched:
            self._commit_group_legacy(ost)
            return
        pend = self._pending_group
        pc = len(pend["seqs"]) if (pend is not None and pend["ost"] is ost) else 0
        seq0 = info.stripes_written + pc
        grp = self._build_group(ost, ost.group_buffer, seq0)
        ost.group_buffer = []
        end_of_segment = seq0 + len(grp["seqs"]) == info.n_stripes
        self._sync_pending()  # overlaps with grp's in-flight encode
        defer = (
            self.cfg.overlap
            and not end_of_segment
            and self.budget.remaining is None
            and self.append_plan_fn is None
            and self.commit_listener is None
        )
        if defer:
            self._pending_group = grp
        else:
            self._commit_built_group(grp)

    def _sync_pending(self) -> None:
        """Explicit sync point: commit the deferred (double-buffered) group."""
        if self._pending_group is not None:
            grp = self._pending_group
            self._pending_group = None
            self._commit_built_group(grp)

    def _commit_built_group(self, grp: dict) -> None:
        """Materialize the group's device parity and commit it to the drives.

        Normal path: one bulk Zone-Append run per drive (the per-drive issue
        subsequence of the shuffled completion order) plus fully vectorized
        CST/L2P/validity bookkeeping.  With a crash budget armed the scalar
        per-command loop is kept so power loss cuts at exact block
        granularity, like NAND."""
        ost = grp["ost"]
        info = ost.info
        m, c = info.m, info.chunk_blocks
        n = info.n_drives
        bb = self.zns_cfg.block_bytes
        scheme = self._scheme_for(info)
        codec = self._codec_for(info)
        narrow = len(info.drive_ids) < self.cfg.n_drives
        if narrow and self.obs_event is not None:
            self.obs_event("commit_narrow.begin", seg_id=info.seg_id,
                           width=info.n_drives)
        seqs = grp["seqs"]
        s_count = len(seqs)
        if scheme.mirror:
            parity_all = grp["data_all"]
        elif m:
            t0 = time.perf_counter() if self.encode_listener else 0.0
            parity_np = codec.materialize(grp["parity_dev"])
            if self.encode_listener is not None:
                self.encode_listener(
                    info, s_count, (time.perf_counter() - t0) * 1e6
                )
            parity_all = kops.unpack_bytes_np(parity_np)[:s_count].reshape(
                s_count, m, c, bb
            )
        else:
            parity_all = np.zeros((s_count, 0, c, bb), np.uint8)
        codeword = np.concatenate([grp["data_all"], parity_all], axis=1)
        oob_code = np.concatenate([grp["data_oob"], grp["par_oob"]], axis=1)
        rot = scheme.rotation_many(seqs)
        order = grp["order"]
        offsets = np.empty((s_count, n), dtype=np.int64)
        if self.budget.remaining is not None:
            crashed = None
            for oi in order:
                s_i, drive_idx = divmod(int(oi), n)
                role = int((drive_idx - rot[s_i]) % n)
                zone = info.zone_ids[drive_idx]
                try:
                    off = self.drives[info.drive_ids[drive_idx]].zone_append_commit(
                        zone, codeword[s_i, role], oob_code[s_i, role]
                    )
                except DeviceCrashed as e:
                    crashed = e
                    break
                offsets[s_i, drive_idx] = off
                self.stats.device_blocks_written += c
                ost.meta[drive_idx, off - c : off + 0] = oob_code[s_i, role]
            if crashed is not None:
                for raw in grp["raws"]:
                    raw.release()
                raise crashed
            for d in range(n):
                ost.cst.record_many(
                    d, (offsets[:, d] - info.data_start()) // c,
                    seqs % info.group_size,
                )
        else:
            # one vectorized checksum pass over the whole codeword -- the
            # payload arrays are uint8 views of the packed int32 arenas, so
            # this is the "CRC at commit time on the arenas" point; the
            # per-drive commits below just gather their slice of it
            crc_all = crc32c_many(codeword.reshape(-1, bb)).reshape(
                s_count, n, c
            )
            for d in range(n):
                mask = (order % n) == d
                s_list = order[mask] // n
                roles = (d - rot[s_list]) % n
                payload = codeword[s_list, roles]
                oobs = oob_code[s_list, roles]
                zone = info.zone_ids[d]
                offs = self.drives[info.drive_ids[d]].zone_append_commit_many(
                    zone, payload, oobs, crc_all[s_list, roles]
                )
                self.stats.device_blocks_written += payload.shape[0] * c
                base = int(offs[0]) - c
                ost.meta[d, base : base + offs.shape[0] * c] = oobs.reshape(-1)
                offsets[s_list, d] = offs
                ost.cst.record_many(
                    d, (offs - info.data_start()) // c,
                    seqs[s_list] % info.group_size,
                )
        info.stripes_written += s_count
        self.stats.stripes_committed += s_count
        self._finish_group_bookkeeping(ost, grp, offsets, codeword, parity_all)
        for raw in grp["raws"]:
            raw.release()
        if narrow and self.obs_event is not None:
            self.obs_event("commit_narrow.end", seg_id=info.seg_id)

    def _commit_group_legacy(self, ost: _OpenSegment) -> None:
        """Per-stripe build + per-command commit (``batched=False``)."""
        info = ost.info
        c = info.chunk_blocks
        scheme = self._scheme_for(info)
        narrow = len(info.drive_ids) < self.cfg.n_drives
        if narrow and self.obs_event is not None:
            self.obs_event("commit_narrow.begin", seg_id=info.seg_id,
                           width=info.n_drives)
        staged = [
            self._build_stripe(ost, raw, info.stripes_written + i)
            for i, raw in enumerate(ost.group_buffer)
        ]
        ops = []
        for s_i, built in enumerate(staged):
            for drive_idx in range(info.n_drives):
                ops.append((s_i, drive_idx))
        if self.append_plan_fn is not None:
            # timed mode: completion order falls out of the device model --
            # the fastest command of the batch wins the write pointer
            order = self.append_plan_fn(info, ops)
        else:
            order = self.rng.permutation(len(ops))
        offsets: dict[tuple[int, int], int] = {}
        crashed = None
        for oi in order:
            s_i, drive_idx = ops[oi]
            built = staged[s_i]
            role = scheme.drive_to_role(drive_idx, built["seq"])
            payload, oobs = self._role_payload(built, role)
            zone = info.zone_ids[drive_idx]
            try:
                off = self.drives[info.drive_ids[drive_idx]].zone_append_commit(
                    zone, payload, oobs
                )
            except DeviceCrashed as e:
                crashed = e
                break
            offsets[(s_i, drive_idx)] = off
            self.stats.device_blocks_written += c
            ost.meta[drive_idx, off - c : off + 0] = oobs
        if crashed is not None:
            for raw in ost.group_buffer:
                raw.release()
            ost.group_buffer = []
            raise crashed
        # all appends of the group persisted -> record CST, L2P, ack
        for s_i, built in enumerate(staged):
            per_drive_off = {d: offsets[(s_i, d)] for d in range(info.n_drives)}
            for drive_idx, off in per_drive_off.items():
                chunk_idx = (off - info.data_start()) // c
                ost.cst.record(drive_idx, chunk_idx, built["seq"] % info.group_size)
            info.stripes_written += 1
            self.stats.stripes_committed += 1
            self._finish_stripe_bookkeeping(ost, built, per_drive_off)
        for raw in ost.group_buffer:
            raw.release()
        ost.group_buffer = []
        if narrow and self.obs_event is not None:
            self.obs_event("commit_narrow.end", seg_id=info.seg_id)

    def _finish_stripe_bookkeeping(
        self, ost: _OpenSegment, built: dict, per_drive_off: dict[int, int]
    ) -> None:
        """Post-persist: update L2P / mapping table / validity, ack writes."""
        info = ost.info
        rec = self.segments[info.seg_id]
        k, c = info.k, info.chunk_blocks
        scheme = self._scheme_for(info)
        seq = built["seq"]
        for role in range(k):
            drive_idx = scheme.role_to_drive(role, seq)
            off = per_drive_off[drive_idx]
            for b in range(c):
                lba = int(built["lbas"][role, b])
                gid = int(built["meta_gids"][role, b])
                ts = int(built["ts"][role, b]) if "ts" in built else 0
                blk_off = off + b
                pba = pack_pba(info.seg_id, drive_idx, blk_off)
                didx = blk_off - info.data_start()
                if gid >= 0:  # mapping block
                    self._meta_unref(gid)
                    if ts < self._gid_ts.get(gid, 0):
                        continue  # a newer mapping block already committed
                    self._gid_ts[gid] = ts
                    old = self.mapping_table.get(gid, int(NO_PBA))
                    if old != int(NO_PBA):
                        self._invalidate(old)
                    self.mapping_table[gid] = pba
                    rec.valid[drive_idx, didx] = True
                    rec.valid_count += 1
                    if self.cache is not None:
                        # the committed bytes are what a future fault-in
                        # would read from media: keep the cache copy warm
                        self.cache.fill_one(
                            (gid << 1) | 1, built["data"][role, b], force=True
                        )
                elif lba >= 0:  # user block
                    if ts < int(self._lba_ts[lba]):
                        continue  # stale at birth: a newer write already won
                    self._lba_ts[lba] = ts
                    old = self.l2p.get(lba)
                    if old != int(NO_PBA):
                        self._invalidate(old)
                    self.l2p.set(lba, pba)
                    rec.valid[drive_idx, didx] = True
                    rec.valid_count += 1
                    if self.cache is not None:  # overwrite coherence point
                        self.cache.refresh_one(lba << 1, built["data"][role, b])
        if self.commit_listener is not None:
            self.commit_listener(info, built, per_drive_off)

    def _finish_group_bookkeeping(
        self,
        ost: _OpenSegment,
        grp: dict,
        offsets: np.ndarray,
        codeword: np.ndarray,
        parity_all: np.ndarray,
    ) -> None:
        """Vectorized ``_finish_stripe_bookkeeping`` for a whole group.

        User-block L2P/validity updates collapse into one ``get_many`` /
        ``set_many`` / fancy-index pass (user LBAs are unique within a group:
        duplicates were cancelled into padding at staging time).  Mapping
        blocks are rare and keep the ordered scalar body; so does the whole
        user loop when the L2P offloads, because CLOCK eviction decisions --
        and hence which mapping blocks hit the media -- depend on the exact
        per-block access order the scalar path defines."""
        info = ost.info
        rec = self.segments[info.seg_id]
        k, c = info.k, info.chunk_blocks
        n = info.n_drives
        seqs = grp["seqs"]
        s_count = len(seqs)
        rot = self._scheme_for(info).rotation_many(seqs)
        drive_of = (np.arange(k)[None, :] + rot[:, None]) % n          # (S, k)
        base_off = np.take_along_axis(offsets, drive_of, axis=1)       # (S, k)
        blk_off = base_off[:, :, None] + np.arange(c)[None, None, :]   # (S, k, c)
        drive_f = np.broadcast_to(drive_of[:, :, None], (s_count, k, c)).ravel()
        blk_f = blk_off.ravel()
        pba_f = pack_pba_many(info.seg_id, drive_f, blk_f)
        didx_f = blk_f - info.data_start()
        lba_f = grp["lbas_all"].ravel()
        ts_f = grp["ts_all"].ravel()
        gid_f = grp["gids_all"].ravel()
        if self.cache is not None:
            bb = self.zns_cfg.block_bytes
            data_f = grp["data_all"].reshape(-1, bb)  # aligns with lba_f/gid_f
        for i in np.flatnonzero(gid_f >= 0):  # mapping blocks
            gid, ts = int(gid_f[i]), int(ts_f[i])
            self._meta_unref(gid)
            if ts < self._gid_ts.get(gid, 0):
                continue  # a newer mapping block already committed
            self._gid_ts[gid] = ts
            old = self.mapping_table.get(gid, int(NO_PBA))
            if old != int(NO_PBA):
                self._invalidate(old)
            self.mapping_table[gid] = int(pba_f[i])
            rec.valid[drive_f[i], didx_f[i]] = True
            rec.valid_count += 1
            if self.cache is not None:
                self.cache.fill_one((gid << 1) | 1, data_f[i], force=True)
        user_idx = np.flatnonzero(lba_f >= 0)
        if self.l2p.offload:
            for i in user_idx:
                lba, ts = int(lba_f[i]), int(ts_f[i])
                if ts < int(self._lba_ts[lba]):
                    continue  # stale at birth: a newer write already won
                self._lba_ts[lba] = ts
                old = self.l2p.get(lba)
                if old != int(NO_PBA):
                    self._invalidate(old)
                self.l2p.set(lba, int(pba_f[i]))
                rec.valid[drive_f[i], didx_f[i]] = True
                rec.valid_count += 1
                if self.cache is not None:  # overwrite coherence point
                    self.cache.refresh_one(lba << 1, data_f[i])
        elif user_idx.size:
            lba_u = lba_f[user_idx]
            ok = ts_f[user_idx].astype(np.uint64) >= self._lba_ts[lba_u]
            ui = user_idx[ok]
            lba_u = lba_u[ok]
            self._lba_ts[lba_u] = ts_f[ui]
            old = self.l2p.get_many(lba_u)
            self._invalidate_many(old)
            self.l2p.set_many(lba_u, pba_f[ui])
            rec.valid[drive_f[ui], didx_f[ui]] = True
            rec.valid_count += int(ui.size)
            if self.cache is not None and ui.size:  # overwrite coherence point
                self.cache.refresh_many(lba_u << 1, data_f[ui])
        if self.commit_listener is not None:
            for s_i in range(s_count):
                built = {
                    "seq": int(seqs[s_i]),
                    "data": codeword[s_i, :k],
                    "parity": parity_all[s_i],
                    "data_oob": grp["data_oob"][s_i],
                    "par_oob": grp["par_oob"][s_i],
                    "lbas": grp["lbas_all"][s_i],
                    "ts": grp["ts_all"][s_i],
                    "meta_gids": grp["gids_all"][s_i],
                }
                per_drive_off = {d: int(offsets[s_i, d]) for d in range(n)}
                self.commit_listener(info, built, per_drive_off)

    def _invalidate_many(self, pbas: np.ndarray) -> None:
        """Vectorized ``_invalidate`` (old copies superseded by a group)."""
        pbas = pbas[pbas != int(NO_PBA)]
        if pbas.size == 0:
            return
        segs, drvs, offs = unpack_pba_many(pbas)
        for seg_id in np.unique(segs):
            rec = self.segments.get(int(seg_id))
            if rec is None:
                continue
            sel = segs == seg_id
            didx = offs[sel] - rec.info.data_start()
            d = drvs[sel]
            inb = (didx >= 0) & (didx < rec.valid.shape[1])
            d, didx = d[inb], didx[inb]
            cur = rec.valid[d, didx]
            rec.valid[d, didx] = False
            rec.valid_count -= int(cur.sum())

    def _invalidate(self, pba: int) -> None:
        seg_id, drive, off = unpack_pba(pba)
        rec = self.segments.get(seg_id)
        if rec is None:
            return
        didx = off - rec.info.data_start()
        if 0 <= didx < rec.valid.shape[1] and rec.valid[drive, didx]:
            rec.valid[drive, didx] = False
            rec.valid_count -= 1

    # -- sealing -----------------------------------------------------------------

    def _maybe_seal(self, ost: _OpenSegment) -> None:
        info = ost.info
        if info.stripes_written + self._pending_count(ost) < info.n_stripes:
            return
        if ost.group_buffer:
            self._commit_group(ost)
        self._sync_pending()  # the tail group must land before the footer
        self._seal_segment(ost)

    def _seal_segment(self, ost: _OpenSegment) -> None:
        """Write footer regions (per-zone own metadata) and finish zones.

        Footer serialization is deterministic, so a partially-written footer
        (crash mid-seal) is resumed from the zone's write pointer: the
        already-persisted prefix is identical by construction (§3.4).
        """
        info = ost.info
        footer_start = info.data_start() + info.n_stripes * info.chunk_blocks
        for drive_idx in range(info.n_drives):
            drive = self.drives[info.drive_ids[drive_idx]]
            zone = info.zone_ids[drive_idx]
            foot = pack_footer(ost.meta[drive_idx], self.zns_cfg.block_bytes)
            wp = int(drive.wp[zone])
            skip = wp - footer_start
            assert 0 <= skip <= foot.shape[0], (wp, footer_start, foot.shape)
            if skip < foot.shape[0]:
                rest = foot[skip:]
                oobs = np.zeros(rest.shape[0], dtype=OOB_DTYPE)
                oobs["lba"] = INVALID_LBA
                drive.zone_write(zone, wp, rest, oobs)
                self.stats.device_blocks_written += rest.shape[0]
            drive.finish_zone(zone)
        info.state = int(SegmentState.SEALED)
        del self.open_segments[info.seg_id]
        # replace the open-segment slot with a fresh segment of the same class
        if info.seg_id in self.small_ids:
            i = self.small_ids.index(info.seg_id)
            self.small_ids[i] = self._open_segment(
                SegmentClass(info.seg_class), info.chunk_blocks, info.group_size
            )
        elif info.seg_id in self.large_ids:
            i = self.large_ids.index(info.seg_id)
            self.large_ids[i] = self._open_segment(
                SegmentClass(info.seg_class), info.chunk_blocks, info.group_size
            )

    # ------------------------------------------------------------------ reads

    def read(self, lba: int, n_blocks: int = 1) -> np.ndarray:
        self._sync_pending()  # read-your-writes: deferred group must land
        self.stats.reads += n_blocks
        # single-block reads keep the scalar path: the gather/group machinery
        # costs more than it saves below ~2 blocks (random-read hot path)
        if not self.cfg.batched or n_blocks == 1:
            out = np.zeros((n_blocks, self.zns_cfg.block_bytes), dtype=np.uint8)
            for i in range(n_blocks):
                out[i] = self._read_block(lba + i)
            return out
        return self._read_blocks(np.arange(lba, lba + n_blocks, dtype=np.int64))

    def _read_blocks(self, lbas: np.ndarray) -> np.ndarray:
        """Vectorized multi-block read: one L2P gather, then one numpy gather
        per (segment, drive) the blocks land on; blocks on failed drives are
        collected and reconstructed in one fused decode per surviving-role
        set (the batched degraded-read path).

        With a cache tier attached this is a read-through layer: one batched
        ``lookup_many`` filters the hits (served at cache-device latency),
        only the misses touch the L2P and the drives, and every mapped miss
        -- including reconstructed degraded blocks -- is offered back for
        admission."""
        out = np.zeros((lbas.shape[0], self.zns_cfg.block_bytes), dtype=np.uint8)
        idx = np.arange(lbas.shape[0], dtype=np.int64)
        if self.cache is not None:
            hit, rows = self.cache.lookup_many(lbas << 1)
            n_hit = rows.shape[0]
            if n_hit:
                out[idx[hit]] = rows
                self.stats.cache_hits += n_hit
            self.stats.cache_misses += int(lbas.size) - n_hit
            idx = idx[~hit]
            if idx.size == 0:
                return out
            lbas = lbas[idx]
        pbas = self.l2p.get_many(lbas)
        mapped = idx[pbas != int(NO_PBA)]
        if mapped.size == 0:
            return out
        verify = self.cfg.verify_reads
        segs, drives, offs = unpack_pba_many(pbas[pbas != int(NO_PBA)])
        # faulted: (seg, member, out idxs, zone offs, repairable) -- the last
        # flag is True for media faults on a live drive (checksum mismatch /
        # UNC), where the reconstructed bytes are rewritten in place
        faulted: list[tuple[int, int, np.ndarray, np.ndarray, bool]] = []
        for key in {(int(s), int(d)) for s, d in zip(segs, drives)}:
            seg_id, drive_idx = key  # drive_idx is the segment-member index
            sel = (segs == seg_id) & (drives == drive_idx)
            idxs = mapped[sel]
            s_info = self.segments[seg_id].info
            zone = s_info.zone_ids[drive_idx]
            if (seg_id, drive_idx) in self._rebuild_pending:
                faulted.append((seg_id, drive_idx, idxs, offs[sel], False))
                continue
            drive = self.drives[s_info.drive_ids[drive_idx]]
            try:
                got = drive.read_blocks(zone, offs[sel])
            except DriveFailed:
                faulted.append((seg_id, drive_idx, idxs, offs[sel], False))
                continue
            if verify:
                ok = self._verify_media(drive, zone, offs[sel], got)
                if not ok.all():
                    bad = ~ok
                    faulted.append(
                        (seg_id, drive_idx, idxs[bad], offs[sel][bad], True)
                    )
                    out[idxs[ok]] = got[ok]
                    continue
            out[idxs] = got
        for seg_id, drive_idx, idxs, f_offs, repair in faulted:
            rec = self.segments[seg_id]
            info = rec.info
            c = info.chunk_blocks
            didx = f_offs - info.data_start()
            chunk_idxs, inv = np.unique(didx // c, return_inverse=True)
            chunks, _ = self._reconstruct_chunks(
                rec, drive_idx, chunk_idxs, verify=verify
            )
            out[idxs] = chunks[inv, didx % c]
            self.stats.degraded_reads += int(idxs.size)
            if repair:
                self._repair_in_place(rec, drive_idx, f_offs, out[idxs])
        if self.cache is not None:
            # Offer every mapped miss (reconstructed blocks included) for
            # admission: a warm cache absorbs reconstruction traffic.
            self.cache.fill_many(lbas[pbas != int(NO_PBA)] << 1, out[mapped])
        return out

    def _read_block(self, lba: int) -> np.ndarray:
        if self.cache is not None:
            row = self.cache.lookup_one(lba << 1)
            if row is not None:
                self.stats.cache_hits += 1
                return row.copy()
            self.stats.cache_misses += 1
        pba = self.l2p.get(lba)
        if pba == int(NO_PBA):
            return np.zeros(self.zns_cfg.block_bytes, dtype=np.uint8)
        out = self._read_pba(pba)
        if self.cache is not None:
            self.cache.fill_one(lba << 1, out)
        return out

    def _read_pba(self, pba: int) -> np.ndarray:
        seg_id, drive_idx, off = unpack_pba(pba)  # drive_idx = member index
        if (seg_id, drive_idx) in self._rebuild_pending:
            return self._degraded_read(seg_id, drive_idx, off)
        info = self.segments[seg_id].info
        try:
            drive = self.drives[info.drive_ids[drive_idx]]
            out = drive.read(info.zone_ids[drive_idx], off, 1)[0].copy()
        except DriveFailed:
            return self._degraded_read(seg_id, drive_idx, off)
        if self.cfg.verify_reads:
            offs = np.array([off], dtype=np.int64)
            zone = info.zone_ids[drive_idx]
            if not self._verify_media(drive, zone, offs, out[None, :]).all():
                rec = self.segments[seg_id]
                out = self._degraded_read(seg_id, drive_idx, off)
                self._repair_in_place(rec, drive_idx, offs, out[None, :])
        return out

    # -- integrity: verify / repair (PR 10) -----------------------------------

    def _verify_media(
        self, drive, zone: int, offs: np.ndarray, blocks: np.ndarray
    ) -> np.ndarray:
        """Per-block verdict for a gather: checksum matches and readable.

        Bumps detection counters for every failing block; callers route the
        failures into reconstruction."""
        ok = crc32c_many(blocks) == drive.crc_blocks(zone, offs)
        unc = drive.unc_blocks(zone, offs)
        ok &= ~unc
        n_bad = int((~ok).sum())
        if n_bad:
            self.stats.integrity_corruptions_detected += n_bad
            self.stats.integrity_unreadable_hits += int(unc.sum())
        return ok

    def _repair_in_place(
        self,
        rec: _SegmentRecord,
        member: int,
        offs: np.ndarray,
        blocks: np.ndarray,
        *,
        refresh_cache: bool = True,
    ) -> None:
        """Rewrite reconstructed bytes over corrupt media (no log relocation
        -- L2P and CST are untouched) and re-sync any cache-resident copy.

        ``refresh_cache`` must be False for parity-role blocks: their OOB
        lba field is parity-encoded metadata, not a cache key."""
        info = rec.info
        drive = self.drives[info.drive_ids[member]]
        zone = info.zone_ids[member]
        offs = np.asarray(offs, dtype=np.int64)
        blocks = np.asarray(blocks, dtype=np.uint8).reshape(offs.size, -1)
        drive.repair_blocks(zone, offs, blocks)
        self.stats.integrity_blocks_repaired += int(offs.size)
        if self.obs_event is not None:
            self.obs_event("integrity.repair", seg_id=info.seg_id,
                           member=member, n_blocks=int(offs.size))
        if refresh_cache and self.cache is not None:
            # The OOB lba field *is* the cache key encoding (lba<<1 user,
            # (gid<<1)|1 mapping) for data-role blocks, so a repair can
            # refresh resident copies directly -- a warm cache must never
            # keep serving pre-repair bytes.
            keys = drive.oob[zone, offs]["lba"]
            live = (keys != INVALID_LBA) & (
                keys < np.uint64(2 * self.cfg.logical_blocks)
            )
            if live.any():
                self.cache.refresh_many(keys[live].astype(np.int64),
                                        blocks[live])

    # -- degraded read (§3.5) -------------------------------------------------

    def _degraded_read(self, seg_id: int, failed_drive: int, off: int) -> np.ndarray:
        self.stats.degraded_reads += 1
        rec = self.segments[seg_id]
        info = rec.info
        c = info.chunk_blocks
        didx = off - info.data_start()
        chunk_idx = didx // c
        blk_in_chunk = didx % c
        if self.cfg.verify_reads:
            chunk, _ = self._reconstruct_chunk_checked(rec, failed_drive, chunk_idx)
        else:
            chunk = self._reconstruct_chunk(rec, failed_drive, chunk_idx)
        return chunk[blk_in_chunk]

    def _reconstruct_chunk(
        self, rec: _SegmentRecord, failed_drive: int, chunk_idx: int
    ) -> np.ndarray:
        """Decode the chunk at (failed member, chunk_idx) from survivors."""
        info = rec.info
        c = info.chunk_blocks
        bb = self.zns_cfg.block_bytes
        scheme = self._scheme_for(info)
        codec = self._codec_for(info)
        seq, member_chunks = self._chunk_members(rec, failed_drive, chunk_idx)
        lost_role = scheme.drive_to_role(failed_drive, seq)
        if scheme.mirror:
            # read the surviving twin copy directly
            twin = (lost_role + scheme.k) % (2 * scheme.k)
            for d, cidx in member_chunks.items():
                if scheme.drive_to_role(d, seq) == twin:
                    zone = info.zone_ids[d]
                    return self.drives[info.drive_ids[d]].read(
                        zone, info.data_start() + cidx * c, c
                    ).copy()
            raise RuntimeError("mirror copy also lost")
        rows, roles = [], []
        for d, cidx in member_chunks.items():
            if len(rows) == scheme.k:
                break
            zone = info.zone_ids[d]
            off0 = info.data_start() + cidx * c
            rows.append(
                self.drives[info.drive_ids[d]].read(zone, off0, c).reshape(c * bb)
            )
            roles.append(scheme.drive_to_role(d, seq))
        if len(rows) < scheme.k:
            raise RuntimeError("not enough surviving chunks to decode")
        data = codec.decode_np(np.stack(rows), tuple(roles)).reshape(
            scheme.k, c, bb
        )
        if lost_role < scheme.k:
            return data[lost_role]
        # lost chunk was parity: re-encode
        par = codec.encode_np(data.reshape(scheme.k, c * bb))
        return par.reshape(scheme.m, c, bb)[lost_role - scheme.k]

    def _reconstruct_chunk_checked(
        self, rec: _SegmentRecord, failed_member: int, chunk_idx: int
    ) -> tuple[np.ndarray, np.ndarray]:
        """Verified scalar reconstruction of one lost/corrupt chunk.

        Survivor candidates whose media fails verification are skipped in
        favor of alternates (raid6 tolerates one more loss, mirrors fall to
        the twin); when fewer than ``k`` intact chunks remain the stripe is
        unrepairable and a loud :class:`IntegrityError` surfaces instead of
        garbage bytes.  Returns ``(chunk (c, bb), oobs (c,))``."""
        info = rec.info
        c = info.chunk_blocks
        bb = self.zns_cfg.block_bytes
        scheme = self._scheme_for(info)
        codec = self._codec_for(info)
        seq, members = self._chunk_members(rec, failed_member, chunk_idx)
        lost_role = scheme.drive_to_role(failed_member, seq)
        oobs = np.zeros(c, dtype=OOB_DTYPE)
        oobs["lba"] = INVALID_LBA
        oobs["stripe"] = seq
        if scheme.mirror:
            twin = (lost_role + scheme.k) % (2 * scheme.k)
            for d, cidx in members.items():
                if scheme.drive_to_role(d, seq) != twin:
                    continue
                drive = self.drives[info.drive_ids[d]]
                zone = info.zone_ids[d]
                offs = info.data_start() + cidx * c + np.arange(c)
                blocks = drive.read_blocks(zone, offs)
                if self._verify_media(drive, zone, offs, blocks).all():
                    return blocks.copy(), drive.read_oob_blocks(zone, offs).copy()
            raise IntegrityError(
                f"segment {info.seg_id} stripe {seq}: mirror copy of member "
                f"{failed_member} also lost or corrupt"
            )
        rows, roles, lba_rows, ts_rows = [], [], [], []
        for d, cidx in members.items():
            if len(rows) == scheme.k:
                break
            drive = self.drives[info.drive_ids[d]]
            zone = info.zone_ids[d]
            offs = info.data_start() + cidx * c + np.arange(c)
            blocks = drive.read_blocks(zone, offs)
            if not self._verify_media(drive, zone, offs, blocks).all():
                continue  # corrupt survivor: try an alternate member
            roob = drive.read_oob_blocks(zone, offs)
            rows.append(blocks.reshape(c * bb))
            lba_rows.append(roob["lba"])
            ts_rows.append(roob["ts"])
            roles.append(scheme.drive_to_role(d, seq))
        if len(rows) < scheme.k:
            raise IntegrityError(
                f"segment {info.seg_id} stripe {seq}: only {len(rows)} intact "
                f"chunk(s) of the {scheme.k} needed to reconstruct member "
                f"{failed_member} -- unrepairable double fault"
            )
        data = codec.decode_np(np.stack(rows), tuple(roles)).reshape(
            scheme.k, c, bb
        )
        d_lba, d_ts = decode_meta(
            codec, np.stack(lba_rows), np.stack(ts_rows), tuple(roles)
        )
        if lost_role < scheme.k:
            oobs["lba"] = d_lba[lost_role]
            oobs["ts"] = d_ts[lost_role]
            return data[lost_role].copy(), oobs
        par = codec.encode_np(data.reshape(scheme.k, c * bb)).reshape(
            scheme.m, c, bb
        )
        p_lba, p_ts = parity_oob(codec, d_lba, d_ts)
        oobs["lba"] = p_lba[lost_role - scheme.k]
        oobs["ts"] = p_ts[lost_role - scheme.k]
        return par[lost_role - scheme.k].copy(), oobs

    # -- batched reconstruction (rebuild datapath) ----------------------------

    def _chunk_members(
        self, rec: _SegmentRecord, failed_drive: int, chunk_idx: int
    ) -> tuple[int, dict[int, int]]:
        """(stripe seq, {surviving member -> chunk idx}) for one lost chunk."""
        info = rec.info
        if info.uses_append:
            cst = rec.cst
            assert cst is not None, "CST missing for append segment"
            sid = cst.stripe_id_at(failed_drive, chunk_idx)
            group_idx = chunk_idx // info.group_size
            seq = group_idx * info.group_size + sid
            members = {}
            for d in range(info.n_drives):
                if (
                    d == failed_drive
                    or self.drives[info.drive_ids[d]].failed
                    or (info.seg_id, d) in self._rebuild_pending
                ):
                    continue
                hit = cst.find_in_group(d, group_idx, sid)
                if hit is not None:
                    members[d] = hit
            self.stats.cst_entries_accessed = cst.entries_accessed
        else:
            seq = chunk_idx
            members = {
                d: chunk_idx
                for d in range(info.n_drives)
                if d != failed_drive
                and not self.drives[info.drive_ids[d]].failed
                and (info.seg_id, d) not in self._rebuild_pending
            }
        return seq, members

    def _reconstruct_chunks(
        self,
        rec: _SegmentRecord,
        failed_drive: int,
        chunk_idxs: np.ndarray,
        verify: bool = False,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Batched ``_reconstruct_chunk`` + ``_reconstruct_oob`` over a zone.

        Survivor payloads and OOB rows are gathered with one scatter-read per
        surviving drive, then decoded in one fused call per distinct
        surviving-role set (parity rotation yields at most ``n`` such sets).
        With ``verify`` the survivor gathers are checksum-checked in bulk;
        chunks whose picked survivors fail fall back to the verified scalar
        path (:meth:`_reconstruct_chunk_checked`), which tries alternate
        members and raises :class:`IntegrityError` when the stripe is
        unrepairable.  Returns ``(chunks (N, c, bb), oobs (N, c))``.
        """
        if self.obs_event is not None:
            self.obs_event("degraded.begin", seg_id=rec.info.seg_id,
                           n_chunks=len(chunk_idxs),
                           failed_drive=failed_drive)
        try:
            return self._reconstruct_chunks_obs(
                rec, failed_drive, chunk_idxs, verify
            )
        finally:
            if self.obs_event is not None:
                self.obs_event("degraded.end", seg_id=rec.info.seg_id)

    def _reconstruct_chunks_obs(self, rec, failed_drive, chunk_idxs,
                                verify=False):
        """Body of ``_reconstruct_chunks`` (split so the obs hook can
        bracket the survivor gathers + fused decode with begin/end)."""
        info = rec.info
        scheme = self._scheme_for(info)
        codec = self._codec_for(info)
        k, m, c = scheme.k, scheme.m, info.chunk_blocks
        bb = self.zns_cfg.block_bytes
        n = len(chunk_idxs)
        out = np.zeros((n, c, bb), np.uint8)
        oobs = np.zeros((n, c), dtype=OOB_DTYPE)
        oobs["lba"] = INVALID_LBA
        seqs = np.empty(n, dtype=np.int64)
        chosen: list[list[tuple[int, int]]] = []  # per chunk: [(member, cidx)] * k
        roles_of: list[tuple[int, ...]] = []
        lost_roles = np.empty(n, dtype=np.int64)
        twin_src: list[tuple[int, int]] = []  # mirror: (member, cidx) of the twin
        for pos, chunk_idx in enumerate(int(ci) for ci in chunk_idxs):
            seq, members = self._chunk_members(rec, failed_drive, chunk_idx)
            seqs[pos] = seq
            lost_role = scheme.drive_to_role(failed_drive, seq)
            lost_roles[pos] = lost_role
            if scheme.mirror:
                twin = (lost_role + scheme.k) % (2 * scheme.k)
                src = next(
                    (
                        (d, cidx) for d, cidx in members.items()
                        if scheme.drive_to_role(d, seq) == twin
                    ),
                    None,
                )
                if src is None:
                    raise RuntimeError("mirror copy also lost")
                twin_src.append(src)
                chosen.append([])
                roles_of.append(())
                continue
            picks = list(members.items())[: scheme.k]
            if len(picks) < scheme.k:
                raise RuntimeError("not enough surviving chunks to decode")
            chosen.append(picks)
            roles_of.append(
                tuple(scheme.drive_to_role(d, seq) for d, _ in picks)
            )
        oobs["stripe"] = seqs[:, None]
        # positions whose bulk-gathered survivors failed verification fall
        # back to the verified scalar path (alternate members / loud error)
        bad_positions: set[int] = set()
        if scheme.mirror:
            # one gather per twin drive for payload and OOB alike
            by_drive: dict[int, list[int]] = {}
            for pos, (d, _) in enumerate(twin_src):
                by_drive.setdefault(d, []).append(pos)
            for d, poss in by_drive.items():
                drive = self.drives[info.drive_ids[d]]
                zone = info.zone_ids[d]
                offs = np.concatenate([
                    info.data_start() + twin_src[p][1] * c + np.arange(c)
                    for p in poss
                ])
                raw = drive.read_blocks(zone, offs)
                out[poss] = raw.reshape(-1, c, bb)
                oobs[poss] = drive.read_oob_blocks(zone, offs).reshape(-1, c)
                if verify:
                    okc = self._verify_media(drive, zone, offs, raw) \
                        .reshape(-1, c).all(axis=1)
                    bad_positions.update(
                        p for p, good in zip(poss, okc) if not good
                    )
            for pos in sorted(bad_positions):
                out[pos], oobs[pos] = self._reconstruct_chunk_checked(
                    rec, failed_drive, int(chunk_idxs[pos])
                )
            return out, oobs
        # gather survivor payload + metadata rows, one scatter-read per drive
        rows = np.empty((n, k, c * bb), np.uint8)
        rows_lba = np.empty((n, k, c), np.uint64)
        rows_ts = np.empty((n, k, c), np.uint64)
        by_drive2: dict[int, list[tuple[int, int, int]]] = {}  # d -> (pos, row, cidx)
        for pos, picks in enumerate(chosen):
            for row, (d, cidx) in enumerate(picks):
                by_drive2.setdefault(d, []).append((pos, row, cidx))
        for d, entries in by_drive2.items():
            drive = self.drives[info.drive_ids[d]]
            zone = info.zone_ids[d]
            offs = np.concatenate([
                info.data_start() + cidx * c + np.arange(c)
                for _, _, cidx in entries
            ])
            raw = drive.read_blocks(zone, offs)
            blocks = raw.reshape(-1, c * bb)
            roobs = drive.read_oob_blocks(zone, offs).reshape(-1, c)
            okc = None
            if verify:
                okc = self._verify_media(drive, zone, offs, raw) \
                    .reshape(-1, c).all(axis=1)
            for e, (pos, row, _) in enumerate(entries):
                if okc is not None and not okc[e]:
                    bad_positions.add(pos)
                rows[pos, row] = blocks[e]
                rows_lba[pos, row] = roobs[e]["lba"]
                rows_ts[pos, row] = roobs[e]["ts"]
        # one fused decode per distinct surviving-role set
        role_sets = sorted({
            r for p, r in enumerate(roles_of) if p not in bad_positions
        })
        for roles in role_sets:
            poss = np.array([
                p for p, r in enumerate(roles_of)
                if r == roles and p not in bad_positions
            ])
            data = codec.decode_batch_np(rows[poss], roles).reshape(
                len(poss), k, c, bb
            )
            d_lba, d_ts = decode_meta_batch(
                codec, rows_lba[poss], rows_ts[poss], roles
            )
            lost = lost_roles[poss]
            for data_role in np.unique(lost[lost < k]):
                sel = poss[lost == data_role]
                out[sel] = data[lost == data_role, int(data_role)]
                oobs["lba"][sel] = d_lba[lost == data_role, int(data_role)]
                oobs["ts"][sel] = d_ts[lost == data_role, int(data_role)]
            par_sel = lost >= k
            if np.any(par_sel):
                par = codec.encode_batch_np(
                    data[par_sel].reshape(-1, k, c * bb)
                ).reshape(-1, m, c, bb)
                p_lba, p_ts = parity_oob_batch(
                    codec, d_lba[par_sel], d_ts[par_sel]
                )
                for e, pos in enumerate(poss[par_sel]):
                    role = int(lost_roles[pos]) - k
                    out[pos] = par[e, role]
                    oobs["lba"][pos] = p_lba[e, role]
                    oobs["ts"][pos] = p_ts[e, role]
        for pos in sorted(bad_positions):
            out[pos], oobs[pos] = self._reconstruct_chunk_checked(
                rec, failed_drive, int(chunk_idxs[pos])
            )
        return out, oobs

    # ------------------------------------------------------- L2P offload plumbing

    def _queue_mapping_block(self, gid: int, entries: np.ndarray) -> None:
        # Staged until the mapping block is durably committed: fault-ins of
        # this group must see the staged entries, not the stale on-SSD block.
        self._meta_staging[gid] = entries.copy()
        self._pending_meta.append(gid)
        self._meta_refs[gid] = self._meta_refs.get(gid, 0) + 1

    def _meta_unref(self, gid: int) -> None:
        """One queued image of ``gid`` became durable; drop the host-side
        staging copy once no in-flight image remains."""
        refs = self._meta_refs.get(gid, 0) - 1
        if refs > 0:
            self._meta_refs[gid] = refs
        elif refs == 0:
            del self._meta_refs[gid]
            self._meta_staging.pop(gid, None)  # durable now
        # refs < 0: a GC-restaged copy of an already-durable block -- no
        # staging existed for it, nothing to do.

    def _drain_meta(self) -> None:
        while self._pending_meta:
            gid = self._pending_meta.pop(0)
            if self.l2p.offload and gid in self.l2p.resident:
                # the group was faulted back in after eviction: the resident
                # copy is the freshest image -- serialize that one, and clear
                # its dirty bit (the on-SSD block is now current).
                entries = self.l2p.resident[gid].copy()
                self.l2p.dirty.discard(gid)
                self._meta_staging[gid] = entries
            else:
                entries = self._meta_staging.get(gid)
            if entries is None:
                # superseded (faulted back in and re-evicted): release the
                # pending entry's ref without writing anything
                self._meta_unref(gid)
                continue
            block = self._serialize_mapping(entries)
            ts = self._now()
            # _append_block takes the in-stripe ref before we release the
            # pending one, so refs never dip to zero across the handoff
            self._append_block(self._classify(1), -1, block, ts, meta_gid=gid)
            self._meta_unref(gid)
            self.stats.meta_blocks_written += 1

    def _serialize_mapping(self, entries: np.ndarray) -> np.ndarray:
        """Pack int64 PBAs into 32-bit on-disk entries (seg<<20|drive<<16|off)."""
        out = np.full(self.zns_cfg.block_bytes // 4, 0xFFFFFFFF, dtype=np.uint32)
        for i, pba in enumerate(entries):
            pba = int(pba)
            if pba == int(NO_PBA):
                continue
            seg, drive, off = unpack_pba(pba)
            assert seg < (1 << 12) and drive < 16 and off < (1 << 16), (
                "array too large for 32-bit mapping entries"
            )
            out[i] = (seg << 20) | (drive << 16) | off
        return out.view(np.uint8)

    def _deserialize_mapping(self, block: np.ndarray) -> np.ndarray:
        raw = block.view(np.uint32)
        out = np.full(raw.shape[0], NO_PBA, dtype=np.int64)
        live = raw != 0xFFFFFFFF
        seg = (raw[live] >> 20).astype(np.int64)
        drive = ((raw[live] >> 16) & 0xF).astype(np.int64)
        off = (raw[live] & 0xFFFF).astype(np.int64)
        out[live] = (seg << 40) | (drive << 32) | off
        return out

    def _read_mapping_block(self, gid: int) -> Optional[np.ndarray]:
        staged = self._meta_staging.get(gid)
        if staged is not None:
            return staged.copy()  # evicted but not yet durable
        pba = self.mapping_table.get(gid)
        if pba is None:
            return None
        if self.cache is not None:
            # Mapping-table cache: fault-ins beyond the CLOCK resident
            # budget are served from the cache tier instead of media.
            row = self.cache.lookup_one((gid << 1) | 1)
            if row is not None:
                self.stats.l2p_cache_hits += 1
                return self._deserialize_mapping(row)
            self.stats.l2p_cache_misses += 1
        block = self._read_pba(pba)
        if self.cache is not None:
            self.cache.fill_one((gid << 1) | 1, block, force=True)
        return self._deserialize_mapping(block)

    # -------------------------------------------------------------------- GC

    def maybe_gc(self) -> None:
        while self.free_segment_count() < self.cfg.gc_free_segments_low:
            before = self.free_segment_count()
            if not self.gc_once():
                break
            if self.free_segment_count() <= before:
                # a pass that nets no free segment cannot converge on the
                # watermark (everything live, restage consumes what the
                # victim frees) -- stop instead of collecting in a loop
                break

    def _gc_select_victim(self) -> Optional[_SegmentRecord]:
        """Greedy cost-benefit victim scoring (§4), vectorized across all
        sealed segments: ``score = (1 - u) / (1 + u) * age`` with ``u`` the
        valid fraction -- the classic LFS cost-benefit policy instead of a
        plain min-valid scan.  Shared by the scalar and batched datapaths so
        both collect the same victim sequence (bit-identity)."""
        recs = [
            r for r in self.segments.values()
            if r.info.state == int(SegmentState.SEALED)
        ]
        if not recs:
            return None
        n = len(recs)
        valid = np.fromiter((r.valid_count for r in recs), np.float64, n)
        cap = np.fromiter((r.data_capacity() for r in recs), np.float64, n)
        u = valid / np.maximum(cap, 1.0)
        age = np.maximum(
            self.ts_counter
            - np.fromiter((r.info.create_ts for r in recs), np.float64, n),
            1.0,
        )
        score = np.where(u < 1.0, (1.0 - u) / (1.0 + u) * age, -np.inf)
        best = int(np.argmax(score))
        if not np.isfinite(score[best]):
            return None  # every sealed segment is fully live
        return recs[best]

    def _gc_collect_batched(
        self, rec: _SegmentRecord
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Gather the victim's live blocks: one payload gather + one OOB
        gather per drive, liveness split with numpy masks (no per-block
        loops, no ``(lba, block)`` tuple lists).  A failed drive routes
        through the fused whole-chunk reconstruction instead of per-block
        degraded reads.  Returns ``(user_lbas, user_blocks, meta_gids,
        meta_blocks)`` in scalar collection order (drive-major, ascending
        data index)."""
        info = rec.info
        c = info.chunk_blocks
        bb = self.zns_cfg.block_bytes
        lba_parts: list[np.ndarray] = []
        blk_parts: list[np.ndarray] = []
        for drive_idx in range(info.n_drives):
            didxs = np.flatnonzero(rec.valid[drive_idx])
            if didxs.size == 0:
                continue
            drive = self.drives[info.drive_ids[drive_idx]]
            zone = info.zone_ids[drive_idx]
            if (
                drive.failed
                or (info.seg_id, drive_idx) in self._rebuild_pending
            ):
                chunk_idxs, inv = np.unique(didxs // c, return_inverse=True)
                chunks, oob_all = self._reconstruct_chunks(rec, drive_idx, chunk_idxs)
                blocks = chunks[inv, didxs % c]
                lba_parts.append(oob_all["lba"][inv, didxs % c].astype(np.uint64))
                self.stats.degraded_reads += int(didxs.size)
            else:
                offs = info.data_start() + didxs
                # read_blocks gathers via advanced indexing: already a fresh
                # array, no defensive copy needed
                blocks = drive.read_blocks(zone, offs)
                oob_arr = drive.read_oob_blocks(zone, offs)
                lba_parts.append(oob_arr["lba"].astype(np.uint64))
            blk_parts.append(blocks)
        if not lba_parts:
            empty = np.zeros(0, np.int64)
            none = np.zeros((0, bb), np.uint8)
            return empty, none, empty, none
        lba_fields = np.concatenate(lba_parts)
        blocks = blk_parts[0] if len(blk_parts) == 1 else np.concatenate(blk_parts)
        live = lba_fields != INVALID_LBA
        is_meta = ((lba_fields & np.uint64(1)) != 0) & live
        user = live & ~is_meta
        keys = (lba_fields >> np.uint64(1)).astype(np.int64)
        return keys[user], blocks[user], keys[is_meta], blocks[is_meta]

    def _gc_collect_scalar(
        self, rec: _SegmentRecord
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Per-block collection baseline (``batched=False``): one read + OOB
        read per live block, per-block degraded reads on a failed drive."""
        info = rec.info
        c = info.chunk_blocks
        bb = self.zns_cfg.block_bytes
        u_lbas: list[int] = []
        u_blocks: list[np.ndarray] = []
        m_gids: list[int] = []
        m_blocks: list[np.ndarray] = []
        for drive_idx in range(info.n_drives):
            drive = self.drives[info.drive_ids[drive_idx]]
            zone = info.zone_ids[drive_idx]
            pending = (info.seg_id, drive_idx) in self._rebuild_pending
            for didx in np.flatnonzero(rec.valid[drive_idx]):
                off = info.data_start() + int(didx)
                try:
                    if pending:
                        raise DriveFailed("zone awaiting paced rebuild")
                    block = drive.read(zone, off, 1)[0].copy()
                    oob = drive.read_oob(zone, off, 1)[0]
                except DriveFailed:
                    block = self._degraded_read(info.seg_id, drive_idx, off)
                    oob = self._reconstruct_oob(rec, drive_idx, int(didx) // c)[
                        int(didx) % c
                    ]
                lba_field = int(oob["lba"])
                if lba_field == int(INVALID_LBA):
                    continue
                if lba_field & 1:
                    m_gids.append(lba_field >> 1)
                    m_blocks.append(block)
                else:
                    u_lbas.append(lba_field >> 1)
                    u_blocks.append(block)

        def pack(lbas: list[int], blks: list[np.ndarray]):
            if not lbas:
                return np.zeros(0, np.int64), np.zeros((0, bb), np.uint8)
            return np.array(lbas, np.int64), np.stack(blks)

        ul, ub = pack(u_lbas, u_blocks)
        mg, mb = pack(m_gids, m_blocks)
        return ul, ub, mg, mb

    def gc_once(self) -> bool:
        """Greedy GC (§4): collect the best cost-benefit victim's live blocks
        and restage them through the normal write path, then reclaim the
        victim's zones.  On the batched datapath collection is one gather +
        OOB read per drive, liveness/eligibility are numpy masks over
        ``l2p.get_many``, and the survivors bulk-stage straight into the
        int32-packed arenas (the donated fused re-encode); mapping blocks
        batch the same way.  The scalar path stays as the bit-identical
        per-block baseline."""
        # deferred commits must land first: GC reads validity/L2P state that a
        # pending group is about to update (its old copies would look live)
        self._sync_pending()
        rec = self._gc_select_victim()
        if rec is None:
            return False
        self.stats.gc_runs += 1
        if self.obs_event is not None:
            self.obs_event("gc.begin", seg_id=rec.info.seg_id)
        moved0 = self.stats.gc_blocks_moved
        # Restage segment opens may consume the reserved-zone escrow while
        # this pass runs (cleared before both exits below).
        self._gc_active = True
        info = rec.info
        self._restage_live(rec)
        self.flush()
        self._release_segment(rec)
        self._gc_active = False
        if self.obs_event is not None:
            self.obs_event("gc.end", seg_id=info.seg_id,
                           blocks_moved=self.stats.gc_blocks_moved - moved0)
        return True

    def _restage_live(self, rec: _SegmentRecord) -> None:
        """Collect ``rec``'s live blocks and restage the still-eligible ones
        through the normal write path (the middle of a GC pass; also the
        re-widening relocation of survivor-width segments -- see _rewiden)."""
        info = rec.info
        if self.cfg.batched:
            u_lbas, u_blocks, m_gids, m_blocks = self._gc_collect_batched(rec)
        else:
            u_lbas, u_blocks, m_gids, m_blocks = self._gc_collect_scalar(rec)
        # rewrites go to a large-chunk segment when hybrid (§3.3)
        target_class = (
            int(SegmentClass.LARGE)
            if (self.cfg.hybrid and self.large_ids)
            else int(SegmentClass.SMALL)
        )
        if self.cfg.batched and not self.l2p.offload:
            # GC'd LBAs are unique (one live copy each), so eligibility can be
            # decided up front and the survivors staged in one bulk append.
            if u_lbas.size:
                pbas = self.l2p.get_many(u_lbas)
                segs, _, _ = unpack_pba_many(pbas)
                buffered = np.fromiter(
                    (int(l) in self._buffered for l in u_lbas), bool, u_lbas.size
                )
                sel = np.flatnonzero(
                    (pbas != int(NO_PBA)) & (segs == info.seg_id) & ~buffered
                )
                if sel.size:
                    self._append_blocks(target_class, u_lbas[sel], u_blocks[sel], 0)
                    self.stats.gc_blocks_moved += int(sel.size)
        else:
            # scalar restage -- also the L2P-offload path, where CLOCK
            # eviction decisions depend on the exact per-block access order
            for i in range(u_lbas.size):
                lba = int(u_lbas[i])
                if lba in self._buffered:
                    continue  # a newer user write is in flight; old copy is dead
                pba = self.l2p.get(lba)
                if pba == int(NO_PBA) or unpack_pba(pba)[0] != info.seg_id:
                    continue  # stale by now
                self._append_block(target_class, lba, u_blocks[i], 0)
                self.stats.gc_blocks_moved += 1
        if self.cfg.batched and m_gids.size:
            # mapping blocks batch regardless of L2P offload: the mapping
            # table is a plain dict (no CLOCK), so upfront eligibility and
            # bulk staging are order-equivalent to the scalar loop
            mt = np.fromiter(
                (self.mapping_table.get(int(g), int(NO_PBA)) for g in m_gids),
                np.int64, m_gids.size,
            )
            msegs, _, _ = unpack_pba_many(mt)
            msel = np.flatnonzero((mt != int(NO_PBA)) & (msegs == info.seg_id))
            if msel.size:
                self._append_blocks(
                    target_class,
                    np.full(msel.size, -1, np.int64),
                    m_blocks[msel], 0,
                    meta_gids=m_gids[msel],
                )
                self.stats.gc_blocks_moved += int(msel.size)
        elif m_gids.size:
            for i in range(m_gids.size):
                gid = int(m_gids[i])
                pba = self.mapping_table.get(gid)
                if pba is None or unpack_pba(pba)[0] != info.seg_id:
                    continue
                self._append_block(target_class, -1, m_blocks[i], 0, meta_gid=gid)
                self.stats.gc_blocks_moved += 1

    def _release_segment(self, rec: _SegmentRecord) -> None:
        """Reclaim every member zone of ``rec`` and drop the segment.

        A failed member's zone is returned to that drive's free list without
        a device reset (the drive cannot take commands; ``replace()`` wipes
        its media wholesale), so GC keeps reclaiming while degraded."""
        info = rec.info
        for drive_idx in range(info.n_drives):
            p = info.drive_ids[drive_idx]
            if not self.drives[p].failed:
                self.drives[p].reset_zone(info.zone_ids[drive_idx])
            self.free_zones[p].append(info.zone_ids[drive_idx])
            self._rebuild_pending.discard((info.seg_id, drive_idx))
        self.open_segments.pop(info.seg_id, None)
        del self.segments[info.seg_id]

    # -------------------------------------------------------------- drive fail

    def fail_drive(self, drive_idx: int) -> None:
        """Mark a drive failed and re-rotate writes onto the survivors.

        Staged blocks (partial stripes, buffered Zone-Append groups) are
        drained host-side and restaged at survivor width, so the array stays
        fully writable while degraded: new segments open at k-1 data + m
        parity on the healthy drives, existing full-width open segments
        freeze until rebuild re-adopts them.  When the scheme cannot operate
        at the survivor width (raid6 past two failures, raid0 data loss) the
        rotation is left alone and the next write raises."""
        self._sync_pending()  # the deferred group still owns healthy drives
        self.drives[drive_idx].fail()
        try:
            self._scheme_for_width(len(self._active_drive_ids()))
        except RuntimeError:
            return  # not writable this narrow; reads still decode
        staged = self._drain_staged()
        self._rebuild_rotation()
        self._restage_drained(staged)

    def _drain_staged(self) -> list[tuple[int, int, np.ndarray, int]]:
        """Pull every volatile staged block back to the host: in-flight
        partial stripes and buffered (uncommitted) Zone-Append stripes.
        Returns [(seg_class, lba, block, meta_gid)] in staging order and
        releases the arena slots -- the caller restages after changing the
        write rotation (fail_drive / _rewiden)."""
        self._sync_pending()
        staged: list[tuple[int, int, np.ndarray, int]] = []

        def collect(seg_class: int, stripe: _InFlightStripe) -> None:
            for i in range(stripe.fill):
                lba = int(stripe.lbas[i])
                gid = int(stripe.meta_gids[i])
                if lba < 0 and gid < 0:
                    continue  # padding or a cancelled superseded copy
                if lba >= 0:
                    self._buffered.pop(lba, None)
                staged.append((seg_class, lba, stripe.blocks[i].copy(), gid))
            stripe.release()

        for ost in self.open_segments.values():
            for stripe in ost.group_buffer:
                collect(ost.info.seg_class, stripe)
            ost.group_buffer = []
        for seg_class, stripe in list(self._in_flight.items()):
            collect(seg_class, stripe)
        self._in_flight.clear()
        return staged

    def _restage_drained(self, staged: list[tuple[int, int, np.ndarray, int]]) -> None:
        for seg_class, lba, block, gid in staged:
            self._append_block(seg_class, lba, block, 0, meta_gid=gid)
            if gid >= 0:
                # the drained copy's staging ref moves to the re-appended one
                self._meta_unref(gid)

    def _rebuild_rotation(self) -> None:
        """Point the open-segment rotation at the current active drive set.

        Re-adopts existing open segments that span exactly the active drives
        (in seg_id order) and opens fresh ones at active width for the rest.
        Open segments at other widths stay open but leave the rotation --
        frozen full-width segments while degraded, survivor-width segments
        after a re-widening rebuild (the latter are then relocated away by
        _rewiden)."""
        ids = self._active_drive_ids()
        self._scheme_for_width(len(ids))  # raises if unwritable this narrow
        self._active_ids = ids
        by_class: dict[tuple[int, bool], list[int]] = {}
        for sid in sorted(self.open_segments):
            ost = self.open_segments[sid]
            info = ost.info
            if info.drive_ids != ids:
                continue
            if info.stripes_written + self._pending_count(ost) >= info.n_stripes:
                continue  # data-complete: will seal, not take new stripes
            if any((sid, d) in self._rebuild_pending for d in range(info.n_drives)):
                continue
            by_class.setdefault(
                (info.seg_class, info.uses_append), []
            ).append(sid)

        def take(seg_class: int, chunk_blocks: int, group_size: int) -> int:
            lst = by_class.get((int(seg_class), group_size > 1))
            if lst:
                return lst.pop(0)
            return self._open_segment(seg_class, chunk_blocks, group_size)

        if not self.cfg.hybrid:
            self.small_ids = [
                take(SegmentClass.SMALL, self.cfg.chunk_blocks, self.cfg.group_size)
            ]
            self.large_ids = []
            return
        small, large = [], []
        for i in range(self.cfg.n_small):
            g = self.cfg.group_size if i == 0 else 1  # only one ZA segment
            small.append(take(SegmentClass.SMALL, self.cfg.small_chunk_blocks, g))
        for _ in range(self.cfg.n_large):
            large.append(take(SegmentClass.LARGE, self.cfg.large_chunk_blocks, 1))
        self.small_ids, self.large_ids = small, large

    def _rewiden(self) -> None:
        """Re-widen after rebuild: move writes back to the full drive set and
        relocate survivor-width segments onto full-width stripes.

        Narrow groups are read (fused decode where a member is still
        failed), re-encoded at the active width through the normal write
        path, and their zones reclaimed -- the re-widening backfill.  With
        multiple failures (raid6) only segments *narrower than the current
        active width* relocate; full-width segments holding a still-failed
        member wait for that drive's own rebuild."""
        try:
            ids = self._active_drive_ids()
            self._scheme_for_width(len(ids))
        except RuntimeError:
            return  # still too degraded to write; nothing to re-widen onto
        staged = self._drain_staged()
        self._rebuild_rotation()
        self._restage_drained(staged)
        narrow = [
            rec for sid, rec in sorted(self.segments.items())
            if len(rec.info.drive_ids) < len(ids)
        ]
        if not narrow:
            return
        if self.obs_event is not None:
            self.obs_event("rewiden.begin", n_segments=len(narrow))
        self._gc_active = True  # relocation may consume the GC escrow
        try:
            for rec in narrow:
                self._restage_live(rec)
                self.flush()
                self._release_segment(rec)
        finally:
            self._gc_active = False
        if self.obs_event is not None:
            self.obs_event("rewiden.end", n_segments=len(narrow))

    def rebuild_drive(self, drive_idx: int) -> None:
        """Full-drive recovery (§3.5) onto a replacement drive, then
        re-widen: survivor-width segments written while degraded are
        re-encoded at full width and backfilled across all drives."""
        self._sync_pending()
        self.drives[drive_idx].replace()
        scaffold: dict[int, tuple[np.ndarray, np.ndarray, np.ndarray]] = {}
        for rec in sorted(self.segments.values(), key=lambda r: r.info.seg_id):
            self._rebuild_segment(rec, drive_idx, scaffold)
        self._rewiden()

    def _rebuild_scaffold(
        self, scaffold: dict, chunk_blocks: int
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Header/OOB/metadata scratch buffers, allocated once per chunk size
        and reused across every rebuilt segment (not per segment)."""
        tmpl = scaffold.get(chunk_blocks)
        if tmpl is None:
            c = chunk_blocks
            bb = self.zns_cfg.block_bytes
            hdr_chunk = np.zeros((c, bb), np.uint8)
            hdr_oob = np.zeros(c, dtype=OOB_DTYPE)
            hdr_oob["lba"] = INVALID_LBA
            s_max, _ = self._layout_for(c)
            meta_buf = np.zeros(s_max * c, dtype=OOB_DTYPE)
            tmpl = (hdr_chunk, hdr_oob, meta_buf)
            scaffold[chunk_blocks] = tmpl
        return tmpl

    def _rebuild_segment(
        self, rec: _SegmentRecord, drive_idx: int, scaffold: dict
    ) -> None:
        """Reconstruct one segment's zone onto the (already replaced) drive.

        ``rebuild_drive`` calls this for every live segment; the timed
        pipeline's paced rebuild actor calls it one segment per tick so the
        reconstruction traffic contends with foreground I/O over time.
        ``drive_idx`` is the *physical* drive: segments the replaced drive is
        not a member of (survivor-width groups written while it was failed)
        are skipped here -- re-widening relocates them instead (_rewiden).
        ``scaffold`` is the caller-held scratch-buffer cache (see
        :meth:`_rebuild_scaffold`) -- required, so the per-segment
        reallocation this refactor removed cannot quietly return."""
        info = rec.info
        if drive_idx not in info.drive_ids:
            return
        member = info.drive_ids.index(drive_idx)
        new = self.drives[drive_idx]
        scheme = self._scheme_for(info)
        zone = info.zone_ids[member]
        c = info.chunk_blocks
        bb = self.zns_cfg.block_bytes
        hdr_chunk, hdr_oob, meta_buf = self._rebuild_scaffold(scaffold, c)
        hdr_chunk[:] = 0
        hdr_chunk[0] = pack_header(info, bb)
        new.zone_write(zone, 0, hdr_chunk, hdr_oob)
        # how far was this zone written? mirror a surviving zone's shape:
        # sealed => full layout; open => per-CST/our records
        ost = self.open_segments.get(info.seg_id)
        if ost is not None:
            n_chunks = self._zone_chunk_count(rec, member)
        else:
            n_chunks = info.n_stripes
        meta = meta_buf[: n_chunks * c]
        meta[:] = np.zeros((), dtype=OOB_DTYPE)
        meta["lba"] = INVALID_LBA
        if self.cfg.batched and n_chunks:
            # whole-zone batched reconstruction: per-drive gather reads,
            # one fused decode per surviving-role set, one ordered write
            chunks, oob_all = self._reconstruct_chunks(
                rec, member, np.arange(n_chunks),
                verify=self.cfg.verify_reads,
            )
            meta[:] = oob_all.reshape(-1)
            new.zone_write(
                zone, info.data_start(), chunks.reshape(-1, bb), meta
            )
            self.stats.recovery_blocks_read += n_chunks * scheme.k * c
        else:
            for chunk_idx in range(n_chunks):
                chunk = self._reconstruct_chunk(rec, member, chunk_idx)
                oobs = self._reconstruct_oob(rec, member, chunk_idx)
                off = info.data_start() + chunk_idx * c
                new.zone_write(zone, off, chunk, oobs)
                meta[chunk_idx * c : (chunk_idx + 1) * c] = oobs
                self.stats.recovery_blocks_read += scheme.k * c
        if ost is not None:
            ost.meta[member, : n_chunks * c] = meta
        if info.state == int(SegmentState.SEALED):
            foot = pack_footer(meta, bb)
            foot_oob = np.zeros(foot.shape[0], dtype=OOB_DTYPE)
            foot_oob["lba"] = INVALID_LBA
            new.zone_write(zone, int(new.wp[zone]), foot, foot_oob)
            new.finish_zone(zone)
        self._rebuild_pending.discard((info.seg_id, member))

    def _zone_chunk_count(self, rec: _SegmentRecord, drive_idx: int) -> int:
        """Chunks committed to (open) segment on this drive = stripes written."""
        return rec.info.stripes_written

    def _reconstruct_oob(
        self, rec: _SegmentRecord, failed_drive: int, chunk_idx: int
    ) -> np.ndarray:
        """Rebuild the lost chunk's OOB entries from survivors (parity OOB)."""
        info = rec.info
        c = info.chunk_blocks
        scheme = self._scheme_for(info)
        codec = self._codec_for(info)
        seq, members = self._chunk_members(rec, failed_drive, chunk_idx)
        lost_role = scheme.drive_to_role(failed_drive, seq)
        out = np.zeros(c, dtype=OOB_DTYPE)
        out["stripe"] = seq
        if scheme.mirror:
            # copy OOB from the surviving mirror twin
            twin = (lost_role + scheme.k) % (2 * scheme.k)
            for d, cidx in members.items():
                if scheme.drive_to_role(d, seq) == twin:
                    zone = info.zone_ids[d]
                    return self.drives[info.drive_ids[d]].read_oob(
                        zone, info.data_start() + cidx * c, c
                    ).copy()
            raise RuntimeError("mirror OOB lost")
        # The metadata is protected by the same erasure code as the payload
        # (parity_oob); gather k surviving (lba, ts) rows and decode.
        rows_lba, rows_ts, roles = [], [], []
        for d, cidx in members.items():
            if len(roles) == scheme.k:
                break
            zone = info.zone_ids[d]
            oob = self.drives[info.drive_ids[d]].read_oob(
                zone, info.data_start() + cidx * c, c
            )
            rows_lba.append(oob["lba"].astype(np.uint64))
            rows_ts.append(oob["ts"].astype(np.uint64))
            roles.append(scheme.drive_to_role(d, seq))
        data_lba, data_ts = decode_meta(
            codec, np.stack(rows_lba), np.stack(rows_ts), tuple(roles)
        )
        if lost_role < scheme.k:
            out["lba"] = data_lba[lost_role]
            out["ts"] = data_ts[lost_role]
        else:
            p_lba, p_ts = parity_oob(codec, data_lba, data_ts)
            out["lba"] = p_lba[lost_role - scheme.k]
            out["ts"] = p_ts[lost_role - scheme.k]
        return out

    # ------------------------------------------------------------------ scrub

    def scrub_segment(self, seg_id: int) -> dict:
        """Bulk-verify one sealed segment and repair every detected fault.

        Per member zone the whole written extent is gathered in one read
        and checked against the drive's checksum store (plus the UNC
        mask).  Detected faults are repaired in place by provenance:

        * header region -- regenerated from the controller's
          ``SegmentInfo`` (the header is a replicated descriptor);
        * footer region -- repacked from the zone's own OOB area (the
          footer is a serialization of it);
        * data region -- reconstructed through parity
          (:meth:`_reconstruct_chunks` with survivor verification), which
          raises :class:`IntegrityError` if a stripe has lost more blocks
          than the code tolerates.

        Members on failed or rebuild-pending drives are skipped -- the
        rebuild path owns them.  Returns per-pass counters."""
        rec = self.segments[seg_id]
        if rec.info.state != int(SegmentState.SEALED):
            raise ValueError(f"segment {seg_id} is not sealed")
        if self.obs_event is not None:
            self.obs_event("scrub.begin", seg_id=seg_id)
        try:
            return self._scrub_segment_obs(rec)
        finally:
            if self.obs_event is not None:
                self.obs_event("scrub.end", seg_id=seg_id)

    def _scrub_segment_obs(self, rec: _SegmentRecord) -> dict:
        info = rec.info
        c = info.chunk_blocks
        bb = self.zns_cfg.block_bytes
        ds = info.data_start()
        data_end = ds + info.n_stripes * c
        scheme = self._scheme_for(info)
        counters = {"verified": 0, "detected": 0, "repaired": 0,
                    "skipped_members": 0}
        for member in range(info.n_drives):
            drive = self.drives[info.drive_ids[member]]
            if drive.failed or (info.seg_id, member) in self._rebuild_pending:
                counters["skipped_members"] += 1
                continue
            zone = info.zone_ids[member]
            wp = int(drive.wp[zone])
            if wp == 0:
                continue
            offs = np.arange(wp, dtype=np.int64)
            blocks = drive.read_blocks(zone, offs)
            before = self.stats.integrity_corruptions_detected
            ok = self._verify_media(drive, zone, offs, blocks)
            counters["verified"] += wp
            counters["detected"] += (
                self.stats.integrity_corruptions_detected - before
            )
            self.stats.integrity_scrub_blocks += wp
            bad = offs[~ok]
            if bad.size == 0:
                continue
            hbad = bad[bad < ds]
            if hbad.size:
                hdr_chunk = np.zeros((c, bb), np.uint8)
                hdr_chunk[0] = pack_header(info, bb)
                self._repair_in_place(rec, member, hbad, hdr_chunk[hbad],
                                      refresh_cache=False)
                counters["repaired"] += int(hbad.size)
            fbad = bad[bad >= data_end]
            if fbad.size:
                entries = drive.read_oob(zone, ds, data_end - ds)
                foot = pack_footer(entries, bb)
                self._repair_in_place(rec, member, fbad,
                                      foot[fbad - data_end],
                                      refresh_cache=False)
                counters["repaired"] += int(fbad.size)
            dbad = bad[(bad >= ds) & (bad < data_end)]
            if dbad.size:
                didx = dbad - ds
                chunk_idxs, inv = np.unique(didx // c, return_inverse=True)
                chunks, _ = self._reconstruct_chunks(
                    rec, member, chunk_idxs, verify=True
                )
                good = chunks[inv, didx % c]
                # cache keys only exist for data-role blocks (a parity
                # block's OOB lba is erasure-coded metadata, not a key);
                # mirror twins both carry real keys
                data_role = np.empty(chunk_idxs.size, dtype=bool)
                for i, ci in enumerate(chunk_idxs):
                    seq, _ = self._chunk_members(rec, member, int(ci))
                    role = scheme.drive_to_role(member, seq)
                    data_role[i] = scheme.mirror or role < scheme.k
                is_data = data_role[inv]
                for sel, refresh in ((is_data, True), (~is_data, False)):
                    if sel.any():
                        self._repair_in_place(
                            rec, member, dbad[sel], good[sel],
                            refresh_cache=refresh,
                        )
                counters["repaired"] += int(dbad.size)
        return counters

    def scrub_once(self) -> dict:
        """One whole-array scrub pass over every sealed segment.

        The timed pipeline's paced actor walks segments one per tick
        instead (:meth:`HandlerPipeline.schedule_scrub`); this synchronous
        form is for tests and crash-free tooling."""
        self._sync_pending()
        totals = {"verified": 0, "detected": 0, "repaired": 0,
                  "skipped_members": 0, "segments": 0}
        for seg_id in sorted(self.segments):
            if self.segments[seg_id].info.state != int(SegmentState.SEALED):
                continue
            r = self.scrub_segment(seg_id)
            for key in ("verified", "detected", "repaired",
                        "skipped_members"):
                totals[key] += r[key]
            totals["segments"] += 1
        self.stats.integrity_scrub_passes += 1
        return totals

    # ------------------------------------------------------------ crash + misc

    def arm_crash(self, blocks_from_now: int) -> None:
        """Next ``blocks_from_now`` block commits succeed; later ones crash."""
        # a deferred group predates the arming (the synchronous path would
        # already have committed it), so land it before the budget bites
        self._sync_pending()
        self.budget.remaining = blocks_from_now

    def disarm_crash(self) -> None:
        self.budget.remaining = None

    def logical_utilization(self) -> float:
        self._sync_pending()
        live = sum(r.valid_count for r in self.segments.values())
        return live / max(1, self.cfg.logical_blocks)
