"""SPDK-style request pipeline (paper §4) over the ZapRAID array.

The paper decomposes request handling into seven handlers on SPDK threads:
dispatch, device I/O, completion, indexing, encoding, segment-state tracking,
and cleaning.  This module provides the same decomposition as an explicit
event pipeline over the functional array -- the form a real async runtime
(asyncio / SPDK reactors / TPU host offload threads) would schedule.  The
synchronous simulator executes stages inline; the *structure* (who produces
which event for whom, and what state each stage owns) matches the paper:

  dispatch        -> classifies writes (hybrid §3.3), fills in-flight stripes,
                     emits ENCODE when a stripe's k data chunks are ready
  encoding        -> parity generation (Pallas XOR/GF(256)), emits DEV_IO
  device I/O      -> Zone Write / Zone Append submission + completion polling
  completion      -> per-request completion tracking; degraded-read decode
  indexing        -> L2P queries/updates, CLOCK offloading, write acks
  segment state   -> header/footer writes, group barriers, sealing
  cleaning        -> GC trigger + valid-block rewrite

Each ``tick()`` drains one round of events; counters expose per-stage
activity for the benchmarks.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Optional

import numpy as np

from repro.core.array import ZapRAIDArray


@dataclasses.dataclass
class Event:
    kind: str      # WRITE | READ | ENCODE | DEV_IO | COMPLETE | INDEX | SEAL | CLEAN
    payload: Any
    callback: Optional[Callable] = None


class HandlerPipeline:
    """Event-driven facade over ZapRAIDArray mirroring the paper's stages."""

    STAGES = ("dispatch", "encoding", "device_io", "completion",
              "indexing", "segment_state", "cleaning")

    def __init__(self, array: ZapRAIDArray):
        self.array = array
        self.queues: dict[str, collections.deque] = {
            s: collections.deque() for s in self.STAGES
        }
        self.counters = {s: 0 for s in self.STAGES}
        self.completed: list[Any] = []

    # -- submission (application-facing, like the bdev layer) ---------------

    def submit_write(self, lba: int, data: np.ndarray, cb=None):
        self.queues["dispatch"].append(Event("WRITE", (lba, data), cb))

    def submit_read(self, lba: int, n_blocks: int = 1, cb=None):
        self.queues["dispatch"].append(Event("READ", (lba, n_blocks), cb))

    # -- stages --------------------------------------------------------------

    def _dispatch(self, ev: Event):
        if ev.kind == "WRITE":
            lba, data = ev.payload
            # classification + in-flight stripe fill; the array emits the
            # encode+device-io work inline (synchronous simulator), which we
            # account to the downstream stages.
            self.array.write(lba, data)
            self.counters["encoding"] += 1
            self.counters["device_io"] += 1
            self.queues["indexing"].append(Event("INDEX", ("ack", lba), ev.callback))
        else:
            lba, n = ev.payload
            self.queues["device_io"].append(Event("DEV_IO", ("read", lba, n), ev.callback))

    def _device_io(self, ev: Event):
        op = ev.payload[0]
        if op == "read":
            _, lba, n = ev.payload
            out = self.array.read(lba, n)
            self.queues["completion"].append(Event("COMPLETE", (lba, out), ev.callback))

    def _completion(self, ev: Event):
        lba, out = ev.payload
        self.completed.append((lba, out))
        if ev.callback:
            ev.callback(out)

    def _indexing(self, ev: Event):
        kind, lba = ev.payload
        if ev.callback:
            ev.callback(lba)

    def _segment_state(self):
        # group barriers / sealing are folded into the array's commit path;
        # the periodic examination (paper: every 1us) maps to this tick.
        self.array.flush()

    def _cleaning(self):
        self.array.maybe_gc()

    # -- scheduler -----------------------------------------------------------

    def tick(self, flush: bool = False) -> int:
        """Drain one round of events (one 'poll loop' iteration)."""
        n = 0
        for stage, fn in (
            ("dispatch", self._dispatch),
            ("device_io", self._device_io),
            ("completion", self._completion),
            ("indexing", self._indexing),
        ):
            q = self.queues[stage]
            for _ in range(len(q)):
                fn(q.popleft())
                self.counters[stage] += 1
                n += 1
        if flush:
            self._segment_state()
            self.counters["segment_state"] += 1
            self._cleaning()
            self.counters["cleaning"] += 1
        return n

    def drain(self) -> None:
        while self.tick():
            pass
        self.tick(flush=True)
