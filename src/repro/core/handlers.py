"""SPDK-style request pipeline (paper §4) over the ZapRAID array.

The paper decomposes request handling into seven handlers on SPDK threads:
dispatch, device I/O, completion, indexing, encoding, segment-state tracking,
and cleaning.  This module provides that decomposition in two modes:

**Synchronous mode** (``engine=None``) -- the original explicit event
pipeline over the functional array: each ``tick()`` drains one round of
events, stages execute inline, counters expose per-stage activity.

**Timed mode** (``engine=``:class:`repro.sim.Engine`) -- the stages become
producers/consumers of *scheduled events* on a discrete-event engine:

  dispatch        -> fires at the request's arrival time; classifies writes,
                     fills in-flight stripes (functional), registers the
                     request as pending until its stripe persists
  encoding        -> accounted per committed stripe (Pallas parity path)
  device I/O      -> every Zone Write / Zone Append / read books service
                     time on the TimedDrive queues (one Zone Write in
                     flight per zone, qd<=4 Zone Appends per zone); group
                     commits get their completion *order* from the booked
                     times -- the fastest append wins the write pointer
  completion      -> write acks fire at the stripe's device completion
                     time (+ host CPU cost); reads at their device time
  indexing        -> L2P updates ride the commit event; acks call back
  segment state   -> group barriers are real waits (a group's appends
                     cannot start before the previous group fully landed);
                     the periodic examination maps to timeout flush ticks
  cleaning        -> GC runs inline on the same virtual timeline, its I/O
                     contending with foreground traffic on the drives

Latency attribution works through two array hooks (``commit_listener``,
``append_plan_fn``) rather than rewriting the functional array as
coroutines: state changes execute instantly, device time is booked forward,
and later events observe the bookings as queueing delay (see
``repro.sim.engine``).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Optional

import numpy as np

from repro.core.array import ZapRaidConfig, ZapRAIDArray
from repro.core.zns import ZnsConfig


@dataclasses.dataclass
class Event:
    kind: str      # WRITE | READ | ENCODE | DEV_IO | COMPLETE | INDEX | SEAL | CLEAN
    payload: Any
    callback: Optional[Callable] = None


@dataclasses.dataclass
class _PendingWrite:
    """A submitted write waiting for its stripe(s) to persist."""

    tenant: str
    t_submit: float
    t_dispatch: float
    remaining: set          # lbas not yet durably committed
    callback: Optional[Callable]
    t_done: float = 0.0     # max device completion over covering stripes
    buffer_wait_us: float = 0.0
    device_us: float = 0.0


class HandlerPipeline:
    """Event-driven facade over ZapRAIDArray mirroring the paper's stages."""

    STAGES = ("dispatch", "encoding", "device_io", "completion",
              "indexing", "segment_state", "cleaning")

    def __init__(
        self,
        array: ZapRAIDArray,
        engine=None,
        recorder=None,
        flush_interval_us: float = 1000.0,
    ):
        self.array = array
        self.queues: dict[str, collections.deque] = {
            s: collections.deque() for s in self.STAGES
        }
        self.counters = {s: 0 for s in self.STAGES}
        self.completed: list[Any] = []
        self.engine = engine
        self.recorder = recorder
        self.flush_interval_us = flush_interval_us
        if engine is not None:
            if recorder is None:
                from repro.sim.stats import LatencyRecorder
                self.recorder = LatencyRecorder()
            self.service = array.drives[0].service
            self._pending: dict[int, list[_PendingWrite]] = {}
            self._open_reqs = 0
            self._barriers: dict[int, float] = {}  # seg_id -> group-done time
            self._last_write_dispatch = 0.0
            # External work source (e.g. the block service's submission
            # queues): the timeout-flush tick keeps re-arming while it
            # reports work, so a drained submission queue still flushes
            # partially filled stripes (see ensure_flush_ticks).
            self.busy_hook: Optional[Callable[[], bool]] = None
            self._flush_tick_armed = False
            # Optional obs tracer (repro.obs) -- see attach_obs.  None keeps
            # every hook site at a single attribute test.
            self.tracer = None
            self._obs_marks: dict[str, float] = {}
            array.commit_listener = self._on_stripe_commit
            array.encode_listener = self._on_group_encode
            if array.cfg.append_order == "timed":
                array.append_plan_fn = self._plan_group

    # -- construction ---------------------------------------------------------

    @classmethod
    def build_timed(
        cls,
        cfg: ZapRaidConfig,
        zns_cfg: ZnsConfig,
        *,
        engine=None,
        service=None,
        recorder=None,
        seed: int = 0,
        flush_interval_us: float = 1000.0,
    ) -> "HandlerPipeline":
        """Construct engine + timed drives + array + pipeline in one call."""
        from repro.sim.device import make_timed_drives
        from repro.sim.engine import Engine
        engine = engine or Engine()
        drives = make_timed_drives(
            cfg.n_drives, zns_cfg, engine, service=service, seed=seed
        )
        array = ZapRAIDArray(cfg, zns_cfg, drives=drives)
        return cls(array, engine=engine, recorder=recorder,
                   flush_interval_us=flush_interval_us)

    def attach_cache(self, cache) -> None:
        """Attach a ``repro.cache.ZnsCacheTier`` to the array; in timed mode
        a :class:`~repro.sim.device.TimedCacheDevice` is created on the
        engine so hits complete at cache-device latency on the virtual
        clock (their ``touch_io`` feeds the same ``io_watermark`` that
        prices drive reads)."""
        if self.engine is not None and cache.timed_dev is None:
            from repro.sim.device import TimedCacheDevice
            cache.timed_dev = TimedCacheDevice(self.engine)
        self.array.attach_cache(cache)
        if self.tracer is not None:
            # a cache attached after attach_obs still gets instrumented
            cache.obs_event = self._on_obs_event
            if cache.timed_dev is not None:
                cache.timed_dev.tracer = self.tracer

    def attach_obs(self, tracer=None):
        """Install a :class:`repro.obs.Tracer` across every layer.

        Wires the tracer into the drives (per-channel command spans), the
        cache device, and the array's ``obs_event`` hook (degraded decode,
        GC passes, cache lookups); the pipeline itself adds commit-barrier
        and rebuild spans.  Returns the tracer so callers can export.
        Detach by passing the same sites ``None`` -- or simply build a
        fresh pipeline: tracing-off pipelines never see these hooks.
        """
        assert self.engine is not None, "obs requires a timed pipeline"
        if tracer is None:
            from repro.obs import Tracer
            tracer = Tracer(self.engine)
        self.tracer = tracer
        for d in self.array.drives:
            d.tracer = tracer
        self.array.obs_event = self._on_obs_event
        cache = self.array.cache
        if cache is not None:
            cache.obs_event = self._on_obs_event
            if cache.timed_dev is not None:
                cache.timed_dev.tracer = tracer
        return tracer

    def _on_obs_event(self, name: str, **args) -> None:
        """Adapter: array/cache instrumentation points -> tracer spans.

        Begin/end pairs (``gc.begin``/``gc.end``, ``degraded.begin``/
        ``degraded.end``) become spans from the begin instant to the I/O
        watermark at the end instant -- the window the pass's device
        bookings occupy; point events become instants on their track."""
        tr = self.tracer
        if tr is None:
            return
        eng = self.engine
        if name.endswith(".begin"):
            self._obs_marks[name[:-6]] = eng.now
            return
        if name.endswith(".end"):
            key = name[:-4]
            t0 = self._obs_marks.pop(key, eng.now)
            span_name = {
                "gc": "gc.pass",
                "degraded": "degraded.decode",
                "commit_narrow": "stripe.commit_narrow",
                "rewiden": "rebuild.rewiden",
                "scrub": "scrub.segment",
            }.get(key, key)
            tr.span("array", span_name, t0, max(t0, eng.io_watermark, eng.now),
                    cat="background", **args)
            return
        if name == "cache.lookup":
            tr.instant("cache", name, eng.now, **args)
        elif name == "cache.zone_reset":
            tr.instant("cache", name, eng.now, **args)
        else:
            tr.instant("array", name, eng.now, **args)

    # -- submission (application-facing, like the bdev layer) ---------------

    def submit_write(self, lba: int, data: np.ndarray, cb=None, *,
                     at: Optional[float] = None, tenant: str = "host"):
        if self.engine is None:
            self.queues["dispatch"].append(Event("WRITE", (lba, data), cb))
            return
        t = self.engine.now if at is None else at
        self._open_reqs += 1
        self.ensure_flush_ticks()
        # dispatch fires after the host-side submission cost; latency is
        # still measured from the arrival instant t
        self.engine.at(t + self.service.cpu_dispatch_us,
                       self._ev_write, lba, data, cb, tenant, t)

    def submit_read(self, lba: int, n_blocks: int = 1, cb=None, *,
                    at: Optional[float] = None, tenant: str = "host"):
        if self.engine is None:
            self.queues["dispatch"].append(Event("READ", (lba, n_blocks), cb))
            return
        t = self.engine.now if at is None else at
        self._open_reqs += 1
        self.ensure_flush_ticks()
        self.engine.at(t + self.service.cpu_dispatch_us,
                       self._ev_read, lba, n_blocks, cb, tenant, t)

    # -- timed-mode events ---------------------------------------------------

    def _ev_write(self, lba: int, data: np.ndarray, cb, tenant: str, t_submit: float):
        eng = self.engine
        self.counters["dispatch"] += 1
        self._last_write_dispatch = eng.now
        n = data.shape[0] if data.ndim == 2 else 1
        req = _PendingWrite(
            tenant=tenant, t_submit=t_submit, t_dispatch=eng.now,
            remaining=set(range(lba, lba + n)), callback=cb,
        )
        for l in req.remaining:
            self._pending.setdefault(l, []).append(req)
        self.recorder.notes["W_blocks"] = self.recorder.notes.get("W_blocks", 0) + n
        # functional write at the dispatch instant; commits triggered by it
        # (stripe fills, group barriers, GC) book device time forward and
        # resolve pending requests through the commit listener
        self.array.write(lba, data)

    def _ev_read(self, lba: int, n_blocks: int, cb, tenant: str, t_submit: float):
        eng = self.engine
        self.counters["dispatch"] += 1
        self.counters["device_io"] += 1
        mark = eng.mark_io()
        out = self.array.read(lba, n_blocks)
        t_dev = max(eng.io_watermark, eng.now)
        self.recorder.notes["R_blocks"] = self.recorder.notes.get("R_blocks", 0) + n_blocks
        eng.at(t_dev + self.service.cpu_complete_us, self._ev_read_done,
               lba, out, cb, tenant, t_submit, t_dev - mark)

    def _ev_read_done(self, lba, out, cb, tenant, t_submit, device_us):
        self.counters["completion"] += 1
        self.completed.append((lba, out))
        self.recorder.record(tenant, "R", t_submit, self.engine.now,
                             stages={"device_us": device_us})
        self._open_reqs -= 1
        if cb:
            cb(out)

    def _ev_write_done(self, req: _PendingWrite):
        self.counters["completion"] += 1
        self.counters["indexing"] += 1
        self.recorder.record(
            req.tenant, "W", req.t_submit, self.engine.now,
            stages={"buffer_wait_us": req.buffer_wait_us,
                    "device_us": req.device_us},
        )
        self._open_reqs -= 1
        if req.callback:
            req.callback(self.engine.now)

    def _ev_flush_tick(self):
        """Timeout path (paper: periodic in-flight examination): pad+commit
        staged stripes when no *write* has arrived for one interval (read
        traffic must not keep half-filled stripes pinned in the buffer)."""
        if self.engine.now - self._last_write_dispatch >= self.flush_interval_us:
            self.array.flush()
            self.counters["segment_state"] += 1
            self.array.maybe_gc()
            self.counters["cleaning"] += 1

    # -- self-rescheduling timeout flush (service tier / open-ended traffic) --

    def _busy(self) -> bool:
        """Work outstanding anywhere: dispatched requests still pending, or
        an attached front end (busy_hook) holding queued/scheduled work."""
        return self._open_reqs > 0 or bool(self.busy_hook and self.busy_hook())

    def ensure_flush_ticks(self) -> None:
        """Arm the periodic timeout-flush tick (idempotent).

        Unlike the fixed tick train ``replay`` used to pre-schedule over the
        arrival span, this tick *re-arms itself* for as long as the pipeline
        is busy -- including work that only exists in an attached service
        tier's submission queues, where no write has been dispatched yet.
        Without it, a dispatcher that drains its submission queue mid-stripe
        would leave the partial stripe staged forever: no further write
        arrives to fill it and no flush event exists to pad it.  The chain
        stops (and can be re-armed by the next submission) once the system
        is fully idle, so an idle timed pipeline schedules no events."""
        if self.engine is None or not self.flush_interval_us:
            return
        if self._flush_tick_armed:
            return
        self._flush_tick_armed = True
        self.engine.after(self.flush_interval_us, self._ev_flush_tick_auto)

    def _ev_flush_tick_auto(self) -> None:
        self._flush_tick_armed = False
        self._ev_flush_tick()
        if self._busy():
            self.ensure_flush_ticks()

    # -- array hooks (timed mode) -------------------------------------------

    def _plan_group(self, info, ops):
        """Zone-Append group planner: real barrier wait + timing-driven order."""
        from repro.sim.device import plan_group_appends
        eng = self.engine
        barrier = self._barriers.get(info.seg_id, 0.0)
        floor = max(eng.now, barrier)
        if barrier > eng.now:
            self.recorder.note("group_barrier_wait_us", barrier - eng.now)
            if self.tracer is not None:
                self.tracer.span("array", "stripe.commit_barrier",
                                 eng.now, barrier, cat="commit",
                                 seg_id=info.seg_id)
        # ops index drives by segment-member position; map to the physical
        # drives the segment spans (identity when healthy, survivors when
        # the group was opened at degraded width)
        member_drives = [self.array.drives[p] for p in info.drive_ids]
        order, group_done = plan_group_appends(
            member_drives, info.zone_ids, ops, info.chunk_blocks, floor
        )
        self._barriers[info.seg_id] = group_done
        self.counters["segment_state"] += 1
        return order

    def _on_group_encode(self, info, n_stripes: int, host_us: float) -> None:
        """Encode-completion event from the device-resident datapath.

        The fused group encode runs on the accelerator while the committer
        prepares the drive payloads; the sync stall the committer actually
        paid (host wall time of the materialize) is threaded into the
        recorder -- ``notes["encode_sync_us"]`` totals the stall and
        ``note_counts["encode_sync_us"]`` counts the groups -- so timed-mode
        stats stay honest about codec cost.  Virtual
        time is untouched: with the timed pipeline attached, group commits
        are synchronous (the group barrier is already a sync point)."""
        # one note per group: notes["encode_sync_us"] accumulates the total
        # stall, note_counts["encode_sync_us"] counts encoded groups
        self.recorder.note("encode_sync_us", host_us)

    def _on_stripe_commit(self, info, built, per_drive_off):
        """Resolve pending writes covered by a just-persisted stripe."""
        eng = self.engine
        self.counters["encoding"] += 1
        self.counters["device_io"] += len(per_drive_off)
        t_done = eng.now
        for d, off in per_drive_off.items():
            # d is the segment-member index; translate to the physical drive
            t = self.array.drives[info.drive_ids[d]].chunk_completion(
                info.zone_ids[d], off)
            if t is not None and t > t_done:
                t_done = t
        for lba in built["lbas"].ravel():
            lba = int(lba)
            if lba < 0:
                continue
            reqs = self._pending.pop(lba, None)
            if not reqs:
                continue
            for req in reqs:
                req.t_done = max(req.t_done, t_done)
                req.buffer_wait_us = max(req.buffer_wait_us, eng.now - req.t_dispatch)
                req.device_us = max(req.device_us, t_done - eng.now)
                req.remaining.discard(lba)
                if not req.remaining:
                    eng.at(req.t_done + self.service.cpu_complete_us,
                           self._ev_write_done, req)

    # -- workload replay (timed mode) ---------------------------------------

    def replay(self, requests, payload_fn=None):
        """Replay a :mod:`repro.sim.workload` request stream to completion.

        Writes carry deterministic pseudo-random payloads unless
        ``payload_fn(request) -> (n_blocks, block_bytes) uint8`` is given.
        Returns the latency recorder."""
        assert self.engine is not None, "replay requires a timed pipeline"
        bb = self.array.zns_cfg.block_bytes
        rng = np.random.default_rng(0xFEED)
        t_end = 0.0
        for r in requests:
            t_end = max(t_end, r.t_us)
            if r.op == "W":
                data = (payload_fn(r) if payload_fn else
                        rng.integers(0, 256, (r.n_blocks, bb), dtype=np.uint8))
                self.submit_write(r.lba, data, at=r.t_us, tenant=r.tenant)
            else:
                self.submit_read(r.lba, r.n_blocks, at=r.t_us, tenant=r.tenant)
        # the tick re-arms itself while requests are outstanding, so traffic
        # that queues past the last arrival still gets timeout flushes
        self.ensure_flush_ticks()
        self.drain()
        return self.recorder

    def precondition(self, writes) -> None:
        """Install media state outside the measured timeline.

        ``writes`` is an iterable of ``(lba, data)``.  The functional writes
        execute instantly, then every device-time booking -- and every
        recorder note / stage counter the warm-up produced -- is discarded,
        so the measured workload starts against a warm array on idle drives
        with clean stats."""
        assert self.engine is not None
        for lba, data in writes:
            self.array.write(lba, data)
        self.array.flush()
        for d in self.array.drives:
            d.reset_timing()
        cache = self.array.cache
        if cache is not None:
            # warm contents survive; timing and hit counters restart clean
            cache.reset_timing()
            cache.stats.reset()
        self._barriers.clear()
        rec = self.recorder
        rec.samples.clear()
        rec.stage_sums.clear()
        rec.stage_counts.clear()
        rec.tenant_stage_sums.clear()
        rec.tenant_stage_counts.clear()
        rec.notes.clear()
        rec.note_counts.clear()
        self.counters = {s: 0 for s in self.STAGES}
        if self.tracer is not None:
            # warm-up spans are not part of the measured window
            self.tracer.clear()
            self._obs_marks.clear()

    # -- failure/rebuild/GC actors (timed mode) -----------------------------

    def schedule_drive_failure(self, drive_idx: int, at: float) -> None:
        self.engine.at(at, self.array.fail_drive, drive_idx)

    def attach_faults(self, plan, *, seed: int = 0) -> "Any":
        """Arm a :class:`repro.sim.faults.FaultPlan` on this pipeline's
        engine; returns the armed :class:`~repro.sim.faults.FaultInjector`
        (its ``log`` records every fired event).  ``seed`` drives the
        injector's fire-time victim sampling for media faults."""
        from repro.sim.faults import FaultInjector
        return FaultInjector(self, plan, seed=seed).arm()

    def schedule_rebuild(
        self, drive_idx: int, at: float, interval_us: float = 0.0
    ) -> None:
        """Full-drive rebuild as an engine actor contending for device time.

        With ``interval_us == 0`` the whole rebuild books at once (one burst
        of device traffic).  With ``interval_us > 0`` the rebuild is *paced*:
        open segments are reconstructed up front (they still take appends),
        then sealed segments one per tick, with every not-yet-rebuilt zone
        registered in the array's ``_rebuild_pending`` set so foreground
        reads route through reconstruction instead of returning the
        replacement drive's zeroed media."""
        if interval_us <= 0.0:
            self.engine.at(at, self._ev_rebuild, drive_idx)
        else:
            self.engine.at(at, self._ev_rebuild_start, drive_idx, interval_us)

    def _ev_rebuild(self, drive_idx: int) -> None:
        eng = self.engine
        mark = eng.mark_io()
        self.array.rebuild_drive(drive_idx)
        self.recorder.note("rebuild_device_us", max(0.0, eng.io_watermark - mark))
        if self.tracer is not None:
            self.tracer.span("array", "rebuild.full", eng.now,
                             max(eng.now, eng.io_watermark),
                             cat="background", drive=drive_idx)

    def _ev_rebuild_start(self, drive_idx: int, interval_us: float) -> None:
        arr = self.array
        eng = self.engine
        mark = eng.mark_io()
        arr._sync_pending()
        arr.drives[drive_idx].replace()
        scaffold: dict = {}
        sealed = []  # (seg_id, member index of the replaced drive)
        for rec in sorted(arr.segments.values(), key=lambda r: r.info.seg_id):
            if drive_idx not in rec.info.drive_ids:
                # survivor-width segment written while the drive was failed;
                # the final re-widening pass relocates it
                continue
            if rec.info.seg_id in arr.open_segments:
                # open segments take new appends between ticks, so their
                # zones must be whole before foreground writes resume
                arr._rebuild_segment(rec, drive_idx, scaffold)
            else:
                member = rec.info.drive_ids.index(drive_idx)
                arr._rebuild_pending.add((rec.info.seg_id, member))
                sealed.append((rec.info.seg_id, member))
        self.recorder.note("rebuild_device_us", max(0.0, eng.io_watermark - mark))
        if sealed:
            eng.at(eng.now + interval_us, self._ev_rebuild_step,
                   drive_idx, sealed, 0, interval_us, scaffold)
        else:
            eng.at(eng.now + interval_us, self._ev_rewiden)

    def _ev_rebuild_step(
        self, drive_idx: int, sealed: list, i: int, interval_us: float, scaffold: dict
    ) -> None:
        arr = self.array
        eng = self.engine
        seg_id, member = sealed[i]
        rec = arr.segments.get(seg_id)
        if rec is not None:
            mark = eng.mark_io()
            arr._rebuild_segment(rec, drive_idx, scaffold)
            self.recorder.note("rebuild_device_us", max(0.0, eng.io_watermark - mark))
            if self.tracer is not None:
                self.tracer.span("array", "rebuild.segment", eng.now,
                                 max(eng.now, eng.io_watermark),
                                 cat="background", drive=drive_idx,
                                 seg_id=seg_id)
        else:
            # the segment was GC'd while pending; nothing left to rebuild
            arr._rebuild_pending.discard((seg_id, member))
        self.counters["segment_state"] += 1
        if i + 1 < len(sealed):
            eng.at(eng.now + interval_us, self._ev_rebuild_step,
                   drive_idx, sealed, i + 1, interval_us, scaffold)
        else:
            # every zone is whole again: relocate survivor-width segments
            # back to full width on the rebuilt drive set
            eng.at(eng.now + interval_us, self._ev_rewiden)

    def _ev_rewiden(self) -> None:
        arr = self.array
        eng = self.engine
        # No mark_io() here: this actor fires *after* the last rebuild step,
        # and resetting the shared watermark then would let the final
        # rebuild.segment span outrun the run's max(now, io_watermark) bound.
        before = max(eng.now, eng.io_watermark)
        arr._rewiden()
        self.recorder.note("rebuild_device_us", max(0.0, eng.io_watermark - before))

    def schedule_gc(
        self,
        at: float,
        interval_us: float,
        n_ticks: int = 1,
        watermark: Optional[int] = None,
    ) -> None:
        """Rate-limited background-GC actor: every ``interval_us`` run at
        most one ``gc_once`` pass while free segments sit below
        ``watermark`` (default: one above the array's inline-GC trigger, so
        the actor cleans *proactively* and the write path rarely stalls on
        an inline GC burst).  Collection and restage book device time on the
        timed drives, so foreground tail latency under GC pressure becomes a
        measurable QoS figure (``notes["gc_device_us"]`` totals the actor's
        device traffic, ``note_counts`` its runs)."""
        if watermark is None:
            watermark = self.array.cfg.gc_free_segments_low + 1
        self.engine.at(at, self._ev_gc_tick, interval_us, n_ticks, watermark)

    def _ev_gc_tick(self, interval_us: float, remaining: int, watermark: int) -> None:
        arr = self.array
        eng = self.engine
        if arr.free_segment_count() < watermark:
            mark = eng.mark_io()
            arr.gc_once()
            self.counters["cleaning"] += 1
            self.recorder.note("gc_device_us", max(0.0, eng.io_watermark - mark))
        if remaining > 1:
            eng.at(eng.now + interval_us, self._ev_gc_tick,
                   interval_us, remaining - 1, watermark)

    def schedule_scrub(
        self,
        at: float,
        interval_us: float,
        n_passes: int = 1,
        yield_to_foreground: bool = True,
    ) -> None:
        """Paced background-scrub actor: walk every sealed segment, one per
        ``interval_us`` tick, bulk-verifying its zones against the checksum
        store and repairing detected faults through parity
        (:meth:`ZapRAIDArray.scrub_segment`).  Each step's gathers and
        repair writes book device time on the timed drives, so scrub
        traffic contends with foreground I/O the same way GC and rebuild
        do; with ``yield_to_foreground`` a tick that finds requests in
        flight defers its segment to the next tick instead of stealing
        device time from them.  ``notes["scrub_device_us"]`` totals the
        actor's device traffic.  ``n_passes`` whole-array passes run
        back to back (each re-snapshots the sealed set)."""
        self.engine.at(at, self._ev_scrub_start,
                       interval_us, n_passes, yield_to_foreground)

    def _ev_scrub_start(
        self, interval_us: float, remaining: int, yield_fg: bool
    ) -> None:
        from repro.core.segment import SegmentState
        arr = self.array
        arr._sync_pending()
        sealed = sorted(
            sid for sid, rec in arr.segments.items()
            if rec.info.state == int(SegmentState.SEALED)
        )
        if sealed:
            self._ev_scrub_step(sealed, 0, interval_us, remaining, yield_fg)
        else:
            arr.stats.integrity_scrub_passes += 1
            if remaining > 1:
                self.engine.at(self.engine.now + interval_us,
                               self._ev_scrub_start,
                               interval_us, remaining - 1, yield_fg)

    def _ev_scrub_step(
        self, sealed: list, i: int, interval_us: float, remaining: int,
        yield_fg: bool,
    ) -> None:
        from repro.core.segment import SegmentState
        arr = self.array
        eng = self.engine
        if yield_fg and self._open_reqs > 0:
            # foreground requests in flight: give them the device and try
            # this segment again next tick
            eng.at(eng.now + interval_us, self._ev_scrub_step,
                   sealed, i, interval_us, remaining, yield_fg)
            return
        seg_id = sealed[i]
        rec = arr.segments.get(seg_id)
        if rec is not None and rec.info.state == int(SegmentState.SEALED):
            mark = eng.mark_io()
            arr.scrub_segment(seg_id)
            self.counters["cleaning"] += 1
            self.recorder.note("scrub_device_us",
                               max(0.0, eng.io_watermark - mark))
        if i + 1 < len(sealed):
            eng.at(eng.now + interval_us, self._ev_scrub_step,
                   sealed, i + 1, interval_us, remaining, yield_fg)
        else:
            arr.stats.integrity_scrub_passes += 1
            if remaining > 1:
                eng.at(eng.now + interval_us, self._ev_scrub_start,
                       interval_us, remaining - 1, yield_fg)

    # -- stages (synchronous mode) ------------------------------------------

    def _dispatch(self, ev: Event):
        if ev.kind == "WRITE":
            lba, data = ev.payload
            # classification + in-flight stripe fill; the array emits the
            # encode+device-io work inline (synchronous simulator), which we
            # account to the downstream stages.
            self.array.write(lba, data)
            self.counters["encoding"] += 1
            self.counters["device_io"] += 1
            self.queues["indexing"].append(Event("INDEX", ("ack", lba), ev.callback))
        else:
            lba, n = ev.payload
            self.queues["device_io"].append(Event("DEV_IO", ("read", lba, n), ev.callback))

    def _device_io(self, ev: Event):
        op = ev.payload[0]
        if op == "read":
            _, lba, n = ev.payload
            out = self.array.read(lba, n)
            self.queues["completion"].append(Event("COMPLETE", (lba, out), ev.callback))

    def _completion(self, ev: Event):
        lba, out = ev.payload
        self.completed.append((lba, out))
        if ev.callback:
            ev.callback(out)

    def _indexing(self, ev: Event):
        kind, lba = ev.payload
        if ev.callback:
            ev.callback(lba)

    def _segment_state(self):
        # group barriers / sealing are folded into the array's commit path;
        # the periodic examination (paper: every 1us) maps to this tick.
        self.array.flush()

    def _cleaning(self):
        self.array.maybe_gc()

    # -- scheduler -----------------------------------------------------------

    def tick(self, flush: bool = False) -> int:
        """Drain one round of events (one 'poll loop' iteration)."""
        if self.engine is not None:
            return self.engine.run()
        n = 0
        for stage, fn in (
            ("dispatch", self._dispatch),
            ("device_io", self._device_io),
            ("completion", self._completion),
            ("indexing", self._indexing),
        ):
            q = self.queues[stage]
            for _ in range(len(q)):
                fn(q.popleft())
                self.counters[stage] += 1
                n += 1
        if flush:
            self._segment_state()
            self.counters["segment_state"] += 1
            self._cleaning()
            self.counters["cleaning"] += 1
        return n

    def drain(self) -> None:
        if self.engine is not None:
            eng = self.engine
            eng.run()
            for _ in range(64):
                if not self._open_reqs:
                    break
                # quiesce: timeout-flush whatever is still staged, then let
                # the resulting ack events fire
                self.array.flush()
                self.counters["segment_state"] += 1
                self.array.maybe_gc()
                self.counters["cleaning"] += 1
                eng.run()
            assert not self._open_reqs, "timed drain left unresolved requests"
            return
        while self.tick():
            pass
        self.tick(flush=True)
