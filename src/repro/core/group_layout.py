"""Group-based data layout: the compact stripe table (CST) (paper §3.2).

For every Zone-Append segment the CST stores a (k+m, S) matrix of stripe IDs
-- the sequence number of the stripe *within its stripe group* that each
chunk slot holds.  Stripe IDs take ceil(log2 G) bits, rounded up to whole
bytes exactly as the paper's prototype does (uint8 for G <= 256, uint16 for
G <= 65536).

Degraded reads resolve a lost chunk by searching the G slots of its group on
each surviving drive for the matching stripe ID -- a k*G bounded scan.  The
table exposes access counters so benchmarks can report query overhead.
"""
from __future__ import annotations

import math

import numpy as np

NO_STRIPE = None  # sentinel filled value is the dtype max


def stripe_id_dtype(group_size: int) -> np.dtype:
    bits = max(1, math.ceil(math.log2(max(group_size, 2))))
    nbytes = -(-bits // 8)
    return {1: np.dtype(np.uint8), 2: np.dtype(np.uint16)}.get(
        nbytes, np.dtype(np.uint32)
    )


class CompactStripeTable:
    """Per-segment stripe-ID matrix with byte-rounded entries."""

    def __init__(self, n_drives: int, n_stripes: int, group_size: int):
        self.group_size = group_size
        self.dtype = stripe_id_dtype(group_size)
        self.fill = np.iinfo(self.dtype).max
        self.table = np.full((n_drives, n_stripes), self.fill, dtype=self.dtype)
        self.entries_accessed = 0  # degraded-read query counter

    def memory_bytes(self) -> int:
        return self.table.nbytes

    def record(self, drive: int, chunk_idx: int, stripe_id_in_group: int) -> None:
        assert stripe_id_in_group < max(self.group_size, 2)
        self.table[drive, chunk_idx] = stripe_id_in_group

    def record_many(
        self, drive: int, chunk_idxs: np.ndarray, stripe_ids: np.ndarray
    ) -> None:
        """Vectorized :meth:`record` for one drive (bulk group commit)."""
        assert stripe_ids.size == 0 or int(stripe_ids.max()) < max(self.group_size, 2)
        self.table[drive, np.asarray(chunk_idxs, np.int64)] = stripe_ids

    def stripe_id_at(self, drive: int, chunk_idx: int) -> int:
        self.entries_accessed += 1
        return int(self.table[drive, chunk_idx])

    def find_in_group(self, drive: int, group_idx: int, stripe_id: int) -> int | None:
        """Chunk index on ``drive`` holding ``stripe_id`` within group; None if absent."""
        g0 = group_idx * self.group_size
        window = self.table[drive, g0 : g0 + self.group_size]
        self.entries_accessed += window.shape[0]
        hits = np.nonzero(window == stripe_id)[0]
        if hits.size == 0:
            return None
        return int(g0 + hits[0])

    def group_members(self, group_idx: int, stripe_id: int) -> dict[int, int]:
        """drive -> chunk_idx for every drive holding ``stripe_id`` in the group."""
        out = {}
        for d in range(self.table.shape[0]):
            hit = self.find_in_group(d, group_idx, stripe_id)
            if hit is not None:
                out[d] = hit
        return out
