"""GF(2^8) arithmetic for Reed-Solomon parity in ZapRAID.

The field is GF(256) with the AES/RS-standard reduction polynomial
x^8 + x^4 + x^3 + x^2 + 1 (0x11d).  Two implementations are provided:

* numpy table-based routines (host-side: building encode matrices, inverting
  decode matrices -- these touch only (k+m)^2 <= 32^2 entries and never run on
  the datapath);
* branchless SWAR routines on int32-packed bytes (the on-device datapath used
  by both the jnp reference and the Pallas kernel).  Four GF(256) lanes are
  packed per int32; ``xtime`` (multiply-by-x) is computed simultaneously on
  all four bytes without cross-byte carry leakage.
"""
from __future__ import annotations

import functools

import numpy as np

GF_POLY = 0x11D
GF_GEN = 2  # generator of the multiplicative group for 0x11d


def _build_tables() -> tuple[np.ndarray, np.ndarray]:
    exp = np.zeros(512, dtype=np.uint8)
    log = np.zeros(256, dtype=np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & 0x100:
            x ^= GF_POLY
    exp[255:510] = exp[0:255]  # wraparound so exp[a+b] never needs a mod
    return exp, log


GF_EXP, GF_LOG = _build_tables()


def gf_mul(a: int, b: int) -> int:
    """Scalar GF(256) multiply (table based)."""
    if a == 0 or b == 0:
        return 0
    return int(GF_EXP[int(GF_LOG[a]) + int(GF_LOG[b])])


def gf_inv(a: int) -> int:
    if a == 0:
        raise ZeroDivisionError("GF(256) inverse of 0")
    return int(GF_EXP[255 - int(GF_LOG[a])])


def gf_mul_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Elementwise GF(256) multiply of uint8 arrays."""
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    out = GF_EXP[GF_LOG[a] + GF_LOG[b]].astype(np.uint8)
    return np.where((a == 0) | (b == 0), np.uint8(0), out)


def gf_matmul_np(m: np.ndarray, d: np.ndarray) -> np.ndarray:
    """GF(256) matrix product: (r, k) x (k, n) -> (r, n), all uint8."""
    m = np.asarray(m, dtype=np.uint8)
    d = np.asarray(d, dtype=np.uint8)
    r, k = m.shape
    out = np.zeros((r, d.shape[1]), dtype=np.uint8)
    for i in range(k):
        out ^= gf_mul_np(m[:, i : i + 1], d[i : i + 1, :])
    return out


def gf_inv_matrix_np(m: np.ndarray) -> np.ndarray:
    """Gauss-Jordan inversion of a square matrix over GF(256)."""
    m = np.asarray(m, dtype=np.uint8)
    n = m.shape[0]
    assert m.shape == (n, n)
    aug = np.concatenate([m.copy(), np.eye(n, dtype=np.uint8)], axis=1)
    for col in range(n):
        pivot = next((r for r in range(col, n) if aug[r, col] != 0), None)
        if pivot is None:
            raise np.linalg.LinAlgError("singular GF(256) matrix")
        if pivot != col:
            aug[[col, pivot]] = aug[[pivot, col]]
        inv_p = gf_inv(int(aug[col, col]))
        aug[col] = gf_mul_np(aug[col], np.uint8(inv_p))
        for r in range(n):
            if r != col and aug[r, col] != 0:
                aug[r] ^= gf_mul_np(np.full(2 * n, aug[r, col], np.uint8), aug[col])
    return aug[:, n:].copy()


@functools.lru_cache(maxsize=None)
def rs_encode_matrix(k: int, m: int) -> np.ndarray:
    """Systematic (k+m, k) RS generator matrix; top k rows are identity.

    Built from a Vandermonde matrix made systematic by column operations, so
    any k rows of the result are invertible (classic Plank construction).
    """
    if k + m > 256:
        raise ValueError("k + m must be <= 256 for GF(256) RS")
    vand = np.zeros((k + m, k), dtype=np.uint8)
    for r in range(k + m):
        v = 1
        for c in range(k):
            vand[r, c] = v
            v = gf_mul(v, r + 1) if r + 1 < 256 else v
    # Make top kxk block identity via column ops (multiply by its inverse).
    top_inv = gf_inv_matrix_np(vand[:k, :k])
    gen = gf_matmul_np(vand, top_inv)
    assert np.array_equal(gen[:k], np.eye(k, dtype=np.uint8))
    return gen


def rs_parity_matrix(k: int, m: int) -> np.ndarray:
    """The (m, k) parity rows of the systematic generator."""
    return rs_encode_matrix(k, m)[k:, :].copy()


def rs_decode_matrix(k: int, m: int, surviving: tuple[int, ...]) -> np.ndarray:
    """(k, k) matrix reconstructing the k data chunks from ``surviving``.

    ``surviving`` are row indices into the (k+m) codeword (data rows 0..k-1,
    parity rows k..k+m-1); exactly k of them must be given.
    """
    surviving = tuple(surviving)
    if len(surviving) != k:
        raise ValueError(f"need exactly k={k} surviving rows, got {len(surviving)}")
    gen = rs_encode_matrix(k, m)
    sub = gen[list(surviving), :]  # (k, k)
    return gf_inv_matrix_np(sub)


# --------------------------------------------------------------------------
# SWAR (int32-packed) GF(256) ops -- shared by jnp reference and Pallas kernel.
# --------------------------------------------------------------------------

def swar_xtime(v):
    """Multiply each of the 4 packed GF(256) bytes in an int32 by x.

    Works for numpy and jax.numpy arrays alike (pure bitwise int32 arithmetic;
    two's-complement wraparound keeps byte lanes independent: bit 7 of each
    byte is cleared before the shift, and the reduction term 0x1d is injected
    per byte from the extracted high bits).
    """
    hi = (v >> 7) & 0x01010101
    return ((v & 0x7F7F7F7F) << 1) ^ (hi * 0x1D)


def swar_gf_scale(v, coeff):
    """Scale packed bytes ``v`` (int32 array) by GF(256) scalar ``coeff``.

    ``coeff`` may be a python int or a traced int32 scalar; the loop over the
    8 bits of the coefficient is static, each step branchless.
    """
    acc = v - v  # zeros_like that works for np and jnp
    cur = v
    for bit in range(8):
        mask = -((coeff >> bit) & 1)  # 0 or -1 (all ones) in int32
        acc = acc ^ (cur & mask)
        cur = swar_xtime(cur)
    return acc


def bytes_to_i32(a: np.ndarray) -> np.ndarray:
    """View a uint8 array whose last dim is a multiple of 4 as int32 lanes."""
    a = np.ascontiguousarray(a, dtype=np.uint8)
    assert a.shape[-1] % 4 == 0
    return a.view(np.int32)


def i32_to_bytes(a: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(a, dtype=np.int32).view(np.uint8)
