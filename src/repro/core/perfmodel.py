"""ZN540-calibrated analytic performance model.

We cannot measure a real ZNS SSD in this environment, so the paper's own
measurements (§2.2, Figure 2; Exp#1 Figure 6; Exp#3 Figure 8) are used as
the calibration surface for an analytic throughput/latency model.  The
benchmarks replay the paper's experiment sweeps through this model plus the
*functional* simulator (for metadata/query/recovery costs measured for real),
and must reproduce the paper's qualitative trends:

* Zone Append > Zone Write for small writes on few open zones (intra-zone
  parallelism, saturating at ~4 outstanding appends per zone);
* Zone Write scales better with many open zones (inter-zone parallelism);
  Zone Append degrades beyond ~2 open zones (firmware compute);
* 16 KiB writes saturate a zone under either primitive;
* group size G buys Zone-Append concurrency up to qd saturation, with a
  per-group barrier amortized over G stripes.

All throughputs in MiB/s, latencies in microseconds, sizes in KiB.
"""
from __future__ import annotations

import bisect
import dataclasses

# ---- calibration points (paper §2.2 / Figure 2) ---------------------------

# Zone Write: single-zone throughput per request size (one outstanding cmd).
ZW_SINGLE = {4: 337.6, 8: 613.6, 16: 1050.0}
# Zone Write: device-level ceiling with many open zones.
ZW_DEVICE_MAX = {4: 777.1, 8: 1430.7, 16: 1750.0}
# Zone Append: single-zone throughput at qd>=4 (saturated intra-zone parallelism)
ZA_SINGLE_SAT = {4: 541.5, 8: 1026.6, 16: 1050.1}
# Zone Append: device ceiling (peaks at ~2 open zones, then firmware-bound)
ZA_DEVICE_MAX = {4: 577.5, 8: 1058.6, 16: 1750.0}
# Zone Append firmware penalty per extra open zone beyond 2 (fractional loss
# applied to the aggregate; paper Fig. 2a shows 4 KiB ZA dropping below its
# 2-zone peak as more zones open)
ZA_MULTIZONE_PENALTY = {4: 0.035, 8: 0.03, 16: 0.0}
ZA_SATURATION_QD = 4

_SIZES = sorted(ZW_SINGLE)


def _interp(table: dict[int, float], size_kib: float) -> float:
    """Log-linear interpolation over the calibrated request sizes."""
    sizes = _SIZES
    if size_kib <= sizes[0]:
        return table[sizes[0]] * (size_kib / sizes[0])  # latency-bound region
    if size_kib >= sizes[-1]:
        return table[sizes[-1]]  # bandwidth-saturated region
    i = bisect.bisect_left(sizes, size_kib)
    lo, hi = sizes[i - 1], sizes[i]
    f = (size_kib - lo) / (hi - lo)
    return table[lo] * (1 - f) + table[hi] * f


def zone_write_tput(size_kib: float, n_zones: int = 1) -> float:
    """Aggregate Zone Write throughput over ``n_zones`` open zones."""
    per_zone = _interp(ZW_SINGLE, size_kib)
    ceiling = _interp(ZW_DEVICE_MAX, size_kib)
    return min(per_zone * max(1, n_zones), ceiling)


def zone_append_tput(size_kib: float, qd: int = 4, n_zones: int = 1) -> float:
    """Aggregate Zone Append throughput (qd = outstanding appends per zone)."""
    sat = _interp(ZA_SINGLE_SAT, size_kib)
    base = _interp(ZW_SINGLE, size_kib)  # qd=1 behaves like an ordered write
    eff_qd = min(max(1, qd), ZA_SATURATION_QD)
    per_zone = base + (sat - base) * (eff_qd - 1) / (ZA_SATURATION_QD - 1)
    ceiling = _interp(ZA_DEVICE_MAX, size_kib)
    penalty = _interp(ZA_MULTIZONE_PENALTY, size_kib)
    agg = min(per_zone * max(1, n_zones), ceiling)
    agg *= 1.0 - penalty * max(0, n_zones - 2)  # firmware compute penalty
    return max(agg, 0.05 * per_zone)


# ---- per-command latency (timed simulation, repro.sim) ---------------------
#
# The discrete-event engine needs *service times for individual commands*,
# not aggregate throughputs.  These are derived from the same ZN540
# calibration surface: in the latency-bound region a zone sustains
# ``tput = size / latency`` with one outstanding command, so the calibrated
# single-zone throughput curve *is* a latency curve.  Zone Append reaches
# its saturated throughput with ~4 commands in flight, so its per-command
# service time at queue depth qd satisfies ``qd * size / latency = tput(qd)``.


def zone_write_cmd_latency_us(size_kib: float) -> float:
    """Mean service time of one Zone Write command (one outstanding/zone)."""
    return size_kib / 1024.0 / zone_write_tput(size_kib, 1) * 1e6


def zone_append_cmd_latency_us(size_kib: float, qd: int = ZA_SATURATION_QD) -> float:
    """Mean service time of one Zone Append command at in-flight depth ``qd``.

    At qd=1 this equals the Zone Write latency (an append with no siblings is
    an ordered write); at qd>=4 the intra-zone parallelism is saturated and
    per-command latency grows while aggregate throughput plateaus -- exactly
    the Figure 2 shape."""
    eff = min(max(1, qd), ZA_SATURATION_QD)
    return eff * size_kib / 1024.0 / zone_append_tput(size_kib, eff, 1) * 1e6


def read_cmd_latency_us(size_kib: float) -> float:
    """Mean service time of one read command (NAND page read dominated).

    Calibrated to the paper's ~82-86 us normal-read medians at 4 KiB
    (Figure 7); reads are slower than SLC-buffered writes on the ZN540."""
    return 70.0 + 4.0 * size_kib


@dataclasses.dataclass
class ArrayPerf:
    """Array-level write performance estimate."""
    throughput_mib_s: float
    median_lat_us: float
    p95_lat_us: float


def zapraid_write_perf(
    *,
    k: int,
    m: int,
    chunk_kib: float,
    group_size: int,
    host_qd: int = 64,
    n_open_segments: int = 1,
    use_append: bool = True,
    barrier_us: float = 12.0,
) -> ArrayPerf:
    """Estimated ZapRAID write throughput for one segment class.

    The user-visible throughput counts data chunks only (k of k+m); the
    drives carry chunk-sized requests.  Zone-Append concurrency per zone is
    bounded by both the stripe-group size G and the host queue depth; the
    inter-group barrier costs ``barrier_us`` amortized over G stripes.
    """
    n = k + m
    per_zone_qd = max(1, min(group_size, host_qd // max(1, n_open_segments)))
    if use_append and group_size > 1:
        drive_tput = zone_append_tput(chunk_kib, per_zone_qd, n_open_segments)
    else:
        # One outstanding Zone Write per zone serializes stripe commits; the
        # paper measures ~10% loss vs the ideal 3x single-zone rate (Exp#1:
        # 910.8 vs 1012.8 MiB/s for 4 KiB).
        drive_tput = zone_write_tput(chunk_kib, n_open_segments) * 0.90
    # Each drive sustains drive_tput; stripes need all k+m chunks; user data
    # fraction is k/(k+m).
    raw = drive_tput * n
    user = raw * (k / n)
    if use_append and group_size > 1 and barrier_us > 0:
        # Barrier amortization: G stripes of k*chunk user bytes per barrier.
        group_bytes_mib = group_size * k * chunk_kib / 1024.0
        t_group_s = group_bytes_mib / user + barrier_us * 1e-6
        user = group_bytes_mib / t_group_s
    stripe_kib = k * chunk_kib
    med = stripe_kib / 1024.0 / max(user, 1e-9) * 1e6  # us per stripe
    p95_factor = 3.0 if (use_append and chunk_kib >= 16) else 1.8
    return ArrayPerf(
        throughput_mib_s=user,
        median_lat_us=med,
        p95_lat_us=med * p95_factor,
    )


def hybrid_write_perf(
    *,
    k: int,
    m: int,
    cs_kib: float,
    cl_kib: float,
    n_small: int,
    n_large: int,
    frac_small: float,
    group_size: int,
    host_qd: int = 64,
) -> ArrayPerf:
    """Hybrid data management (§3.3): small writes -> N_s small-chunk segments
    (one reserved for Zone Append), large writes -> N_l Zone-Write segments."""
    n_open = max(1, n_small + n_large)
    perfs = []
    if frac_small > 0 and n_small > 0:
        za = zapraid_write_perf(
            k=k, m=m, chunk_kib=cs_kib, group_size=group_size,
            host_qd=host_qd, n_open_segments=1, use_append=True,
        )
        zw_small = (
            zapraid_write_perf(
                k=k, m=m, chunk_kib=cs_kib, group_size=1,
                host_qd=host_qd, n_open_segments=n_small - 1, use_append=False,
            ).throughput_mib_s
            if n_small > 1
            else 0.0
        )
        perfs.append(("small", frac_small, za.throughput_mib_s + zw_small, za))
    if frac_small < 1 and n_large > 0:
        zw = zapraid_write_perf(
            k=k, m=m, chunk_kib=cl_kib, group_size=1,
            host_qd=host_qd, n_open_segments=n_large, use_append=False,
        )
        perfs.append(("large", 1 - frac_small, zw.throughput_mib_s, zw))
    if not perfs:  # everything routed to whatever class exists
        za = zapraid_write_perf(
            k=k, m=m, chunk_kib=cs_kib if n_small else cl_kib,
            group_size=group_size if n_small else 1, host_qd=host_qd,
            n_open_segments=n_open, use_append=bool(n_small),
        )
        return za
    # classes run concurrently; workload completes when the slower class
    # finishes its share: T = max_i share_i / tput_i; overall = 1 / T.
    t_total = max(share / max(tput, 1e-9) for _, share, tput, _ in perfs)
    tput = 1.0 / t_total
    med = sum(share * p.median_lat_us for _, share, _, p in perfs)
    p95 = max(p.p95_lat_us for _, _, _, p in perfs)
    return ArrayPerf(throughput_mib_s=tput, median_lat_us=med, p95_lat_us=p95)


def degraded_read_latency_us(
    *, k: int, chunk_kib: float, group_size: int, cst_entry_ns: float = 4.0
) -> float:
    """Degraded read latency: k parallel chunk reads + decode + CST search.

    CST query touches k*G entries (§3.2); read latency calibrated to the
    paper's ~85 us medians (Figure 7) for 4 KiB chunks."""
    read_us = 70.0 + 4.0 * chunk_kib  # k reads issued in parallel
    decode_us = 0.4 * chunk_kib * k / 3.0
    query_us = (k * group_size * cst_entry_ns) / 1e3
    return read_us + decode_us + query_us


def crash_recovery_time_s(
    *, logical_gib: float, chunk_kib: float, footer_read_mib_s: float = 2800.0
) -> float:
    """Crash recovery ~ footer reads of all sealed segments (Exp#5): 20 bytes
    of metadata per 4 KiB block, plus a fixed mount cost."""
    meta_mib = logical_gib * 1024.0 * (20.0 / 4096.0)
    return 1.05 + meta_mib / footer_read_mib_s * 60.0  # calibrated to ~1.5s/100GiB


def full_drive_recovery_time_s(*, logical_gib: float, k: int, chunk_kib: float) -> float:
    """Full-drive rebuild ~ read k survivors + write 1/(k+1) of logical space.
    Calibrated to 81.3 s / 100 GiB at 4 KiB chunks, 18-24% faster for larger
    chunks (Exp#5)."""
    base = 81.3 * (logical_gib / 100.0)
    speedup = {4: 1.0, 8: 0.80, 16: 0.77}.get(int(chunk_kib), 0.77)
    return base * speedup
