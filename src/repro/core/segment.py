"""Segment layout arithmetic and (de)serialization (paper §3.1).

A segment spans k+m zones (one per drive).  Within each zone:

    [ header: C blocks ][ data region: S*C blocks ][ footer: ceil(S*C/204) ]

* header -- replicated segment descriptor (RAID scheme, k, m, zone ids,
  chunk size, group size, segment id, creation timestamp);
* data region -- S stripes of C-block chunks;
* footer -- per-block metadata (LBA u64, ts u64, stripe u32 = 20 bytes) for
  every data-region block *of that zone*, 204 entries per 4 KiB block.

``solve_stripes_per_segment`` reproduces the paper's arithmetic: for the
ZN540 zone (275 712 blocks, C=1) it yields header 1, data 274 366, footer
1 345 blocks.
"""
from __future__ import annotations

import dataclasses
import enum
import struct

import numpy as np

from repro.core.zns import OOB_DTYPE, OOB_ENTRY_BYTES
from repro.integrity.checksum import CRC_BYTES, crc32c_many, crc32c_pack

HEADER_MAGIC = b"ZAPR"
HEADER_VERSION = 3


class FooterError(ValueError):
    """Loud failure: a zone footer is truncated or fails its checksum.

    Raised by :func:`unpack_footer` instead of ever returning garbage
    mappings; recovery catches it and falls back to the OOB-area scan."""


class SegmentState(enum.IntEnum):
    OPEN = 0
    SEALED = 1
    FREE = 2


class SegmentClass(enum.IntEnum):
    SMALL = 0  # small-chunk segment (hybrid data management, §3.3)
    LARGE = 1  # large-chunk segment


def footer_entries_per_block(block_bytes: int) -> int:
    return block_bytes // OOB_ENTRY_BYTES  # 4096 // 20 = 204


def footer_slack_bytes(block_bytes: int) -> int:
    """Bytes left in a footer block after ``epb`` packed entries (16 at
    4 KiB blocks) -- where the in-band footer checksum lives."""
    return block_bytes - footer_entries_per_block(block_bytes) * OOB_ENTRY_BYTES


def footer_has_crc(block_bytes: int) -> bool:
    """True when the geometry leaves room for the in-band footer CRC32C.

    Slack-less geometries (e.g. 80/100-byte test blocks pack entries
    exactly) skip the in-band checksum; their footers are still covered
    by the drive's per-block checksum store."""
    return footer_slack_bytes(block_bytes) >= CRC_BYTES


def solve_stripes_per_segment(zone_cap_blocks: int, chunk_blocks: int, block_bytes: int) -> tuple[int, int]:
    """Max stripes S per segment s.t. header + S*C + ceil(S*C/epb) <= cap.

    Returns (S, footer_blocks).
    """
    epb = footer_entries_per_block(block_bytes)
    c = chunk_blocks
    avail = zone_cap_blocks - c  # header costs one chunk
    # S*C + ceil(S*C/epb) <= avail; solve for the largest S.
    s = avail // c
    while s > 0:
        data = s * c
        foot = -(-data // epb)
        if c + data + foot <= zone_cap_blocks:
            break
        s -= 1
    if s <= 0:
        raise ValueError("zone too small for even one stripe")
    return s, -(-s * c // epb)


@dataclasses.dataclass
class SegmentInfo:
    seg_id: int
    scheme_name: str
    k: int
    m: int
    zone_ids: tuple[int, ...]  # zone index on each of the k+m drives
    chunk_blocks: int
    group_size: int  # G; 1 => Zone Write, >1 => Zone Append groups
    seg_class: int  # SegmentClass
    create_ts: int
    n_stripes: int = 0  # filled from layout at open time
    state: int = int(SegmentState.OPEN)
    stripes_written: int = 0  # controller-side cursor (stripes fully persisted)
    drive_ids: tuple[int, ...] = ()  # member index -> physical drive index

    def __post_init__(self) -> None:
        if not self.drive_ids:
            self.drive_ids = tuple(range(self.k + self.m))

    @property
    def n_drives(self) -> int:
        return self.k + self.m

    @property
    def uses_append(self) -> bool:
        return self.group_size > 1

    def data_start(self) -> int:
        return self.chunk_blocks  # header occupies the first chunk

    def group_span_blocks(self) -> int:
        return self.group_size * self.chunk_blocks

    def n_groups(self) -> int:
        return -(-self.n_stripes // self.group_size)


_HEADER_FMT = "<4sHHqHH" + "q" + "qqHq"  # see pack_header


def pack_header(info: SegmentInfo, block_bytes: int) -> np.ndarray:
    """Serialize a SegmentInfo into one block (replicated per zone)."""
    zone_blob = struct.pack(f"<{len(info.zone_ids)}q", *info.zone_ids)
    drive_blob = struct.pack(f"<{len(info.drive_ids)}H", *info.drive_ids)
    name_b = info.scheme_name.encode()
    payload = struct.pack(
        "<4sHHqHHqqHqH",
        HEADER_MAGIC,
        HEADER_VERSION,
        len(name_b),
        info.seg_id,
        info.k,
        info.m,
        info.chunk_blocks,
        info.group_size,
        info.seg_class,
        info.create_ts,
        len(info.zone_ids),
    ) + name_b + zone_blob + drive_blob
    if len(payload) > block_bytes:
        raise ValueError("header does not fit in one block")
    buf = np.zeros(block_bytes, dtype=np.uint8)
    buf[: len(payload)] = np.frombuffer(payload, dtype=np.uint8)
    return buf


def header_candidates(blocks: np.ndarray) -> np.ndarray:
    """Vectorized pre-filter for a batch of would-be header blocks.

    ``blocks`` is (n, block_bytes) uint8; returns a bool mask of rows whose
    magic and version fields match, so the batched recovery scanner only
    struct-unpacks real headers instead of every written zone's block 0."""
    if blocks.shape[0] == 0:
        return np.zeros(0, dtype=bool)
    magic = np.frombuffer(HEADER_MAGIC, dtype=np.uint8)
    ok = (blocks[:, :4] == magic[None, :]).all(axis=1)
    ver = blocks[:, 4].astype(np.uint16) | (blocks[:, 5].astype(np.uint16) << 8)
    return ok & (ver == HEADER_VERSION)


def unpack_header(block: np.ndarray) -> SegmentInfo | None:
    raw = block.tobytes()
    head_sz = struct.calcsize("<4sHHqHHqqHqH")
    if len(raw) < head_sz:
        return None
    (magic, ver, name_len, seg_id, k, m, chunk_blocks, group_size, seg_class,
     create_ts, n_zones) = struct.unpack("<4sHHqHHqqHqH", raw[:head_sz])
    if magic != HEADER_MAGIC or ver != HEADER_VERSION:
        return None
    off = head_sz
    name = raw[off : off + name_len].decode()
    off += name_len
    zone_ids = struct.unpack(f"<{n_zones}q", raw[off : off + 8 * n_zones])
    off += 8 * n_zones
    drive_ids = struct.unpack(f"<{n_zones}H", raw[off : off + 2 * n_zones])
    return SegmentInfo(
        seg_id=seg_id, scheme_name=name, k=k, m=m, zone_ids=tuple(zone_ids),
        chunk_blocks=chunk_blocks, group_size=group_size, seg_class=seg_class,
        create_ts=create_ts, drive_ids=tuple(drive_ids),
    )


def pack_footer(oob_entries: np.ndarray, block_bytes: int) -> np.ndarray:
    """Serialize the data region's OOB entries of one zone into footer blocks.

    When the geometry has slack (:func:`footer_has_crc`) each footer block
    carries a CRC32C of its packed entry area in the first 4 slack bytes,
    so a recovery scan can tell an intact footer from a rotted one without
    trusting the mappings it is about to install."""
    epb = footer_entries_per_block(block_bytes)
    n = oob_entries.shape[0]
    n_blocks = -(-n // epb)
    raw = np.zeros(n_blocks * epb, dtype=OOB_DTYPE)
    raw[:n] = oob_entries
    entry_bytes = epb * OOB_ENTRY_BYTES
    flat = raw.view(np.uint8).reshape(n_blocks, entry_bytes)
    out = np.zeros((n_blocks, block_bytes), dtype=np.uint8)
    out[:, :entry_bytes] = flat
    if footer_has_crc(block_bytes):
        out[:, entry_bytes : entry_bytes + CRC_BYTES] = crc32c_pack(
            crc32c_many(flat)
        )
    return out


def footer_crc_ok(blocks: np.ndarray, block_bytes: int) -> np.ndarray:
    """Per-block validity mask for footer blocks.

    All-True on slack-less geometries (nothing to check in-band)."""
    n_blocks = blocks.shape[0]
    if not footer_has_crc(block_bytes):
        return np.ones(n_blocks, dtype=bool)
    entry_bytes = footer_entries_per_block(block_bytes) * OOB_ENTRY_BYTES
    stored = np.ascontiguousarray(
        blocks[:, entry_bytes : entry_bytes + CRC_BYTES]
    ).view("<u4").reshape(n_blocks)
    return crc32c_many(np.ascontiguousarray(blocks[:, :entry_bytes])) == stored


def unpack_footer(
    blocks: np.ndarray, n_entries: int, block_bytes: int, *, strict: bool = False
) -> np.ndarray:
    """Deserialize footer blocks back into OOB entries.

    Raises :class:`FooterError` when the blocks cannot possibly hold
    ``n_entries`` (truncated footer) and, with ``strict``, when any
    block's in-band checksum mismatches -- never silently returns short
    or corrupt mappings."""
    epb = footer_entries_per_block(block_bytes)
    blocks = np.asarray(blocks, dtype=np.uint8).reshape(blocks.shape[0], -1)
    if blocks.shape[1] < epb * OOB_ENTRY_BYTES:
        raise FooterError(
            f"footer blocks of {blocks.shape[1]} bytes cannot hold "
            f"{epb} entries (need {epb * OOB_ENTRY_BYTES})"
        )
    if blocks.shape[0] * epb < n_entries:
        raise FooterError(
            f"truncated footer: {blocks.shape[0]} blocks hold at most "
            f"{blocks.shape[0] * epb} entries, need {n_entries}"
        )
    if strict:
        ok = footer_crc_ok(blocks[:, :block_bytes], block_bytes)
        if not ok.all():
            bad = np.flatnonzero(~ok)
            raise FooterError(
                f"footer checksum mismatch in block(s) {bad.tolist()}"
            )
    flat = np.ascontiguousarray(blocks[:, : epb * OOB_ENTRY_BYTES]).reshape(-1)
    entries = flat.view(OOB_DTYPE)[:n_entries]
    return entries.copy()
