"""Logical-to-physical (L2P) table with CLOCK-based offloading (paper §3.1).

The L2P maps each logical block address to a packed PBA
``(segment id, drive id, zone offset)``.  Two modes:

* fully resident -- one flat int64 array (the paper's default);
* memory-capped -- entries are grouped into 1024-entry *entry groups*; a
  CLOCK (second-chance) policy evicts non-recently-used groups into 4 KiB
  *mapping blocks* written through the normal write path (LSB-tagged LBA
  field so recovery can tell them from user blocks), with a small in-memory
  mapping table gid -> PBA.

The table is deliberately storage-backend-agnostic: eviction/refill go
through two callbacks supplied by the owning array.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

DEFAULT_ENTRIES_PER_GROUP = 1024  # 4-byte entries -> one 4 KiB mapping block
ENTRIES_PER_GROUP = DEFAULT_ENTRIES_PER_GROUP  # back-compat alias
NO_PBA = np.int64(-1)

# PBA packing: seg_id << 40 | drive << 32 | offset
_SEG_SHIFT = 40
_DRIVE_SHIFT = 32
_OFF_MASK = (1 << 32) - 1
_DRIVE_MASK = (1 << 8) - 1


def pack_pba(seg_id: int, drive: int, offset: int) -> int:
    assert 0 <= offset <= _OFF_MASK and 0 <= drive <= _DRIVE_MASK
    return (seg_id << _SEG_SHIFT) | (drive << _DRIVE_SHIFT) | offset


def unpack_pba(pba: int) -> tuple[int, int, int]:
    pba = int(pba)
    return pba >> _SEG_SHIFT, (pba >> _DRIVE_SHIFT) & _DRIVE_MASK, pba & _OFF_MASK


def unpack_pba_many(pbas: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized ``unpack_pba``: int64 array -> (seg, drive, off) arrays."""
    pbas = np.asarray(pbas, dtype=np.int64)
    return (
        pbas >> _SEG_SHIFT,
        (pbas >> _DRIVE_SHIFT) & _DRIVE_MASK,
        pbas & _OFF_MASK,
    )


def pack_pba_many(
    seg_id: int, drives: np.ndarray, offsets: np.ndarray
) -> np.ndarray:
    """Vectorized ``pack_pba`` for one segment (group-commit bookkeeping)."""
    return (
        (np.int64(seg_id) << _SEG_SHIFT)
        | (np.asarray(drives, np.int64) << _DRIVE_SHIFT)
        | np.asarray(offsets, np.int64)
    )


class L2PTable:
    def __init__(
        self,
        n_blocks: int,
        *,
        memory_limit_entries: Optional[int] = None,
        write_mapping_block: Optional[Callable[[int, np.ndarray], None]] = None,
        read_mapping_block: Optional[Callable[[int], Optional[np.ndarray]]] = None,
        entries_per_group: int = DEFAULT_ENTRIES_PER_GROUP,
    ):
        self.n_blocks = n_blocks
        self.epg = entries_per_group
        self.n_groups = -(-n_blocks // entries_per_group)
        self.offload = memory_limit_entries is not None
        self.limit_groups = (
            max(1, memory_limit_entries // entries_per_group) if self.offload else None
        )
        self._write_cb = write_mapping_block
        self._read_cb = read_mapping_block
        # Fires on every CLOCK eviction (clean or dirty) with the evicted
        # group image -- the array's cache tier uses it to keep offloaded
        # mapping blocks warm beyond the resident budget.
        self.evict_listener: Optional[Callable[[int, np.ndarray], None]] = None
        if not self.offload:
            self.flat = np.full(n_blocks, NO_PBA, dtype=np.int64)
        else:
            self.resident: dict[int, np.ndarray] = {}
            self.dirty: set[int] = set()
            self.refbit = np.zeros(self.n_groups, dtype=np.uint8)
            # resident-group bitmap mirroring ``resident.keys()``: the CLOCK
            # sweep reads candidates from one ``flatnonzero`` instead of
            # rebuilding a sorted Python list per eviction
            self.resident_mask = np.zeros(self.n_groups, dtype=bool)
            self.hand = 0
        # stats
        self.misses = 0
        self.evictions = 0
        self.lookups = 0

    # -- helpers ------------------------------------------------------------

    def _group_of(self, lba: int) -> tuple[int, int]:
        return lba // self.epg, lba % self.epg

    def _fault_in(self, gid: int) -> np.ndarray:
        if gid in self.resident:
            self.refbit[gid] = 1
            return self.resident[gid]
        self.misses += 1
        entries = self._read_cb(gid) if self._read_cb else None
        if entries is None:
            entries = np.full(self.epg, NO_PBA, dtype=np.int64)
        self.resident[gid] = entries
        self.resident_mask[gid] = True
        self.refbit[gid] = 1
        # The faulting group is pinned: the caller is about to read or mutate
        # the returned array, so evicting it here would orphan that update
        # (a clean eviction writes nothing back and the store is lost).
        self._maybe_evict(pinned=gid)
        return entries

    def _maybe_evict(self, pinned: Optional[int] = None) -> None:
        while len(self.resident) > self.limit_groups:
            # CLOCK sweep over resident groups in gid order from the hand:
            # one bitmap scan yields the (already sorted) candidates.
            gids = np.flatnonzero(self.resident_mask)
            n = int(gids.size)
            start = int(np.searchsorted(gids, self.hand))
            if start == n:
                start = 0
            for step in range(2 * n + 1):
                g = int(gids[(start + step) % n])
                if g == pinned:
                    continue
                if self.refbit[g]:
                    self.refbit[g] = 0
                    continue
                self._evict(g)
                self.hand = int(gids[(start + step + 1) % n])
                break
            else:  # all referenced twice around: evict the hand's group
                g = int(gids[start])
                if g == pinned:
                    g = int(gids[(start + 1) % n])
                self._evict(g)

    def _evict(self, gid: int) -> None:
        entries = self.resident.pop(gid)
        self.resident_mask[gid] = False
        self.evictions += 1
        if self.evict_listener is not None:
            self.evict_listener(gid, entries)
        if gid in self.dirty:
            self.dirty.discard(gid)
            if self._write_cb is not None:
                self._write_cb(gid, entries)

    # -- public API ---------------------------------------------------------

    def get(self, lba: int) -> int:
        self.lookups += 1
        if not self.offload:
            return int(self.flat[lba])
        gid, idx = self._group_of(lba)
        return int(self._fault_in(gid)[idx])

    def set(self, lba: int, pba: int) -> None:
        if not self.offload:
            self.flat[lba] = pba
            return
        gid, idx = self._group_of(lba)
        self._fault_in(gid)[idx] = pba
        self.dirty.add(gid)

    def _group_runs(self, lbas: np.ndarray):
        """Yield ``(gid, positions)`` per distinct entry group, ascending gid.

        One stable argsort replaces the per-group boolean masks (O(n log n)
        instead of O(groups * n) -- the difference between a noticeable stall
        and a non-event for recovery-scale bulk installs).  Positions keep
        their original relative order within each group."""
        if lbas.size == 0:
            return
        gids = lbas // self.epg
        order = np.argsort(gids, kind="stable")
        sg = gids[order]
        starts = np.flatnonzero(np.r_[True, sg[1:] != sg[:-1]])
        ends = np.r_[starts[1:], sg.size]
        for s, e in zip(starts, ends):
            yield int(sg[s]), order[s:e]

    def get_many(self, lbas: np.ndarray) -> np.ndarray:
        """Vectorized lookup: int array of LBAs -> int64 array of PBAs.

        Flat mode is a single numpy gather; offload mode faults in each
        distinct entry group once and gathers within it, so a sequential
        multi-block read costs O(groups) faults instead of O(blocks)."""
        lbas = np.asarray(lbas, dtype=np.int64)
        self.lookups += int(lbas.size)
        if not self.offload:
            return self.flat[lbas].copy()
        out = np.empty(lbas.shape, dtype=np.int64)
        for g, pos in self._group_runs(lbas):
            entries = self.resident.get(g)  # one dict probe per *group*
            if entries is None:
                entries = self._fault_in(g)
            else:
                self.refbit[g] = 1
            out[pos] = entries[lbas[pos] % self.epg]
        return out

    def set_many(self, lbas: np.ndarray, pbas: np.ndarray) -> None:
        """Vectorized update; later entries win on duplicate LBAs (numpy
        fancy-assignment order), matching a sequential ``set`` loop."""
        lbas = np.asarray(lbas, dtype=np.int64)
        pbas = np.asarray(pbas, dtype=np.int64)
        if not self.offload:
            self.flat[lbas] = pbas
            return
        for g, pos in self._group_runs(lbas):
            entries = self.resident.get(g)  # one dict probe per *group*
            if entries is None:
                entries = self._fault_in(g)
            else:
                self.refbit[g] = 1
            entries[lbas[pos] % self.epg] = pbas[pos]
            self.dirty.add(g)

    def compare_and_clear(self, lba: int, pba: int) -> None:
        """Invalidate the mapping only if it still points at ``pba`` (GC races)."""
        if self.get(lba) == pba:
            self.set(lba, int(NO_PBA))

    def flush(self) -> None:
        """Write back every dirty resident group (used before clean shutdown)."""
        if not self.offload:
            return
        for gid in sorted(self.dirty):
            if self._write_cb is not None:
                self._write_cb(gid, self.resident[gid])
        self.dirty.clear()

    def load_group(self, gid: int, entries: np.ndarray) -> None:
        """Recovery helper: install a group image."""
        if not self.offload:
            lo = gid * self.epg
            hi = min(lo + self.epg, self.n_blocks)
            self.flat[lo:hi] = entries[: hi - lo]
        else:
            self.resident[gid] = entries.copy()
            self.resident_mask[gid] = True
            self.refbit[gid] = 1
            self._maybe_evict()

    def drop_group(self, gid: int) -> None:
        """Recovery helper: forget a resident group (its mapping block is newer)."""
        if self.offload:
            self.resident.pop(gid, None)
            self.resident_mask[gid] = False
            self.dirty.discard(gid)

    def memory_bytes(self) -> int:
        if not self.offload:
            return self.n_blocks * 4  # paper counts 4-byte entries
        return len(self.resident) * self.epg * 4
