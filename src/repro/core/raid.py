"""RAID schemes and the stripe codec (encode / decode / placement rotation).

Supports the paper's five schemes (Exp#4): RAID-0, RAID-01, RAID-4, RAID-5,
RAID-6 on an n-drive array.  The codec operates on int32-packed chunk
payloads and dispatches to the Pallas kernels (XOR for single parity, GF(256)
Reed-Solomon for double parity) or their jnp oracles.

Placement: role r of a stripe lives on drive ``(r + rot) % n`` where
``rot = stripe_seq % n`` for rotating schemes (RAID-5/6) and ``rot = 0`` for
fixed-parity schemes (RAID-0/01/4) -- the classic left-symmetric rotation the
paper sketches in Figure 3.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import gf
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class RaidScheme:
    name: str
    k: int  # data chunks per stripe
    m: int  # parity chunks per stripe
    rotate: bool  # rotate parity placement across drives
    mirror: bool = False  # RAID-01: parity chunks are copies of data chunks

    @property
    def n(self) -> int:
        return self.k + self.m

    def rotation(self, stripe_seq: int) -> int:
        return stripe_seq % self.n if self.rotate else 0

    def role_to_drive(self, role: int, stripe_seq: int) -> int:
        return (role + self.rotation(stripe_seq)) % self.n

    def drive_to_role(self, drive: int, stripe_seq: int) -> int:
        return (drive - self.rotation(stripe_seq)) % self.n

    def rotation_many(self, stripe_seqs: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`rotation` (batched commit/harvest paths)."""
        seqs = np.asarray(stripe_seqs, dtype=np.int64)
        return seqs % self.n if self.rotate else np.zeros(seqs.shape, np.int64)

    def drive_to_role_many(self, drive: int, stripe_seqs: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`drive_to_role` for one drive across stripes."""
        return (drive - self.rotation_many(stripe_seqs)) % self.n


def make_scheme(name: str, n_drives: int) -> RaidScheme:
    name = name.lower()
    if name == "raid0":
        return RaidScheme("raid0", n_drives, 0, rotate=False)
    if name == "raid01":
        if n_drives % 2:
            raise ValueError("raid01 needs an even drive count")
        return RaidScheme("raid01", n_drives // 2, n_drives // 2, rotate=False, mirror=True)
    if name == "raid4":
        return RaidScheme("raid4", n_drives - 1, 1, rotate=False)
    if name == "raid5":
        return RaidScheme("raid5", n_drives - 1, 1, rotate=True)
    if name == "raid6":
        return RaidScheme("raid6", n_drives - 2, 2, rotate=True)
    raise ValueError(f"unknown RAID scheme {name!r}")


class StripeCodec:
    """Encode/decode stripes for a scheme, via Pallas kernels or oracles.

    Two byte-level surfaces exist side by side:

    * ``encode_np``/``decode_np`` and their ``_batch`` variants -- blocking
      uint8-in/uint8-out convenience wrappers (host packing is a free dtype
      view; one device round trip per call);
    * ``encode_batch_async``/``decode_batch_async`` -- the device-resident
      group datapath: take an int32-packed host buffer the caller gives up
      (an arena gather), donate it to XLA, and return the *un-materialized*
      device array so the dispatch overlaps host-side commit work.  The
      caller syncs with :meth:`materialize`.

    ``copy_stats`` (optional) is an object with ``h2d_copies/h2d_bytes/
    d2h_copies/d2h_bytes`` counters (e.g. :class:`repro.core.array.Stats`)
    bumped on every host<->device transfer the codec performs.
    """

    def __init__(self, scheme: RaidScheme, *, use_pallas: bool = False, interpret: bool = True):
        self.scheme = scheme
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.copy_stats = None

    # -- host<->device accounting -------------------------------------------

    def _to_device(self, packed_np: np.ndarray) -> jnp.ndarray:
        if self.copy_stats is not None:
            self.copy_stats.h2d_copies += 1
            self.copy_stats.h2d_bytes += packed_np.nbytes
        # jnp.array (copy=True), NOT jnp.asarray: on the CPU backend asarray
        # zero-copies, and donating a device buffer that aliases host memory
        # the caller still reads (the arena gather doubles as the commit
        # payload) would let XLA scribble over it.
        return jnp.array(packed_np)

    def materialize(self, out_dev: jnp.ndarray) -> np.ndarray:
        """Sync point: block on the device result and bring it to the host."""
        out = np.asarray(out_dev)
        if self.copy_stats is not None:
            self.copy_stats.d2h_copies += 1
            self.copy_stats.d2h_bytes += out.nbytes
        return out

    # data: (k, n_i32) int32 packed chunk payloads
    def encode(self, data_i32: jnp.ndarray) -> jnp.ndarray:
        """Return (m, n_i32) parity chunks (empty for RAID-0)."""
        s = self.scheme
        assert data_i32.shape[0] == s.k, (data_i32.shape, s)
        if s.m == 0:
            return jnp.zeros((0, data_i32.shape[1]), jnp.int32)
        if s.mirror:
            return data_i32
        if s.m == 1:
            p = ops.xor_parity(
                data_i32, use_pallas=self.use_pallas, interpret=self.interpret
            )
            return p[None, :]
        return ops.rs_encode(
            data_i32, s.m, use_pallas=self.use_pallas, interpret=self.interpret
        )

    def decode(
        self, surviving_i32: jnp.ndarray, surviving_roles: tuple[int, ...]
    ) -> jnp.ndarray:
        """Reconstruct all k data chunks from k surviving codeword rows."""
        s = self.scheme
        if s.m == 0:
            raise ValueError("RAID-0 cannot decode lost chunks")
        if s.mirror:
            # role r and role r+k are copies; pick whichever survived.
            out = {}
            for row, role in zip(surviving_i32, surviving_roles):
                out.setdefault(role % s.k, row)
            if len(out) < s.k:
                raise ValueError("RAID-01: both copies of a chunk lost")
            return jnp.stack([out[i] for i in range(s.k)], axis=0)
        roles = tuple(surviving_roles)
        if len(roles) != s.k:
            raise ValueError(f"need exactly k={s.k} surviving rows, got {len(roles)}")
        if set(roles) == set(range(s.k)):
            # all data roles survive (possibly permuted): just reorder.
            order = [roles.index(i) for i in range(s.k)]
            return surviving_i32[jnp.array(order)]
        if s.m == 1:
            # Single parity: lost data chunk = XOR of the survivors.
            lost = set(range(s.k)) - set(roles)
            assert len(lost) == 1
            lost_role = lost.pop()
            rec = ops.xor_parity(
                surviving_i32, use_pallas=self.use_pallas, interpret=self.interpret
            )
            rows = {role: surviving_i32[i] for i, role in enumerate(roles) if role < s.k}
            rows[lost_role] = rec
            return jnp.stack([rows[i] for i in range(s.k)], axis=0)
        return ops.rs_decode(
            surviving_i32, roles, s.k, s.m,
            use_pallas=self.use_pallas, interpret=self.interpret,
        )

    # batched (stripe-group) datapath: data (S, k, n_i32) int32
    def encode_batch(self, data_i32: jnp.ndarray) -> jnp.ndarray:
        """Encode S stripes at once: (S, k, n) -> (S, m, n) parity.

        One fused kernel dispatch per group instead of one per stripe; the
        output is bit-identical to stacking ``encode`` over the S stripes.
        """
        s = self.scheme
        assert data_i32.ndim == 3 and data_i32.shape[1] == s.k, (data_i32.shape, s)
        if s.m == 0:
            return jnp.zeros((data_i32.shape[0], 0, data_i32.shape[2]), jnp.int32)
        if s.mirror:
            return data_i32
        if s.m == 1:
            p = ops.xor_parity_batch(
                data_i32, use_pallas=self.use_pallas, interpret=self.interpret
            )
            return p[:, None, :]
        return ops.rs_encode_batch(
            data_i32, s.m, use_pallas=self.use_pallas, interpret=self.interpret
        )

    def decode_batch(
        self, surviving_i32: jnp.ndarray, surviving_roles: tuple[int, ...]
    ) -> jnp.ndarray:
        """Reconstruct S stripes' data chunks from survivors sharing one role
        set: (S, k, n) survivors -> (S, k, n) data, bit-identical to stacking
        ``decode`` over the S stripes."""
        s = self.scheme
        if s.m == 0:
            raise ValueError("RAID-0 cannot decode lost chunks")
        roles = tuple(surviving_roles)
        if s.mirror:
            out = {}
            for i, role in enumerate(roles):
                out.setdefault(role % s.k, surviving_i32[:, i])
            if len(out) < s.k:
                raise ValueError("RAID-01: both copies of a chunk lost")
            return jnp.stack([out[i] for i in range(s.k)], axis=1)
        if len(roles) != s.k:
            raise ValueError(f"need exactly k={s.k} surviving rows, got {len(roles)}")
        if set(roles) == set(range(s.k)):
            order = [roles.index(i) for i in range(s.k)]
            return surviving_i32[:, jnp.array(order)]
        if s.m == 1:
            lost = set(range(s.k)) - set(roles)
            assert len(lost) == 1
            lost_role = lost.pop()
            rec = ops.xor_parity_batch(
                surviving_i32, use_pallas=self.use_pallas, interpret=self.interpret
            )
            cols = {role: surviving_i32[:, i] for i, role in enumerate(roles) if role < s.k}
            cols[lost_role] = rec
            return jnp.stack([cols[i] for i in range(s.k)], axis=1)
        return ops.rs_decode_batch(
            surviving_i32, roles, s.k, s.m,
            use_pallas=self.use_pallas, interpret=self.interpret,
        )

    def decode_np(self, surviving: np.ndarray, surviving_roles: tuple[int, ...]) -> np.ndarray:
        """Byte-level convenience wrapper (uint8 in/out) used by recovery paths."""
        packed = self._to_device(ops.pack_bytes_np(surviving))
        out = self.decode(packed, surviving_roles)
        return ops.unpack_bytes_np(self.materialize(out))

    def encode_np(self, data: np.ndarray) -> np.ndarray:
        if not self.scheme.m:
            return np.zeros((0, data.shape[1]), np.uint8)
        packed = self._to_device(ops.pack_bytes_np(data))
        out = self.encode(packed)
        return ops.unpack_bytes_np(self.materialize(out)).reshape(self.scheme.m, -1)

    @staticmethod
    def _pad_batch(data: np.ndarray) -> tuple[np.ndarray, int]:
        """Pad the stripe dim to the next power of two (zero stripes).

        Partial groups (flush, segment tail) would otherwise compile a fresh
        XLA executable per distinct S; bucketing to powers of two bounds the
        shape universe at log2(G) variants so steady state never recompiles.
        Zero padding is exact: every scheme's codec is stripe-independent.
        """
        s_count = data.shape[0]
        target = 1 << max(0, (s_count - 1).bit_length())
        if target != s_count:
            data = np.concatenate(
                [data, np.zeros((target - s_count, *data.shape[1:]), data.dtype)]
            )
        return data, s_count

    def encode_batch_np(self, data: np.ndarray) -> np.ndarray:
        """(S, k, n_bytes) uint8 -> (S, m, n_bytes) parity, one pack/unpack
        round-trip and one fused kernel call for the whole batch."""
        s_count, _, n_bytes = data.shape
        if self.scheme.m == 0:
            return np.zeros((s_count, 0, n_bytes), np.uint8)
        out_dev = self.encode_batch_async(
            ops.pack_bytes_np(self._pad_batch(np.ascontiguousarray(data))[0])
        )
        return ops.unpack_bytes_np(self.materialize(out_dev))[:s_count]

    def decode_batch_np(
        self, surviving: np.ndarray, surviving_roles: tuple[int, ...]
    ) -> np.ndarray:
        """(S, k, n_bytes) uint8 survivors -> (S, k, n_bytes) data."""
        s_count = surviving.shape[0]
        out_dev = self.decode_batch_async(
            ops.pack_bytes_np(self._pad_batch(np.ascontiguousarray(surviving))[0]),
            surviving_roles,
        )
        return ops.unpack_bytes_np(self.materialize(out_dev))[:s_count]

    # -- device-resident group entry points (donated buffers, async) ---------

    def encode_batch_async(self, packed_np: np.ndarray) -> jnp.ndarray:
        """Dispatch a fused group encode and return the device array.

        ``packed_np`` is an int32-packed (S, k, n_i32) host buffer the caller
        relinquishes (typically a fresh arena gather, already power-of-two
        bucketed); it is copied to the device once and the device buffer is
        *donated* to the kernel, so steady-state group commits reuse the same
        allocation instead of growing a fresh one per group.  The returned
        array is not materialized -- JAX async dispatch lets the encode run
        while the caller commits the previous group; sync via
        :meth:`materialize`."""
        s = self.scheme
        assert packed_np.ndim == 3 and packed_np.shape[1] == s.k, packed_np.shape
        packed = self._to_device(packed_np)
        if s.m == 0:
            return jnp.zeros((packed.shape[0], 0, packed.shape[2]), jnp.int32)
        if s.mirror:
            return packed
        with ops.quiet_donation():
            if s.m == 1:
                p = ops.xor_parity_batch_device(
                    packed, use_pallas=self.use_pallas, interpret=self.interpret
                )
                return p[:, None, :]
            return ops.rs_encode_batch_device(
                packed, s.m, use_pallas=self.use_pallas, interpret=self.interpret
            )

    def decode_batch_async(
        self, packed_np: np.ndarray, surviving_roles: tuple[int, ...]
    ) -> jnp.ndarray:
        """Donating, async variant of :meth:`decode_batch` (see above)."""
        s = self.scheme
        roles = tuple(surviving_roles)
        if s.m == 0:
            raise ValueError("RAID-0 cannot decode lost chunks")
        packed = self._to_device(packed_np)
        if s.mirror:
            return self.decode_batch(packed, roles)
        if len(roles) != s.k:
            raise ValueError(f"need exactly k={s.k} surviving rows, got {len(roles)}")
        if set(roles) == set(range(s.k)):
            order = [roles.index(i) for i in range(s.k)]
            return packed[:, jnp.array(order)]
        with ops.quiet_donation():
            if s.m == 1:
                lost = set(range(s.k)) - set(roles)
                lost_role = lost.pop()
                # slice the survivor columns out *before* the donating call:
                # the donated buffer is dead the moment the kernel takes it
                cols = {
                    role: packed[:, i] for i, role in enumerate(roles) if role < s.k
                }
                cols[lost_role] = ops.xor_parity_batch_device(
                    packed, use_pallas=self.use_pallas, interpret=self.interpret
                )
                return jnp.stack([cols[i] for i in range(s.k)], axis=1)
            return ops.rs_decode_batch_device(
                packed, roles, s.k, s.m,
                use_pallas=self.use_pallas, interpret=self.interpret,
            )


def _meta_rows(lbas: np.ndarray, ts: np.ndarray) -> np.ndarray:
    """(rows, c) u64 LBAs + (rows, c) u64 timestamps -> (rows, 16c) bytes."""
    rows = lbas.shape[0]
    return np.concatenate(
        [
            np.ascontiguousarray(lbas.astype(np.uint64)).view(np.uint8).reshape(rows, -1),
            np.ascontiguousarray(ts.astype(np.uint64)).view(np.uint8).reshape(rows, -1),
        ],
        axis=1,
    )


def _meta_unrows(raw: np.ndarray, c: int) -> tuple[np.ndarray, np.ndarray]:
    rows = raw.shape[0]
    lbas = np.ascontiguousarray(raw[:, : 8 * c]).view(np.uint64).reshape(rows, c)
    ts = np.ascontiguousarray(raw[:, 8 * c :]).view(np.uint64).reshape(rows, c)
    return lbas, ts


def parity_oob(
    codec: "StripeCodec", data_lbas: np.ndarray, data_ts: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Paper §3.1: parity blocks carry parity-based redundancy of the data
    blocks' LBAs and timestamps (the stripe id is replicated separately).

    We encode the metadata with the *same* erasure code as the payload, so
    metadata survives exactly the failures the payload survives (XOR for
    m=1, RS for m=2, copies for mirrors)."""
    c = data_lbas.shape[1]
    rows = _meta_rows(data_lbas, data_ts)
    enc = codec.encode_np(rows)
    return _meta_unrows(enc, c)


def _meta_rows_batch(lbas: np.ndarray, ts: np.ndarray) -> np.ndarray:
    """(S, rows, c) u64 LBAs + timestamps -> (S, rows, 16c) bytes."""
    s, rows, c = lbas.shape
    return np.concatenate(
        [
            np.ascontiguousarray(lbas.astype(np.uint64)).view(np.uint8).reshape(s, rows, -1),
            np.ascontiguousarray(ts.astype(np.uint64)).view(np.uint8).reshape(s, rows, -1),
        ],
        axis=2,
    )


def _meta_unrows_batch(raw: np.ndarray, c: int) -> tuple[np.ndarray, np.ndarray]:
    s, rows = raw.shape[0], raw.shape[1]
    lbas = np.ascontiguousarray(raw[:, :, : 8 * c]).view(np.uint64).reshape(s, rows, c)
    ts = np.ascontiguousarray(raw[:, :, 8 * c :]).view(np.uint64).reshape(s, rows, c)
    return lbas, ts


def parity_oob_batch(
    codec: "StripeCodec", data_lbas: np.ndarray, data_ts: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Batched ``parity_oob``: (S, k, c) metadata -> (S, m, c) parity metadata
    in one fused encode (bit-identical to the per-stripe path)."""
    c = data_lbas.shape[2]
    rows = _meta_rows_batch(data_lbas, data_ts)
    enc = codec.encode_batch_np(rows)
    return _meta_unrows_batch(enc, c)


def decode_meta_batch(
    codec: "StripeCodec",
    surviving_lbas: np.ndarray,
    surviving_ts: np.ndarray,
    surviving_roles: tuple[int, ...],
) -> tuple[np.ndarray, np.ndarray]:
    """Batched ``decode_meta``: (S, k, c) surviving metadata rows sharing one
    role set -> all S stripes' (k, c) data metadata in one fused decode."""
    c = surviving_lbas.shape[2]
    rows = _meta_rows_batch(surviving_lbas, surviving_ts)
    dec = codec.decode_batch_np(rows, surviving_roles)
    return _meta_unrows_batch(dec.reshape(rows.shape[0], codec.scheme.k, -1), c)


def decode_meta(
    codec: "StripeCodec",
    surviving_lbas: np.ndarray,
    surviving_ts: np.ndarray,
    surviving_roles: tuple[int, ...],
) -> tuple[np.ndarray, np.ndarray]:
    """Reconstruct all k data rows' (lba, ts) metadata from k survivors."""
    c = surviving_lbas.shape[1]
    rows = _meta_rows(surviving_lbas, surviving_ts)
    dec = codec.decode_np(rows, surviving_roles)
    return _meta_unrows(dec.reshape(codec.scheme.k, -1), c)


def gf_coeff_matrix(k: int, m: int) -> np.ndarray:
    return gf.rs_parity_matrix(k, m)
