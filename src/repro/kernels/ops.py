"""Public jit'd entry points for the kernels package.

Every op takes ``use_pallas``/``interpret`` switches so the same call site
serves three modes:

* ``use_pallas=False``   -> pure-jnp oracle (CPU datapath, autodiff-safe)
* ``use_pallas=True, interpret=True``  -> Pallas kernel body on CPU (tests)
* ``use_pallas=True, interpret=False`` -> compiled TPU kernel (production)

Byte-level helpers convert between uint8 chunk buffers and the int32-packed
lanes the kernels consume.
"""
from __future__ import annotations

import contextlib
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gf
from repro.kernels import ref
from repro.kernels.gf256_matmul import gf256_matmul, gf256_matmul_batch
from repro.kernels.parity_xor import parity_xor, parity_xor_batch
from repro.kernels.ssd_scan import ssd_scan


@functools.lru_cache(maxsize=None)
def rs_parity_coeff(k: int, m: int) -> jax.Array:
    """Device-resident (m, k) RS parity matrix, cached per (k, m).

    The matrices are tiny but rebuilding + re-transferring them on every
    encode forces a host->device pack and a retrace; caching the packed
    int32 array makes repeat encodes hit the jit cache directly.
    """
    return jnp.asarray(gf.rs_parity_matrix(k, m), jnp.int32)


@functools.lru_cache(maxsize=None)
def rs_decode_coeff(k: int, m: int, surviving: tuple[int, ...]) -> jax.Array:
    """Device-resident (k, k) RS decode matrix, cached per survivor set."""
    return jnp.asarray(gf.rs_decode_matrix(k, m, surviving), jnp.int32)


def pack_bytes(data_u8: jax.Array) -> jax.Array:
    """(..., 4*n) uint8 -> (..., n) int32 little-endian lane packing."""
    assert data_u8.shape[-1] % 4 == 0
    return jax.lax.bitcast_convert_type(
        data_u8.reshape(*data_u8.shape[:-1], -1, 4), jnp.int32
    )


def unpack_bytes(data_i32: jax.Array) -> jax.Array:
    """(..., n) int32 -> (..., 4*n) uint8."""
    u8 = jax.lax.bitcast_convert_type(data_i32, jnp.uint8)
    return u8.reshape(*data_i32.shape[:-1], -1)


def _pad_lanes(x: jax.Array) -> tuple[jax.Array, int]:
    """Pad the lane dim up to a multiple of 128 (TPU lane width)."""
    n = x.shape[-1]
    pad = (-n) % 128
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, n


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def xor_parity(
    chunks_i32: jax.Array, *, use_pallas: bool = True, interpret: bool = True
) -> jax.Array:
    """XOR parity of (k, n) int32 -> (n,) int32."""
    if use_pallas:
        padded, n = _pad_lanes(chunks_i32)
        return parity_xor(padded, interpret=interpret)[:n]
    return ref.parity_xor_ref(chunks_i32)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def rs_matmul(
    coeff_i32: jax.Array,
    chunks_i32: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """GF(256) (m,k) x (k,n) -> (m,n) on int32-packed bytes."""
    if use_pallas:
        padded, n = _pad_lanes(chunks_i32)
        return gf256_matmul(coeff_i32, padded, interpret=interpret)[:, :n]
    return ref.gf256_matmul_ref(coeff_i32, chunks_i32)


def rs_encode(
    chunks_i32: jax.Array,
    m: int,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Encode (k, n) data chunks into (m, n) RS parity chunks."""
    k = chunks_i32.shape[0]
    coeff = rs_parity_coeff(k, m)
    return rs_matmul(coeff, chunks_i32, use_pallas=use_pallas, interpret=interpret)


def rs_decode(
    surviving_i32: jax.Array,
    surviving_rows: tuple[int, ...],
    k: int,
    m: int,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Reconstruct the k data chunks from any k surviving codeword rows."""
    dec = rs_decode_coeff(k, m, tuple(surviving_rows))
    return rs_matmul(dec, surviving_i32, use_pallas=use_pallas, interpret=interpret)


# ------------------------------------------------------- batched (group) ops

@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def xor_parity_batch(
    chunks_i32: jax.Array, *, use_pallas: bool = True, interpret: bool = True
) -> jax.Array:
    """XOR parity for a whole stripe group: (S, k, n) int32 -> (S, n) int32."""
    if use_pallas:
        padded, n = _pad_lanes(chunks_i32)
        return parity_xor_batch(padded, interpret=interpret)[:, :n]
    return ref.parity_xor_batch_ref(chunks_i32)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def rs_matmul_batch(
    coeff_i32: jax.Array,
    chunks_i32: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """GF(256) (m,k) x (S,k,n) -> (S,m,n) on int32-packed bytes."""
    if use_pallas:
        padded, n = _pad_lanes(chunks_i32)
        return gf256_matmul_batch(coeff_i32, padded, interpret=interpret)[:, :, :n]
    return ref.gf256_matmul_batch_ref(coeff_i32, chunks_i32)


def rs_encode_batch(
    chunks_i32: jax.Array,
    m: int,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Encode (S, k, n) stripes into (S, m, n) RS parity in one fused call."""
    k = chunks_i32.shape[1]
    coeff = rs_parity_coeff(k, m)
    return rs_matmul_batch(
        coeff, chunks_i32, use_pallas=use_pallas, interpret=interpret
    )


def rs_decode_batch(
    surviving_i32: jax.Array,
    surviving_rows: tuple[int, ...],
    k: int,
    m: int,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Reconstruct (S, k, n) data from (S, k, n) survivors sharing one role set."""
    dec = rs_decode_coeff(k, m, tuple(surviving_rows))
    return rs_matmul_batch(
        dec, surviving_i32, use_pallas=use_pallas, interpret=interpret
    )


# -------------------------------------------- device-resident (donated) ops
#
# Entry points for the zero-copy group datapath: the caller hands over a
# packed int32 device buffer it will never touch again (the staging arena's
# per-group gather), so the input buffer is donated to XLA and the dispatch
# returns immediately (JAX async dispatch).  The group committer materializes
# the result with one np.asarray at the commit sync point.
#
# Donation is best-effort: when the output shape differs from the input's
# (encode maps k rows to m), XLA reports the buffer as unusable at compile
# time.  That is expected -- the donation still pays off on the square decode
# matmuls -- so the advisory compile-time warning is silenced at the call
# sites (a module-level filter would not survive pytest's warning capture).

@contextlib.contextmanager
def quiet_donation():
    """Context silencing XLA's advisory unusable-donation compile warning."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable",
            category=UserWarning,
        )
        yield


@functools.partial(
    jax.jit, static_argnames=("use_pallas", "interpret"), donate_argnums=(0,)
)
def xor_parity_batch_device(
    chunks_i32: jax.Array, *, use_pallas: bool = True, interpret: bool = True
) -> jax.Array:
    """Donating ``xor_parity_batch``: (S, k, n) int32 -> (S, n) int32."""
    if use_pallas:
        padded, n = _pad_lanes(chunks_i32)
        return parity_xor_batch(padded, interpret=interpret)[:, :n]
    return ref.parity_xor_batch_ref(chunks_i32)


@functools.partial(
    jax.jit, static_argnames=("use_pallas", "interpret"), donate_argnums=(1,)
)
def rs_matmul_batch_device(
    coeff_i32: jax.Array,
    chunks_i32: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Donating ``rs_matmul_batch``: coeff kept, stripe buffer donated."""
    if use_pallas:
        padded, n = _pad_lanes(chunks_i32)
        return gf256_matmul_batch(coeff_i32, padded, interpret=interpret)[:, :, :n]
    return ref.gf256_matmul_batch_ref(coeff_i32, chunks_i32)


def rs_encode_batch_device(
    chunks_i32: jax.Array,
    m: int,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Donating ``rs_encode_batch`` (cached coeff matrix, donated stripes)."""
    k = chunks_i32.shape[1]
    coeff = rs_parity_coeff(k, m)
    return rs_matmul_batch_device(
        coeff, chunks_i32, use_pallas=use_pallas, interpret=interpret
    )


def rs_decode_batch_device(
    surviving_i32: jax.Array,
    surviving_rows: tuple[int, ...],
    k: int,
    m: int,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Donating ``rs_decode_batch`` (cached decode matrix, donated survivors)."""
    dec = rs_decode_coeff(k, m, tuple(surviving_rows))
    return rs_matmul_batch_device(
        dec, surviving_i32, use_pallas=use_pallas, interpret=interpret
    )


def pack_bytes_np(data_u8: np.ndarray) -> np.ndarray:
    """Host-side ``pack_bytes``: a free dtype view, no device dispatch.

    numpy's in-memory byte order equals ``jax.lax.bitcast_convert_type``'s
    lane packing, so viewing a C-contiguous uint8 buffer as int32 produces
    bit-identical lanes to :func:`pack_bytes` without entering the device."""
    assert data_u8.shape[-1] % 4 == 0
    data_u8 = np.ascontiguousarray(data_u8)
    return data_u8.view(np.int32)


def unpack_bytes_np(data_i32: np.ndarray) -> np.ndarray:
    """Host-side ``unpack_bytes``: a free dtype view of an int32 buffer."""
    return np.ascontiguousarray(data_i32).view(np.uint8)


def ssd_chunk_scan(
    x, dt, a, b, c, h0=None, *, chunk: int = 128,
    use_pallas: bool = True, interpret: bool = True,
):
    """Mamba-2 SSD scan; see kernels/ssd_scan.py.  Returns (y, h_final)."""
    if use_pallas:
        return ssd_scan(x, dt, a, b, c, h0, chunk=chunk, interpret=interpret)
    return ref.ssd_scan_ref(x, dt, a, b, c, h0)
