"""Pallas TPU kernel: XOR parity over k data chunks.

RAID-4/5 parity (and the XOR half of RAID-6) is a pure bandwidth problem:
read k chunks, write one.  On TPU the chunk bytes are bitcast to int32 lanes
and XOR-reduced on the VPU.  The kernel tiles the chunk dimension into
VMEM-resident blocks of (k, BLOCK_N) so each grid step streams k*BLOCK_N*4
bytes HBM->VMEM, XORs in-register, and writes BLOCK_N*4 bytes back -- the
roofline is HBM bandwidth and the kernel is a single pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 2048  # int32 lanes per grid step (8 KiB per input row)


def _parity_xor_kernel(x_ref, o_ref):
    x = x_ref[...]  # (k, bn) int32
    o_ref[...] = jax.lax.reduce(
        x, jnp.int32(0), jax.lax.bitwise_xor, dimensions=(0,)
    )[None, :]


def _parity_xor_batch_kernel(x_ref, o_ref):
    x = x_ref[...]  # (1, k, bn) int32
    o_ref[...] = jax.lax.reduce(
        x, jnp.int32(0), jax.lax.bitwise_xor, dimensions=(1,)
    )[:, None, :]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def parity_xor_batch(
    data: jax.Array, *, block_n: int = DEFAULT_BLOCK_N, interpret: bool = True
) -> jax.Array:
    """XOR-reduce a whole stripe group: (S, k, n) int32 -> (S, n) int32.

    One ``pallas_call`` over a 2-D (stripe, lane-tile) grid replaces S
    per-stripe dispatches: grid step (i, j) streams stripe i's (k, bn) tile
    through VMEM exactly like the single-stripe kernel, so the HBM-bandwidth
    roofline is unchanged while the dispatch cost is paid once per group.
    """
    s, k, n = data.shape
    bn = min(block_n, n)
    assert n % bn == 0 and bn % 128 == 0, (n, bn)
    out = pl.pallas_call(
        _parity_xor_batch_kernel,
        grid=(s, n // bn),
        in_specs=[pl.BlockSpec((1, k, bn), lambda i, j: (i, 0, j))],
        out_specs=pl.BlockSpec((1, 1, bn), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((s, 1, n), jnp.int32),
        interpret=interpret,
    )(data)
    return out[:, 0]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def parity_xor(
    data: jax.Array, *, block_n: int = DEFAULT_BLOCK_N, interpret: bool = True
) -> jax.Array:
    """XOR-reduce (k, n) int32 -> (n,) int32 via Pallas.

    ``n`` must be a multiple of 128 (TPU lane width); ``block_n`` is clamped
    to n.  ``interpret=True`` runs the kernel body on CPU for validation; on
    real TPU pass interpret=False.
    """
    k, n = data.shape
    bn = min(block_n, n)
    assert n % bn == 0 and bn % 128 == 0, (n, bn)
    out = pl.pallas_call(
        _parity_xor_kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((k, bn), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
        interpret=interpret,
    )(data)
    return out[0]
