"""Pallas TPU kernel: GF(256) matrix-multiply for Reed-Solomon coding.

Computes P = M (*) D where M is an (m, k) GF(256) coefficient matrix and D is
(k, n) data with 4 GF bytes packed per int32 lane.  Used for:

* RS encode (M = parity rows of the systematic generator, m small),
* RS decode / degraded read (M = rows of the inverted surviving submatrix).

TPU adaptation: GPU erasure coders use 256-byte log/exp gather tables in
shared memory; gathers are poison for the TPU VPU, so instead the kernel uses
a branchless SWAR double-and-add -- 8 static steps of shift/mask/xor per
coefficient, all (8,128)-shaped VPU ops, no table lookups.  The coefficient
matrix is tiny and is broadcast to every grid step; the data streams through
VMEM in (k, BLOCK_N) tiles.  Arithmetic intensity is ~8k VPU ops per 4k bytes,
so the kernel stays bandwidth-bound like the XOR kernel (within ~1.3x).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gf import swar_gf_scale

DEFAULT_BLOCK_N = 2048


def _make_kernel(m: int, k: int):
    def kernel(coeff_ref, d_ref, o_ref):
        d = d_ref[...]  # (k, bn) int32
        coeff = coeff_ref[...]  # (m, k) int32
        for j in range(m):
            acc = jnp.zeros_like(d[0])
            for i in range(k):
                acc = acc ^ swar_gf_scale(d[i], coeff[j, i])
            o_ref[j, :] = acc

    return kernel


def _make_batch_kernel(m: int, k: int):
    def kernel(coeff_ref, d_ref, o_ref):
        d = d_ref[0]  # (k, bn) int32 -- one stripe's tile
        coeff = coeff_ref[...]  # (m, k) int32
        for j in range(m):
            acc = jnp.zeros_like(d[0])
            for i in range(k):
                acc = acc ^ swar_gf_scale(d[i], coeff[j, i])
            o_ref[0, j, :] = acc

    return kernel


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gf256_matmul_batch(
    coeff: jax.Array,
    data: jax.Array,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
) -> jax.Array:
    """(m, k) GF coeffs x (S, k, n) packed int32 -> (S, m, n) packed int32.

    Batched variant for whole stripe groups: a 2-D (stripe, lane-tile) grid
    runs the same SWAR double-and-add body per tile, with the tiny coefficient
    matrix broadcast to every grid step, so one ``pallas_call`` encodes (or
    decodes) all S stripes instead of S dispatches.
    """
    m, k = coeff.shape
    s, k2, n = data.shape
    assert k == k2, (coeff.shape, data.shape)
    bn = min(block_n, n)
    assert n % bn == 0 and bn % 128 == 0, (n, bn)
    return pl.pallas_call(
        _make_batch_kernel(m, k),
        grid=(s, n // bn),
        in_specs=[
            pl.BlockSpec((m, k), lambda i, j: (0, 0)),
            pl.BlockSpec((1, k, bn), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, m, bn), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((s, m, n), jnp.int32),
        interpret=interpret,
    )(coeff.astype(jnp.int32), data)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gf256_matmul(
    coeff: jax.Array,
    data: jax.Array,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
) -> jax.Array:
    """(m, k) GF coeffs x (k, n) packed int32 -> (m, n) packed int32."""
    m, k = coeff.shape
    k2, n = data.shape
    assert k == k2, (coeff.shape, data.shape)
    bn = min(block_n, n)
    assert n % bn == 0 and bn % 128 == 0, (n, bn)
    return pl.pallas_call(
        _make_kernel(m, k),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((k, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(coeff.astype(jnp.int32), data)
