"""Pallas TPU kernel: chunked Mamba-2 SSD (state-space duality) scan.

The SSD recurrence  h_t = exp(dt_t*a) h_{t-1} + dt_t (b_t (x) x_t),
y_t = c_t . h_t  is the compute hot-spot of the mamba2/zamba2 architectures.
A naive scan is latency-bound (T sequential steps of rank-1 updates); the SSD
blocked form turns it into MXU work: the sequence is cut into chunks of Q
tokens, each chunk does three (Q,Q)/(Q,N)/(Q,P) matmuls (intra-chunk), and a
single (N,P) state carries between chunks.

TPU mapping: grid = (BH, T//Q) with both dims sequential (TPU grid order is
row-major), so the chunk axis iterates innermost and the inter-chunk state
lives in a VMEM scratch buffer that persists across grid steps -- the same
accumulator-carry pattern as Pallas flash attention.  All tiles are MXU
aligned for the production sizes (Q=128, P=64/128, N=64/128); decay masks are
built from 2-D iotas (TPU requires >=2-D iota).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref, h):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)    # (q, p)
    dt = dt_ref[0].astype(jnp.float32)  # (q,)
    a = a_ref[0, 0].astype(jnp.float32)
    b = b_ref[0].astype(jnp.float32)    # (q, n)
    c = c_ref[0].astype(jnp.float32)    # (q, n)
    q = x.shape[0]

    la = dt * a                        # (q,) log-decay per step (<= 0)
    s = jnp.cumsum(la)                 # inclusive cumulative log-decay
    # Lower-triangular decay kernel L[t, j] = exp(s_t - s_j), t >= j.
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    l_mat = jnp.where(row >= col, jnp.exp(s[:, None] - s[None, :]), 0.0)

    h_prev = h[...]                    # (n, p)
    # Intra-chunk: (L . (C B^T)) @ (dt * X)
    cbt = jnp.dot(c, b.T, preferred_element_type=jnp.float32)   # (q, q)
    y_intra = jnp.dot(l_mat * cbt, dt[:, None] * x,
                      preferred_element_type=jnp.float32)       # (q, p)
    # Inter-chunk: exp(s_t) * (C @ h_prev)
    y_inter = jnp.exp(s)[:, None] * jnp.dot(
        c, h_prev, preferred_element_type=jnp.float32)          # (q, p)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # State update: h = exp(s_last) h_prev + sum_j exp(s_last - s_j) dt_j b_j x_j
    w = dt * jnp.exp(s[-1] - s)        # (q,)
    h_new = jnp.exp(s[-1]) * h_prev + jnp.dot(
        b.T * w[None, :], x, preferred_element_type=jnp.float32)  # (n, p)
    h[...] = h_new
    hout_ref[0] = h_new.astype(hout_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret")
)
def ssd_scan(
    x: jax.Array,   # (bh, t, p)
    dt: jax.Array,  # (bh, t)
    a: jax.Array,   # (bh,)
    b: jax.Array,   # (bh, t, n)
    c: jax.Array,   # (bh, t, n)
    h0: jax.Array | None = None,  # (bh, n, p)
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Blocked SSD scan; returns (y (bh,t,p) f32, h_final (bh,n,p) f32)."""
    bh, t, p = x.shape
    n = b.shape[-1]
    q = min(chunk, t)
    assert t % q == 0, (t, q)
    if h0 is None:
        h0 = jnp.zeros((bh, n, p), jnp.float32)
    grid = (bh, t // q)
    y, h_final = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, q), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, q, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, q, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, n, p), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, n, p), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, p), jnp.float32),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, a[:, None], b, c, h0)
    return y, h_final
