"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: each kernel's test sweeps shapes and
dtypes and asserts allclose/array_equal against the function here.  They are
also the CPU fallback datapath used by the storage simulator when Pallas is
not requested.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gf


def parity_xor_ref(data: jax.Array) -> jax.Array:
    """XOR-reduce ``data`` of shape (k, n) int32 -> (n,) int32."""
    return jax.lax.reduce(
        data, jnp.int32(0), jax.lax.bitwise_xor, dimensions=(0,)
    )


def gf256_matmul_ref(coeff: jax.Array, data: jax.Array) -> jax.Array:
    """GF(256) matmul on int32-packed bytes.

    coeff: (m, k) int32 with values in [0, 256) -- GF coefficients.
    data:  (k, n) int32, each int32 packing 4 independent GF(256) bytes.
    returns (m, n) int32 packed the same way.
    """
    m, k = coeff.shape

    def one_row(j):
        acc = jnp.zeros(data.shape[1:], jnp.int32)
        for i in range(k):
            acc = acc ^ gf.swar_gf_scale(data[i], coeff[j, i])
        return acc

    return jnp.stack([one_row(j) for j in range(m)], axis=0)


def parity_xor_batch_ref(data: jax.Array) -> jax.Array:
    """XOR-reduce ``data`` of shape (S, k, n) int32 -> (S, n) int32."""
    return jax.lax.reduce(
        data, jnp.int32(0), jax.lax.bitwise_xor, dimensions=(1,)
    )


def gf256_matmul_batch_ref(coeff: jax.Array, data: jax.Array) -> jax.Array:
    """Batched GF(256) matmul: (m, k) coeffs x (S, k, n) -> (S, m, n)."""
    return jax.vmap(lambda d: gf256_matmul_ref(coeff, d))(data)


def ssd_scan_ref(
    x: jax.Array,      # (bh, t, p)   values (already multiplied by nothing)
    dt: jax.Array,     # (bh, t)      softplus'd step sizes (>0)
    a: jax.Array,      # (bh,)        per-head negative decay rate (A < 0)
    b: jax.Array,      # (bh, t, n)   input->state projection
    c: jax.Array,      # (bh, t, n)   state->output projection
    h0: jax.Array | None = None,  # (bh, n, p) initial state
) -> tuple[jax.Array, jax.Array]:
    """Sequential reference for the Mamba-2 SSD recurrence.

    h_t = exp(dt_t * a) * h_{t-1} + dt_t * (b_t outer x_t)
    y_t = c_t @ h_t
    Returns (y, h_final): y (bh, t, p), h_final (bh, n, p).
    All math in float32.
    """
    bh, t, p = x.shape
    n = b.shape[-1]
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    c = c.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((bh, n, p), jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp  # (bh,p),(bh,),(bh,n),(bh,n)
        decay = jnp.exp(dt_t * a)[:, None, None]  # (bh,1,1)
        h = decay * h + dt_t[:, None, None] * (b_t[:, :, None] * x_t[:, None, :])
        y_t = jnp.einsum("bn,bnp->bp", c_t, h)
        return h, y_t

    inps = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(b, 1, 0),
        jnp.moveaxis(c, 1, 0),
    )
    h_final, ys = jax.lax.scan(step, h0, inps)
    return jnp.moveaxis(ys, 0, 1), h_final


def gf256_matmul_np(coeff: np.ndarray, data_bytes: np.ndarray) -> np.ndarray:
    """Host oracle on raw uint8 (table based), for cross-checking the SWAR path."""
    return gf.gf_matmul_np(coeff.astype(np.uint8), data_bytes)
