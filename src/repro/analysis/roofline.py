"""Roofline analysis from compiled dry-run artifacts.

Inputs: the SPMD-partitioned HLO text (per-device program) plus
``compiled.cost_analysis()``.  Outputs the three roofline terms for TPU v5e:

  compute term    = per-device FLOPs / 197 TF/s (bf16)
  memory term     = per-device HBM bytes / 819 GB/s
  collective term = per-device wire time over 50 GB/s/link ICI

XLA's HloCostAnalysis does NOT multiply ``while`` bodies by their trip
counts (a scan-over-layers model would undercount by n_layers), so this
module re-derives FLOPs and collective bytes directly from the HLO text:

* each computation's *execution multiplier* is propagated through the call
  graph (while bodies multiply by the loop trip count recovered from the
  loop condition's comparison constant);
* FLOPs: every ``dot`` contributes 2 * prod(result_shape) * K (K = product
  of lhs contracting dim sizes), times its computation's multiplier;
* collective wire time uses ring costs:
    all-reduce       2 * B * (S-1)/S
    all-gather       B_out * (S-1)/S
    reduce-scatter   B_out * (S-1)
    all-to-all       B * (S-1)/S
    collective-permute  B
  where S is the replica-group size parsed from ``replica_groups``.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

PEAK_FLOPS = 197e12       # bf16 FLOP/s per v5e chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^\s*%?([\w\.\-]+)\s+\([^)]*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\)?, condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:to_apply|calls|condition|body|branch_computations)=\{?%?([\w\.\-, %]+)\}?")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_DOT_RE = re.compile(r"=\s+(?:\()?\s*(?:pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)\[[\d,]*\][^=]*\bdot\(")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]+)\}")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStat:
    op: str
    count: int = 0
    bytes: float = 0.0       # per-device operand bytes (x multipliers)
    wire_bytes: float = 0.0  # per-device wire traffic (ring model)


@dataclasses.dataclass
class RooflineReport:
    flops: float                 # per-device, trip-count adjusted
    hbm_bytes: float             # per-device (cost_analysis or analytic)
    collective_wire_bytes: float
    collective_bytes: float
    collectives: dict
    compute_s: float
    memory_s: float
    collective_s: float
    cost_analysis_flops: float
    cost_analysis_bytes: float

    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    def to_dict(self):
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant()
        return d


def _split_computations(hlo: str) -> tuple[dict[str, list[str]], str]:
    """Split HLO text into computations.  Headers start at column 0 and end
    with '{'; the ENTRY computation is tagged.  Returns (comps, entry)."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        stripped = line.rstrip()
        if not line.startswith((" ", "\t")) and stripped.endswith("{") and "(" in line:
            name = stripped.split("(")[0].strip()
            is_entry = name.startswith("ENTRY")
            name = name.replace("ENTRY", "").strip().lstrip("%").strip()
            cur = name
            comps[cur] = []
            if is_entry:
                entry = name
            continue
        if stripped == "}" or stripped.startswith("} "):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps, entry


def _symbol_shapes(lines: list[str]) -> dict[str, list[int]]:
    """instruction name -> result dims (first shape literal after '=')."""
    table: dict[str, list[int]] = {}
    for line in lines:
        m = re.match(r"\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)", line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        sm = _SHAPE_RE.search(rest.split("(")[0] + "(")
        sm = _SHAPE_RE.search(rest)
        if sm:
            dims = [int(x) for x in sm.group(2).split(",") if x]
            table[name] = dims or [1]
    return table


def _trip_count(cond_lines: list[str]) -> int:
    """Heuristic: the largest s32 constant in the loop condition."""
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def _computation_multipliers(
    comps: dict[str, list[str]], entry: Optional[str]
) -> dict[str, float]:
    """Execution count per computation (while bodies x trip counts)."""
    mult = {name: 0.0 for name in comps}
    if entry is None or entry not in comps:
        entry = next(
            (n for n in comps if n.startswith("main")), next(iter(comps))
        )
    mult[entry] = 1.0

    # iterate to fixpoint over the call graph (shallow nesting in practice)
    for _ in range(12):
        changed = False
        new_mult = dict(mult)
        for name, lines in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for line in lines:
                wm = _WHILE_RE.search(line)
                if wm:
                    cond, body = wm.group(1), wm.group(2)
                    trips = _trip_count(comps.get(cond, []))
                    for target, factor in ((cond, trips + 1), (body, trips)):
                        want = m * factor
                        if target in comps and new_mult.get(target, 0.0) < want:
                            new_mult[target] = want
                            changed = True
                    continue
                for cm in re.finditer(r"(?:to_apply|calls)=\{?%?([\w\.\-]+)", line):
                    target = cm.group(1)
                    if target in comps and new_mult.get(target, 0.0) < m:
                        new_mult[target] = m
                        changed = True
                for cm in re.finditer(
                    r"(?:true_computation|false_computation|branch_computations)=\{?%?([\w\.\-,% ]+)\}?",
                    line,
                ):
                    for t in re.split(r"[,\s%]+", cm.group(1)):
                        if t in comps and new_mult.get(t, 0.0) < m:
                            new_mult[t] = m
                            changed = True
        mult = new_mult
        if not changed:
            break
    return mult


def _dot_flops(line: str, symbols: dict[str, list[int]]) -> float:
    sm = _SHAPE_RE.search(line)
    if not sm:
        return 0.0
    res = [int(x) for x in sm.group(2).split(",") if x] or [1]
    # lhs operand: first name inside dot(...)
    dm = re.search(r"\bdot\(\s*%?([\w\.\-]+)", line)
    k = 1
    if dm:
        lhs = symbols.get(dm.group(1))
        cm = _CONTRACT_RE.search(line)
        if lhs and cm:
            for idx in cm.group(1).split(","):
                i = int(idx)
                if i < len(lhs):
                    k *= lhs[i]
        elif lhs:
            k = lhs[-1]  # default contraction on last dim
    return 2.0 * math.prod(res) * k


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST.search(line)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def analyze_hlo(
    hlo: str,
    *,
    n_devices: int,
    cost_analysis: Optional[dict] = None,
    analytic_hbm_bytes: Optional[float] = None,
) -> RooflineReport:
    comps, entry = _split_computations(hlo)
    mult = _computation_multipliers(comps, entry)

    flops = 0.0
    colls: dict[str, CollectiveStat] = {}
    wire_total = 0.0
    bytes_total = 0.0
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        symbols = _symbol_shapes(lines)
        for line in lines:
            if " dot(" in line:
                flops += m * _dot_flops(line, symbols)
                continue
            for op in COLLECTIVES:
                if f" {op}(" in line or f" {op}-start(" in line or f" {op}-done(" in line:
                    if f" {op}-done(" in line:
                        break  # counted at -start
                    # result shape(s) = everything between '=' and the op name
                    head = line.split(f"{op}(")[0].split(f"{op}-start(")[0]
                    head = head.split("=", 1)[-1]
                    shapes = _SHAPE_RE.findall(head)
                    b = sum(shape_bytes(dt, dims) for dt, dims in shapes)
                    s = _group_size(line, n_devices)
                    if s <= 1:
                        break
                    if op == "all-reduce":
                        wire = 2.0 * b * (s - 1) / s
                    elif op == "all-gather":
                        wire = b * (s - 1) / s
                    elif op == "reduce-scatter":
                        wire = b * (s - 1)
                    elif op == "all-to-all":
                        wire = b * (s - 1) / s
                    else:  # collective-permute
                        wire = b
                    st = colls.setdefault(op, CollectiveStat(op))
                    st.count += int(m)
                    st.bytes += m * b
                    st.wire_bytes += m * wire
                    wire_total += m * wire
                    bytes_total += m * b
                    break

    ca_flops = float(cost_analysis.get("flops", 0.0)) if cost_analysis else 0.0
    ca_bytes = float(cost_analysis.get("bytes accessed", 0.0)) if cost_analysis else 0.0
    hbm = max(ca_bytes, analytic_hbm_bytes or 0.0)
    eff_flops = max(flops, ca_flops)
    return RooflineReport(
        flops=eff_flops,
        hbm_bytes=hbm,
        collective_wire_bytes=wire_total,
        collective_bytes=bytes_total,
        collectives={k: dataclasses.asdict(v) for k, v in colls.items()},
        compute_s=eff_flops / PEAK_FLOPS,
        memory_s=hbm / HBM_BW,
        collective_s=wire_total / LINK_BW,
        cost_analysis_flops=ca_flops,
        cost_analysis_bytes=ca_bytes,
    )


def model_flops_per_step(cfg, cell) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens/step.

    For train cells this is fwd+bwd (6ND); prefill is forward-only (2ND);
    decode is 2*N_active per token."""
    n_active = cfg.active_param_count()
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    if cell.kind == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens
