"""AdamW with fp32 master weights and ZeRO-1 optimizer-state sharding.

State per parameter: fp32 master copy + first/second moments.  The state
sharding inherits the parameter's PartitionSpec and, when ZeRO-1 is enabled,
additionally shards the first still-unsharded divisible dimension over the
data axes -- the optimizer-state memory then scales 1/(dp*tp) like
production trainers.

Optional gradient compression (``repro.distributed.compression``) plugs in
between grad and update with an error-feedback residual carried in the
optimizer state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding as sh


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    compression: str = "none"  # none | int8 | topk


def init_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def lr_at(cfg: AdamWConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    return cfg.lr * warm


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    # global-norm clip
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state["step"] + 1
    lr = lr_at(cfg, state["step"])
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mw, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / b1c
        vhat = v2 / b2c
        new_master = mw - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                                + cfg.weight_decay * mw)
        return new_master.astype(p.dtype), new_master, m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mw = jax.tree.leaves(state["master"])
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    outs = [upd(*t) for t in zip(flat_p, flat_g, flat_mw, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_state = {
        "step": step,
        "master": jax.tree.unflatten(tdef, [o[1] for o in outs]),
        "m": jax.tree.unflatten(tdef, [o[2] for o in outs]),
        "v": jax.tree.unflatten(tdef, [o[3] for o in outs]),
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def state_specs(param_spec_tree, param_shapes, mesh: Mesh, *, zero1: bool = True):
    """Optimizer-state PartitionSpecs: inherit the param spec, then ZeRO-1
    shard the first unsharded divisible dim over the data axes."""
    dp = sh.batch_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def one(spec: P, shape_leaf):
        shape = shape_leaf.shape
        parts = list(spec) + [None] * (len(shape) - len(spec))
        if zero1 and dp and not any(
            (p == dp or p in dp or (isinstance(p, tuple) and set(dp) & set(p)))
            for p in parts if p is not None
        ):
            for i, (dim, p) in enumerate(zip(shape, parts)):
                if p is None and dim % dp_size == 0 and dim >= dp_size:
                    parts[i] = dp if len(dp) > 1 else dp[0]
                    break
        return P(*parts)

    leaf_spec = jax.tree.map(
        one, param_spec_tree, param_shapes,
        is_leaf=lambda s: isinstance(s, P),
    )
    return {
        "step": P(),
        "master": leaf_spec,
        "m": leaf_spec,
        "v": leaf_spec,
    }
