"""PaliGemma-3B backbone: gemma decoder with MQA (kv=1); SigLIP vision
frontend is a STUB (input_specs provides patch embeddings).
[arXiv:2407.07726; hf-verified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab=257216, head_dim=256,
    vis_prefix_len=256, vis_embed_dim=1152,
)
