"""Assigned-architecture registry: ``get_config(name)`` / ``ARCHS``."""
from __future__ import annotations

import importlib

ARCHS = [
    "smollm-135m",
    "qwen1.5-110b",
    "qwen2.5-3b",
    "deepseek-7b",
    "mamba2-1.3b",
    "whisper-small",
    "grok-1-314b",
    "llama4-scout-17b-a16e",
    "paligemma-3b",
    "zamba2-2.7b",
]

_MODULES = {name: name.replace("-", "_").replace(".", "_") for name in ARCHS}


def get_config(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs():
    return {name: get_config(name) for name in ARCHS}
