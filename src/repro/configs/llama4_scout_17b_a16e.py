"""Llama-4 Scout 17B-active/16E: top-1 MoE with a shared expert and
chunked local attention (iRoPE); early-fusion frontend stubbed.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048, head_dim=128,
    n_experts=16, top_k=1, moe_every=1, shared_expert_ff=8192,
    attn_chunk=8192,
    fsdp=True,
)
