"""Mamba2-1.3B: attention-free SSD (state-space duality) stack.
[arXiv:2405.21060; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=128,
)
