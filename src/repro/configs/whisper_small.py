"""Whisper-small backbone: 12L encoder + 12L decoder; the audio conv
frontend is a STUB (input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, head_dim=64,
    enc_layers=12, enc_len=1500, tie_embeddings=True,
)
