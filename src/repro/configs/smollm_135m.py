"""SmolLM-135M: llama-architecture small dense LM.
[hf:HuggingFaceTB/SmolLM-135M; hf-verified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
    d_ff=1536, vocab=49152, head_dim=64,
    tie_embeddings=True, rope_theta=10000.0,
)
