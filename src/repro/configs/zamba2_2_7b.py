"""Zamba2-2.7B: Mamba-2 backbone with a shared full-attention block
applied every 6 SSM blocks (simplified from the alternating two-block
scheme; noted in DESIGN.md).
[arXiv:2411.15242; hf-verified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, head_dim=80,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=128,
    shared_attn_every=6,
)
