"""Train / serve step functions (the jit roots for the dry-run and drivers).

``make_train_step``  -> (params, opt_state, batch) -> (params, opt_state, metrics)
``make_prefill_step``-> (params, batch) -> (logits, cache)
``make_decode_step`` -> (params, cache, tokens) -> (logits, cache)

Sharding is supplied by the caller as in/out_shardings on jax.jit; the step
functions are pure and mesh-agnostic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed import compression as comp
from repro.models.model import build_model
from repro.optim import adamw


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig):
    model = build_model(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        if opt_cfg.compression != "none":
            grads, new_resid = comp.apply_compression(
                grads, opt_state["residual"], opt_cfg.compression
            )
        new_params, new_opt, metrics = adamw.apply_updates(
            opt_cfg, params, grads, opt_state
        )
        if opt_cfg.compression != "none":
            new_opt["residual"] = new_resid
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return model, train_step


def init_opt_state(model, params, opt_cfg: adamw.AdamWConfig):
    st = adamw.init_state(params)
    if opt_cfg.compression != "none":
        st["residual"] = comp.init_residual(params)
    return st


def make_prefill_step(cfg):
    model = build_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return model, prefill_step


def make_decode_step(cfg):
    model = build_model(cfg)

    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return model, decode_step
