"""Fault-injection harness: drive fail/replace events on the virtual clock.

Wraps the timed pipeline's failure/rebuild actors in a declarative plan so
tests and benchmarks can inject full-drive failures mid-write, mid-GC, or
mid-rebuild and assert the array stays available throughout:

* :class:`FaultEvent` -- one scheduled ``fail`` or ``rebuild`` (replace +
  reconstruct) of a physical drive;
* :class:`FaultPlan`  -- an ordered script of events.  Build one explicitly
  (:meth:`FaultPlan.scripted`) or sample fail/repair cycles from a seeded
  RNG (:meth:`FaultPlan.probabilistic`);
* :class:`FaultInjector` -- arms a plan on a ``HandlerPipeline``'s engine.
  Every fired event is appended to ``injector.log`` as
  ``(t_us, kind, drive)`` so callers can assert what actually happened and
  correlate it with latency samples.

The injector deliberately reuses the array's own entry points
(``fail_drive`` / ``rebuild_drive`` via the pipeline's rebuild actors), so
an injected failure exercises exactly the degraded-write rotation, paced
reconstruction, and re-widening paths foreground code uses -- nothing is
mocked.  Probabilistic plans serialize fail -> rebuild cycles (one drive
out at a time), which keeps every plan valid for ``m >= 1`` schemes while
still hitting writes, GC passes, and checkpoint saves at arbitrary phases.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["FaultEvent", "FaultPlan", "FaultInjector"]


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    t_us: float
    kind: str          # "fail" | "rebuild"
    drive: int
    interval_us: float = 0.0  # rebuild pacing; 0 => one-burst rebuild

    def __post_init__(self) -> None:
        if self.kind not in ("fail", "rebuild"):
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclasses.dataclass
class FaultPlan:
    events: list

    @classmethod
    def scripted(cls, events) -> "FaultPlan":
        """Explicit schedule; events are sorted by fire time."""
        evs = sorted(events, key=lambda e: e.t_us)
        return cls(events=evs)

    @classmethod
    def probabilistic(
        cls,
        *,
        n_drives: int,
        horizon_us: float,
        mtbf_us: float,
        repair_after_us: float,
        seed: int,
        rebuild_interval_us: float = 0.0,
    ) -> "FaultPlan":
        """Seeded fail/repair cycles: exponential inter-failure gaps with
        mean ``mtbf_us``, uniform victim drive, fixed repair delay.  Cycles
        are serialized (a drive is always repaired before the next failure),
        so plans stay valid for single-parity schemes."""
        rng = np.random.default_rng(seed)
        events: list[FaultEvent] = []
        t = float(rng.exponential(mtbf_us))
        while t < horizon_us:
            drive = int(rng.integers(0, n_drives))
            events.append(FaultEvent(t_us=t, kind="fail", drive=drive))
            t_repair = t + repair_after_us
            events.append(
                FaultEvent(t_us=t_repair, kind="rebuild", drive=drive,
                           interval_us=rebuild_interval_us)
            )
            t = t_repair + float(rng.exponential(mtbf_us))
        return cls(events=events)


class FaultInjector:
    """Arms a :class:`FaultPlan` on a timed ``HandlerPipeline``."""

    def __init__(self, pipeline, plan: FaultPlan):
        assert pipeline.engine is not None, "fault injection requires a timed pipeline"
        self.pipeline = pipeline
        self.plan = plan
        self.log: list[tuple[float, str, int]] = []

    def arm(self) -> "FaultInjector":
        for ev in self.plan.events:
            self.pipeline.engine.at(ev.t_us, self._fire, ev)
        return self

    def _fire(self, ev: FaultEvent) -> None:
        pipe = self.pipeline
        self.log.append((pipe.engine.now, ev.kind, ev.drive))
        if ev.kind == "fail":
            pipe.array.fail_drive(ev.drive)
        elif ev.interval_us > 0.0:
            pipe._ev_rebuild_start(ev.drive, ev.interval_us)
        else:
            pipe._ev_rebuild(ev.drive)
