"""Fault-injection harness: drive and media faults on the virtual clock.

Wraps the timed pipeline's failure/rebuild actors and the drives'
media-fault hooks in a declarative plan so tests and benchmarks can
inject faults mid-write, mid-GC, or mid-rebuild and assert the array
stays available throughout:

* :class:`FaultEvent` -- one scheduled fault.  Drive-level kinds:
  ``fail`` and ``rebuild`` (replace + reconstruct).  Media-level kinds
  (PR 10, silent sub-drive faults): ``bit_rot`` (flip a bit in a
  committed block), ``torn_write`` (the tail of the most recent commit
  reverts to erased), ``misdirected_write`` (a victim block is
  overwritten with another block's payload), ``unreadable`` (latent
  sector error: the block reads back UNC).  Media events may pin an
  exact ``(zone, off)`` victim or leave it at -1 to sample uniformly
  from the drive's written blocks at fire time;
* :class:`FaultPlan`  -- an ordered script of events.  Build one
  explicitly (:meth:`FaultPlan.scripted`) or sample from a seeded RNG
  (:meth:`FaultPlan.probabilistic`) -- fail/repair cycles, a weighted
  media-fault mix (``media_mix`` kind weights over a Poisson process
  with mean gap ``media_mtbf_us``), or both in one plan;
* :class:`FaultInjector` -- arms a plan on a ``HandlerPipeline``'s
  engine.  Every fired event is appended to ``injector.log`` as
  ``(t_us, kind, drive)`` so callers can assert what actually happened
  and correlate it with latency samples.

The injector deliberately reuses the array's own entry points
(``fail_drive`` / ``rebuild_drive`` via the pipeline's rebuild actors;
the drives' ``corrupt_*`` hooks), so an injected failure exercises
exactly the degraded-write rotation, paced reconstruction, verify-on-
read, and scrub paths foreground code uses -- nothing is mocked.
Probabilistic fail/rebuild cycles stay serialized (one drive out at a
time) so every plan is valid for ``m >= 1`` schemes; media faults are
an independent process and freely overlap a drive outage.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["FaultEvent", "FaultPlan", "FaultInjector", "MEDIA_KINDS"]

MEDIA_KINDS = ("bit_rot", "torn_write", "misdirected_write", "unreadable")
_DRIVE_KINDS = ("fail", "rebuild")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    t_us: float
    kind: str          # "fail" | "rebuild" | one of MEDIA_KINDS
    drive: int
    interval_us: float = 0.0  # rebuild pacing; 0 => one-burst rebuild
    zone: int = -1     # media kinds: victim zone (-1 => sample at fire time)
    off: int = -1      # media kinds: victim block offset (-1 => sample)
    count: int = 1     # media kinds: blocks hit by this event

    def __post_init__(self) -> None:
        if self.kind not in _DRIVE_KINDS + MEDIA_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclasses.dataclass
class FaultPlan:
    events: list

    @classmethod
    def scripted(cls, events) -> "FaultPlan":
        """Explicit schedule; events are sorted by fire time."""
        evs = sorted(events, key=lambda e: e.t_us)
        return cls(events=evs)

    @classmethod
    def probabilistic(
        cls,
        *,
        n_drives: int,
        horizon_us: float,
        mtbf_us: float | None = None,
        repair_after_us: float = 0.0,
        seed: int,
        rebuild_interval_us: float = 0.0,
        media_mix: dict[str, float] | None = None,
        media_mtbf_us: float | None = None,
        media_count: int = 1,
    ) -> "FaultPlan":
        """Seeded fault sampling over ``[0, horizon_us)``.

        Two independent processes share one RNG stream:

        * **fail/repair cycles** (when ``mtbf_us`` is set): exponential
          inter-failure gaps with mean ``mtbf_us``, uniform victim
          drive, fixed repair delay.  Cycles are serialized (a drive is
          always repaired before the next failure), so plans stay valid
          for single-parity schemes.
        * **media faults** (when ``media_mix`` is set): a Poisson
          process with mean gap ``media_mtbf_us`` whose event kind is
          drawn from the normalized ``media_mix`` weights (keys from
          :data:`MEDIA_KINDS`), uniform victim drive, ``media_count``
          blocks per event; victims are sampled from the drive's
          written blocks at fire time.

        One plan can therefore drive full-drive failures *and* bit rot
        in the same run -- media faults land during outages too, which
        is exactly the double-fault territory scrub must survive.
        """
        rng = np.random.default_rng(seed)
        events: list[FaultEvent] = []
        if mtbf_us is not None:
            t = float(rng.exponential(mtbf_us))
            while t < horizon_us:
                drive = int(rng.integers(0, n_drives))
                events.append(FaultEvent(t_us=t, kind="fail", drive=drive))
                t_repair = t + repair_after_us
                events.append(
                    FaultEvent(t_us=t_repair, kind="rebuild", drive=drive,
                               interval_us=rebuild_interval_us)
                )
                t = t_repair + float(rng.exponential(mtbf_us))
        if media_mix:
            bad = set(media_mix) - set(MEDIA_KINDS)
            if bad:
                raise ValueError(f"unknown media fault kind(s) {sorted(bad)}")
            if media_mtbf_us is None:
                raise ValueError("media_mix requires media_mtbf_us")
            kinds = sorted(media_mix)
            w = np.array([media_mix[k] for k in kinds], dtype=np.float64)
            if w.sum() <= 0:
                raise ValueError("media_mix weights must sum to > 0")
            w = w / w.sum()
            t = float(rng.exponential(media_mtbf_us))
            while t < horizon_us:
                kind = kinds[int(rng.choice(len(kinds), p=w))]
                drive = int(rng.integers(0, n_drives))
                events.append(FaultEvent(t_us=t, kind=kind, drive=drive,
                                         count=media_count))
                t += float(rng.exponential(media_mtbf_us))
        return cls.scripted(events)


class FaultInjector:
    """Arms a :class:`FaultPlan` on a timed ``HandlerPipeline``."""

    def __init__(self, pipeline, plan: FaultPlan, *, seed: int = 0):
        assert pipeline.engine is not None, "fault injection requires a timed pipeline"
        self.pipeline = pipeline
        self.plan = plan
        # Fire-time RNG: victim (zone, off) sampling for media events whose
        # plan left the target at -1 (the written set isn't known plan-time).
        self.rng = np.random.default_rng(seed)
        self.log: list[tuple[float, str, int]] = []

    def arm(self) -> "FaultInjector":
        for ev in self.plan.events:
            self.pipeline.engine.at(ev.t_us, self._fire, ev)
        return self

    def _fire(self, ev: FaultEvent) -> None:
        pipe = self.pipeline
        if ev.kind in MEDIA_KINDS:
            if self._fire_media(ev):
                self.log.append((pipe.engine.now, ev.kind, ev.drive))
            return
        self.log.append((pipe.engine.now, ev.kind, ev.drive))
        if ev.kind == "fail":
            pipe.array.fail_drive(ev.drive)
        elif ev.interval_us > 0.0:
            pipe._ev_rebuild_start(ev.drive, ev.interval_us)
        else:
            pipe._ev_rebuild(ev.drive)

    # -- media faults --------------------------------------------------------

    def _pick_written(self, drive, n: int):
        """Sample ``n`` distinct written (zone, off) victims, or None."""
        mask = drive.written_mask()
        flat = np.flatnonzero(mask.reshape(-1))
        if flat.size == 0:
            return None
        take = self.rng.choice(flat, size=min(n, flat.size), replace=False)
        cap = drive.cfg.zone_cap_blocks
        return take // cap, take % cap

    def _fire_media(self, ev: FaultEvent) -> bool:
        """Apply one media fault; returns False if it had no target (the
        drive is failed/offline or nothing has been written yet)."""
        drive = self.pipeline.array.drives[ev.drive]
        if drive.failed:
            return False
        if ev.kind == "torn_write":
            if ev.zone >= 0:
                zone = ev.zone
            else:
                written = np.flatnonzero(drive.wp > 0)
                if written.size == 0:
                    return False
                zone = int(self.rng.choice(written))
            return drive.corrupt_torn_write(zone, max(1, ev.count)) > 0
        if ev.zone >= 0 and ev.off >= 0:
            zones = np.full(max(1, ev.count), ev.zone, dtype=np.int64)
            offs = np.full(max(1, ev.count), ev.off, dtype=np.int64)
        else:
            picked = self._pick_written(drive, max(1, ev.count))
            if picked is None:
                return False
            zones, offs = picked
        for z, o in zip(zones.tolist(), offs.tolist()):
            if ev.kind == "bit_rot":
                byte = int(self.rng.integers(0, drive.cfg.block_bytes))
                drive.corrupt_bit_rot(z, o, byte=byte,
                                      bit=int(self.rng.integers(0, 8)))
            elif ev.kind == "misdirected_write":
                src = self._pick_written(drive, 1)
                if src is None:
                    return False
                drive.corrupt_misdirected_write(
                    z, o, int(src[0][0]), int(src[1][0])
                )
            else:  # unreadable
                drive.mark_unreadable(z, o)
        return True
