"""Discrete-event timed I/O engine over the functional ZapRAID simulator.

Layers (see DESIGN.md §8):

* :mod:`repro.sim.engine`   -- virtual clock + event heap;
* :mod:`repro.sim.device`   -- ``TimedDrive``: per-zone command queues with
  perfmodel-sampled service times over ``SimZnsDrive``;
* :mod:`repro.sim.workload` -- MSR-style trace parsing + synthetic and
  multi-tenant generators;
* :mod:`repro.sim.stats`    -- per-request latency recording, percentiles,
  BENCH_*.json export.

The timed request pipeline itself lives in :mod:`repro.core.handlers`
(``HandlerPipeline`` with an engine attached); this package holds the
engine-side primitives it schedules on.
"""
from repro.sim.device import ServiceModel, TimedDrive, make_timed_drives, plan_group_appends
from repro.sim.engine import Engine
from repro.sim.faults import FaultEvent, FaultInjector, FaultPlan
from repro.sim.stats import LatencyRecorder
from repro.sim.workload import Request, TenantSpec, multi_tenant, parse_msr_trace, synthetic

__all__ = [
    "Engine",
    "FaultEvent",
    "FaultInjector",
    "FaultPlan",
    "LatencyRecorder",
    "Request",
    "ServiceModel",
    "TenantSpec",
    "TimedDrive",
    "make_timed_drives",
    "multi_tenant",
    "parse_msr_trace",
    "plan_group_appends",
    "synthetic",
]
