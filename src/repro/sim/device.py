"""Timed ZNS drives: per-zone command queues over the functional simulator.

``TimedDrive`` subclasses :class:`repro.core.zns.SimZnsDrive`, so the media
state (data, OOB, write pointers, crash budget) stays exactly the functional
model's; what it adds is *device-time accounting* on every command:

* **Zone Write** -- one in-flight command per zone (§2.1): a write to zone z
  cannot start before the previous write to z completed;
* **Zone Append** -- up to ``append_qd`` (default 4, the ZN540 saturation
  point) commands in flight per zone; per-command service time grows with
  the in-flight depth exactly as the calibrated throughput curve dictates;
* **reads** -- contend with writes for the drive's internal channels;
* **channels** -- every command additionally occupies one of ``n_channels``
  per-drive servers, so heavy writes (GC, rebuild) delay reads and vice
  versa -- the mechanism behind the GC-cliff and degraded-read-under-load
  tails.

Service times are sampled from :mod:`repro.core.perfmodel` means with
multiplicative lognormal jitter from a per-drive seeded RNG.  The jitter is
what makes Zone-Append completion *disorder* emerge from timing: the
fastest command of a batch wins the write pointer (see
``plan_group_appends``), replacing the seeded RNG permutation the functional
array uses standalone.

Bookings are pure arithmetic over floats -- the functional operation itself
executes instantly (see ``repro.sim.engine`` module docstring) -- so a
``TimedDrive`` behaves identically to a ``SimZnsDrive`` as far as every
existing test and recovery path is concerned.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.core import perfmodel as pm
from repro.core.zns import CrashBudget, SimZnsDrive, ZnsConfig
from repro.sim.engine import Engine


@dataclasses.dataclass
class ServiceModel:
    """Per-command service-time distribution parameters."""

    block_bytes: int
    n_channels: int = 4      # internal parallelism shared by reads and writes
    append_qd: int = 4       # max in-flight Zone Appends per zone (ZN540 §2.2)
    read_cmd_max_blocks: int = 8   # a gather splits into commands of this size
    jitter_sigma: float = 0.18  # lognormal sigma on every sampled service time
    cpu_dispatch_us: float = 0.7   # host-side cost arrival -> device submission
    cpu_complete_us: float = 0.5   # host-side completion/callback cost

    def _kib(self, n_blocks: int) -> float:
        return n_blocks * self.block_bytes / 1024.0

    def zone_write_us(self, n_blocks: int) -> float:
        return pm.zone_write_cmd_latency_us(self._kib(n_blocks))

    def zone_append_us(self, n_blocks: int, qd: int) -> float:
        return pm.zone_append_cmd_latency_us(self._kib(n_blocks), qd)

    def read_us(self, n_blocks: int) -> float:
        return pm.read_cmd_latency_us(self._kib(n_blocks))


class TimedDrive(SimZnsDrive):
    """A ``SimZnsDrive`` whose commands occupy virtual device time."""

    def __init__(
        self,
        cfg: ZnsConfig,
        drive_id: int,
        budget: Optional[CrashBudget] = None,
        *,
        engine: Engine,
        service: ServiceModel,
        seed: int = 0,
    ):
        super().__init__(cfg, drive_id, budget)
        self.engine = engine
        self.service = service
        self.jitter_rng = np.random.default_rng(seed)
        # Optional repro.obs.Tracer: every booked command emits a span on
        # this drive's track.  None (the default) costs one attribute test.
        self.tracer = None
        self._trace_track = f"drive{drive_id}"
        self.reset_timing()

    def reset_timing(self) -> None:
        """Discard all queue/channel bookings (fresh hardware at ``now``)."""
        now = self.engine.now
        self.t_zone_free = np.full(self.cfg.n_zones, now)   # Zone Write: 1/zone
        self.za_slots: dict[int, list[float]] = {}          # Zone Append: qd/zone
        self.channels = [now] * self.service.n_channels
        self._planned: dict[int, deque] = {}                # pre-planned append times
        self.chunk_done: dict[tuple[int, int], float] = {}  # (zone, off) -> t_done
        self.busy_us = 0.0                                  # total service time booked

    # -- booking arithmetic -------------------------------------------------

    def _jitter(self) -> float:
        return float(np.exp(self.jitter_rng.normal(0.0, self.service.jitter_sigma)))

    def _grab_channel(self, floor: float) -> float:
        """Earliest start >= floor with a free channel; caller books the end."""
        i = int(np.argmin(self.channels))
        return max(floor, self.channels[i])

    def _book_channel(self, t_done: float) -> None:
        i = int(np.argmin(self.channels))
        self.channels[i] = t_done

    def book_zone_write(self, zone: int, n_blocks: int, floor: float) -> float:
        """Book one Zone Write command; returns its completion time."""
        start = self._grab_channel(max(floor, float(self.t_zone_free[zone])))
        svc = self.service.zone_write_us(n_blocks) * self._jitter()
        done = start + svc
        self.t_zone_free[zone] = done
        self._book_channel(done)
        self.busy_us += svc
        self.engine.touch_io(done)
        if self.tracer is not None:
            self.tracer.span(self._trace_track, "zone_write", start, done,
                             zone=zone, n_blocks=n_blocks)
        return done

    def book_append(self, zone: int, n_blocks: int, floor: float) -> float:
        """Book one Zone Append command; returns its completion time.

        At most ``append_qd`` appends are in flight per zone: when the slots
        are full the command waits for the earliest one to retire.  The
        sampled service time depends on how many siblings are still in
        flight at start (the intra-zone-parallelism curve)."""
        slots = self.za_slots.setdefault(zone, [])
        start = self._grab_channel(floor)
        busy = sorted(s for s in slots if s > start)
        if len(busy) >= self.service.append_qd:
            start = busy[len(busy) - self.service.append_qd]
            busy = [s for s in busy if s > start]
        qd_now = len(busy) + 1
        svc = self.service.zone_append_us(n_blocks, qd_now) * self._jitter()
        done = start + svc
        busy.append(done)
        self.za_slots[zone] = busy[-self.service.append_qd:]
        self._book_channel(done)
        self.busy_us += svc
        self.engine.touch_io(done)
        if self.tracer is not None:
            self.tracer.span(self._trace_track, "zone_append", start, done,
                             zone=zone, n_blocks=n_blocks, qd=qd_now)
        return done

    def book_read(self, n_blocks: int, floor: float) -> float:
        """Book a read of ``n_blocks`` (channel contention; no wp ordering).

        Large gathers (GC valid-block sweeps, rebuild survivor reads) split
        into commands of at most ``read_cmd_max_blocks`` -- each pays the
        NAND access cost, so a whole-zone gather occupies real device time
        instead of amortizing away into one cheap command.  The commands
        fan out across the free channels like a real scatter-read."""
        max_b = max(1, self.service.read_cmd_max_blocks)
        done = floor
        remaining = n_blocks
        while remaining > 0:
            nb = min(remaining, max_b)
            start = self._grab_channel(floor)
            svc = self.service.read_us(nb) * self._jitter()
            t = start + svc
            self._book_channel(t)
            self.busy_us += svc
            done = max(done, t)
            remaining -= nb
            if self.tracer is not None:
                self.tracer.span(self._trace_track, "read", start, t,
                                 n_blocks=nb)
        self.engine.touch_io(done)
        return done

    def plan_completion(self, zone: int, t_done: float) -> None:
        """Queue a pre-planned append completion time (see plan_group_appends)."""
        self._planned.setdefault(zone, deque()).append(t_done)

    def clear_planned(self) -> None:
        """Drop leftover pre-planned times (an aborted group never consumed
        them; a fresh plan must not inherit stale completion timestamps)."""
        self._planned.clear()

    # -- timed command surface (functional op + booking) ----------------------

    def zone_write(self, zone: int, offset: int, blocks, oobs, crcs=None) -> None:
        super().zone_write(zone, offset, blocks, oobs, crcs)
        done = self.book_zone_write(zone, blocks.shape[0], self.engine.now)
        self.chunk_done[(zone, offset)] = done

    def zone_append_commit(self, zone: int, blocks, oobs, crcs=None) -> int:
        off = super().zone_append_commit(zone, blocks, oobs, crcs)
        planned = self._planned.get(zone)
        if planned:
            done = planned.popleft()
            self.engine.touch_io(done)
        else:
            done = self.book_append(zone, blocks.shape[0], self.engine.now)
        self.chunk_done[(zone, off)] = done
        return off

    def zone_append_commit_many(self, zone: int, chunks, oobs, crcs=None) -> np.ndarray:
        offs = super().zone_append_commit_many(zone, chunks, oobs, crcs)
        planned = self._planned.get(zone)
        c = chunks.shape[1]
        for off in offs:
            # the per-zone planned queue is in completion-time order, which
            # is exactly the per-zone issue order of the group committer
            if planned:
                done = planned.popleft()
                self.engine.touch_io(done)
            else:
                done = self.book_append(zone, c, self.engine.now)
            self.chunk_done[(zone, int(off))] = done
        return offs

    def read(self, zone: int, offset: int, n_blocks: int):
        out = super().read(zone, offset, n_blocks)
        self.book_read(n_blocks, self.engine.now)
        return out

    def read_blocks(self, zone: int, offsets):
        out = super().read_blocks(zone, offsets)
        self.book_read(len(offsets), self.engine.now)
        return out

    def read_scattered(self, zones, offsets):
        out = super().read_scattered(zones, offsets)
        self.book_read(len(offsets), self.engine.now)
        return out

    def repair_blocks(self, zone: int, offsets, blocks) -> None:
        # an in-place repair is a write command on the zone's queue: scrub
        # and verify-on-read repairs contend with foreground traffic
        super().repair_blocks(zone, offsets, blocks)
        self.book_zone_write(zone, len(offsets), self.engine.now)

    def replace(self) -> None:
        super().replace()
        self.reset_timing()  # fresh hardware: empty queues, idle channels

    def chunk_completion(self, zone: int, offset: int) -> Optional[float]:
        return self.chunk_done.get((zone, offset))


@dataclasses.dataclass
class CacheServiceModel:
    """Service model for the cache tier: CMB/DRAM-class block reads.

    Deterministic (no jitter) so warm-cache scenarios replay bit- and
    time-identically — the cache benchmark rows gate unscaled in CI."""

    read_us: float = 3.0          # per-command service time at the cache tier
    cmd_max_blocks: int = 16      # a batch of hits splits into commands
    n_channels: int = 8


class TimedCacheDevice:
    """Virtual-time model of the cache device in front of the array.

    Mirrors ``TimedDrive``'s channel booking: a batch of ``n_blocks``
    hits splits into commands of at most ``cmd_max_blocks`` fanned over
    the free channels, each taking a flat ``read_us``.  Completions are
    reported through ``engine.touch_io`` so the handler pipeline's
    ``io_watermark`` convention prices cache hits with zero plumbing."""

    def __init__(self, engine: Engine, model: Optional[CacheServiceModel] = None):
        self.engine = engine
        self.model = model or CacheServiceModel()
        self.tracer = None   # optional repro.obs.Tracer, same contract as
        self.reset_timing()  # TimedDrive.tracer

    def reset_timing(self) -> None:
        self.channels = [self.engine.now] * self.model.n_channels
        self.busy_us = 0.0

    def book_read(self, n_blocks: int, floor: float) -> float:
        max_b = max(1, self.model.cmd_max_blocks)
        done = floor
        remaining = n_blocks
        while remaining > 0:
            nb = min(remaining, max_b)
            i = int(np.argmin(self.channels))
            start = max(floor, self.channels[i])
            t = start + self.model.read_us
            self.channels[i] = t
            self.busy_us += self.model.read_us
            done = max(done, t)
            remaining -= nb
            if self.tracer is not None:
                self.tracer.span("cache-dev", "cache_read", start, t,
                                 n_blocks=nb)
        self.engine.touch_io(done)
        return done


def make_timed_drives(
    n_drives: int,
    cfg: ZnsConfig,
    engine: Engine,
    *,
    service: Optional[ServiceModel] = None,
    budget: Optional[CrashBudget] = None,
    seed: int = 0,
) -> list[TimedDrive]:
    service = service or ServiceModel(block_bytes=cfg.block_bytes)
    budget = budget or CrashBudget(None)
    return [
        TimedDrive(cfg, i, budget, engine=engine, service=service, seed=seed + 101 * i)
        for i in range(n_drives)
    ]


def plan_group_appends(
    drives: list[TimedDrive],
    zone_ids: tuple[int, ...],
    ops: list[tuple[int, int]],
    chunk_blocks: int,
    floor: float,
) -> tuple[list[int], float]:
    """Plan a Zone-Append group: timing decides the completion order.

    ``ops`` is the submission-order list of ``(stripe_index, drive_index)``
    commands of one stripe group.  Every command is booked on its drive's
    zone (qd-limited) starting no earlier than ``floor`` (the group barrier),
    then the batch is sorted by completion time: that order *is* the order
    chunks land at the write pointers -- the fastest command wins.  The
    planned completion times are queued on each drive so the subsequent
    ``zone_append_commit`` calls (issued in the returned order) attribute
    the right time to the right chunk.

    Returns ``(issue_order, group_done_time)``.
    """
    for d in {d for _, d in ops}:
        drives[d].clear_planned()  # stale entries from a crash-aborted group
    done = []
    for idx, (_, d) in enumerate(ops):
        t = drives[d].book_append(zone_ids[d], chunk_blocks, floor)
        done.append((t, idx))
    done.sort()
    for t, idx in done:
        _, d = ops[idx]
        drives[d].plan_completion(zone_ids[d], t)
    return [idx for _, idx in done], done[-1][0]
