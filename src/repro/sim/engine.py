"""Discrete-event simulation core: virtual clock + event heap.

The engine is deliberately tiny: a monotonically advancing virtual clock in
microseconds and a heap of ``(time, seq, callback, args)`` entries.  Events
scheduled for the same instant fire in scheduling order (the ``seq``
tie-break), which keeps every run bit-deterministic for a given workload and
seed -- the property the timed-disorder consistency tests rely on.

Two conventions the rest of ``repro.sim`` builds on:

* **Function-first, time-follows.**  The functional simulator executes state
  changes instantly at the moment an event fires; the timed device layer
  (``repro.sim.device``) *books* the device time those operations would have
  occupied into the future.  Later events observe the bookings as queueing
  delay.  This gives latency-faithful results without rewriting the
  functional array as coroutines.
* **The I/O watermark.**  ``engine.io_watermark`` is bumped by every timed
  device operation to that operation's completion time.  A pipeline stage
  that wants to know "when did the device work triggered by this call
  finish?" resets the watermark to ``now`` before the call and reads it
  after -- the single-threaded event loop makes this race-free.
"""
from __future__ import annotations

import heapq
import math
from typing import Any, Callable


class Engine:
    """Virtual clock (microseconds) + event heap."""

    def __init__(self):
        self.now: float = 0.0
        self.io_watermark: float = 0.0
        self._heap: list[tuple[float, int, Callable, tuple]] = []
        self._seq = 0
        self.events_fired = 0

    # -- scheduling ---------------------------------------------------------

    def at(self, t: float, fn: Callable, *args: Any) -> None:
        """Schedule ``fn(*args)`` at virtual time ``t`` (clamped to now)."""
        heapq.heappush(self._heap, (max(t, self.now), self._seq, fn, args))
        self._seq += 1

    def after(self, delay_us: float, fn: Callable, *args: Any) -> None:
        self.at(self.now + delay_us, fn, *args)

    # -- execution ----------------------------------------------------------

    def run(self, until: float = math.inf) -> int:
        """Fire events in time order until the heap drains (or ``until``).

        Returns the number of events fired.  The clock is left at the last
        fired event's time (it never runs ahead to ``until``: virtual time
        only advances when something happens).
        """
        fired = 0
        while self._heap and self._heap[0][0] <= until:
            t, _, fn, args = heapq.heappop(self._heap)
            self.now = t
            fn(*args)
            fired += 1
        self.events_fired += fired
        return fired

    def pending(self) -> int:
        return len(self._heap)

    def touch_io(self, t_done: float) -> None:
        """Record a timed device completion (see module docstring)."""
        if t_done > self.io_watermark:
            self.io_watermark = t_done

    def mark_io(self) -> float:
        """Reset the I/O watermark to ``now``; returns the mark."""
        self.io_watermark = self.now
        return self.now
