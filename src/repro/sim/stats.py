"""Per-request latency recording for the timed engine.

``LatencyRecorder`` collects one sample per completed request -- tenant, op,
submit and completion virtual times, and an optional per-stage breakdown
(buffer wait, device queueing, device service, post-processing) -- and
reduces them to the distribution figures the paper reports: p50/p95/p99/p999,
mean, max.  ``to_bench_rows`` emits ``(name, us, derived)`` tuples in the
exact shape ``benchmarks.run`` prints and serializes, so timed scenarios
drop into the ``BENCH_*.json`` perf-trajectory format unchanged.
"""
from __future__ import annotations

import dataclasses
import json
from collections import defaultdict
from typing import Optional

import numpy as np

PERCENTILES = (50.0, 95.0, 99.0, 99.9)
_PCT_NAMES = ("p50", "p95", "p99", "p999")


@dataclasses.dataclass(frozen=True)
class Sample:
    tenant: str
    op: str               # "R" | "W"
    t_submit: float
    t_done: float

    @property
    def latency_us(self) -> float:
        return self.t_done - self.t_submit


class LatencyRecorder:
    def __init__(self):
        self.samples: list[Sample] = []
        self.stage_sums: dict[str, float] = defaultdict(float)
        self.stage_counts: dict[str, int] = defaultdict(int)
        # per-tenant breakdown of the same stages, keyed (tenant, stage):
        # the service tier uses it to attribute queue-wait vs service time
        # per client (QoS accounting)
        self.tenant_stage_sums: dict[tuple[str, str], float] = defaultdict(float)
        self.tenant_stage_counts: dict[tuple[str, str], int] = defaultdict(int)
        self.notes: dict[str, float] = defaultdict(float)
        self.note_counts: dict[str, int] = defaultdict(int)

    # -- collection ---------------------------------------------------------

    def record(
        self,
        tenant: str,
        op: str,
        t_submit: float,
        t_done: float,
        stages: Optional[dict[str, float]] = None,
    ) -> None:
        self.samples.append(Sample(tenant, op, t_submit, t_done))
        for k, v in (stages or {}).items():
            self.stage_sums[k] += v
            self.stage_counts[k] += 1
            self.tenant_stage_sums[(tenant, k)] += v
            self.tenant_stage_counts[(tenant, k)] += 1

    def note(self, key: str, value_us: float) -> None:
        """Accumulate an engine-level delay (e.g. group-barrier waits)."""
        self.notes[key] += value_us
        self.note_counts[key] += 1

    # -- reduction ----------------------------------------------------------

    def latencies(self, op: Optional[str] = None, tenant: Optional[str] = None) -> np.ndarray:
        return np.array([
            s.latency_us for s in self.samples
            if (op is None or s.op == op) and (tenant is None or s.tenant == tenant)
        ])

    @staticmethod
    def _reduce(lat: np.ndarray) -> dict:
        """Percentile reduction with an explicit empty-set guard: a tenant
        with zero samples in the selection yields ``n == 0`` and NaN
        figures instead of falling through to ``np.percentile`` on an
        empty array (or a KeyError at the caller)."""
        if lat.size == 0:
            out = {"n": 0, "mean": float("nan"), "max": float("nan")}
            out.update({name: float("nan") for name in _PCT_NAMES})
            return out
        out = {"n": int(lat.size), "mean": float(lat.mean()), "max": float(lat.max())}
        for name, q in zip(_PCT_NAMES, np.percentile(lat, PERCENTILES)):
            out[name] = float(q)
        return out

    def percentiles(self, op: Optional[str] = None, tenant: Optional[str] = None) -> dict:
        """{n, mean, max, p50, p95, p99, p999} over the selected samples."""
        return self._reduce(self.latencies(op, tenant))

    def windowed_percentiles(
        self,
        t_lo: float,
        t_hi: float,
        op: Optional[str] = None,
        tenant: Optional[str] = None,
    ) -> dict:
        """Percentiles over samples *completing* in ``(t_lo, t_hi]``.

        The SLO monitor's view of the world: only completions inside the
        trailing window count, so the figure tracks current conditions
        instead of averaging over the whole run.  Safe on empty windows
        (``n == 0``, NaN figures)."""
        lat = np.array([
            s.latency_us for s in self.samples
            if t_lo < s.t_done <= t_hi
            and (op is None or s.op == op)
            and (tenant is None or s.tenant == tenant)
        ])
        return self._reduce(lat)

    def stage_means(self, tenant: Optional[str] = None) -> dict[str, float]:
        """Mean per-stage delay, optionally restricted to one tenant."""
        if tenant is None:
            return {
                k: self.stage_sums[k] / max(1, self.stage_counts[k])
                for k in sorted(self.stage_sums)
            }
        return {
            k: self.tenant_stage_sums[(t, k)] / max(1, self.tenant_stage_counts[(t, k)])
            for t, k in sorted(self.tenant_stage_sums)
            if t == tenant
        }

    def span_us(self) -> float:
        if not self.samples:
            return 0.0
        return max(s.t_done for s in self.samples) - min(s.t_submit for s in self.samples)

    def throughput_mib_s(self, block_bytes: int, op: str = "W") -> float:
        """Goodput over the virtual-time span.  Block count comes from the
        ``"{op}_blocks"`` note when the pipeline recorded one (multi-block
        requests), else falls back to one block per sample."""
        span = self.span_us()
        if span <= 0:
            return 0.0
        n = self.notes.get(f"{op}_blocks", float(len(self.latencies(op))))
        return n * block_bytes / (span / 1e6) / (1 << 20)

    # -- export -------------------------------------------------------------

    def summary(self) -> dict:
        tenants = sorted({s.tenant for s in self.samples})
        out = {
            "ops": {op: self.percentiles(op=op) for op in ("R", "W")},
            "tenants": {
                t: {
                    **{op: self.percentiles(op=op, tenant=t) for op in ("R", "W")},
                    "stage_means_us": self.stage_means(tenant=t),
                }
                for t in tenants
            },
            "stage_means_us": self.stage_means(),
            "notes_us": {
                k: {"total": self.notes[k], "count": self.note_counts[k]}
                for k in sorted(self.notes)
            },
        }
        return out

    def to_bench_rows(self, prefix: str) -> list[tuple[str, float, str]]:
        """(name, us_per_call, derived) rows, BENCH_*.json-compatible."""
        rows = []
        for op, tag in (("W", "write"), ("R", "read")):
            p = self.percentiles(op=op)
            if p.get("n"):
                rows.append((
                    f"{prefix}/{tag}_p50", p["p50"],
                    f"p99={p['p99']:.1f}us_p999={p['p999']:.1f}us_n={p['n']}",
                ))
        return rows

    def to_json(self, path: str, prefix: str) -> None:
        out = {
            name: {"us_per_call": round(us, 2), "derived": derived}
            for name, us, derived in self.to_bench_rows(prefix)
        }
        with open(path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
            f.write("\n")
