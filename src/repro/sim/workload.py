"""Workloads for the timed engine: trace replay + synthetic generators.

Everything produces a time-ordered list of :class:`Request` -- the open-loop
arrival stream the timed pipeline replays.  Sources:

* ``parse_msr_trace`` -- MSR-Cambridge-style CSV traces
  (``Timestamp,Hostname,DiskNumber,Type,Offset,Size,ResponseTime`` with the
  timestamp in Windows 100 ns ticks), the format the paper's Exp#10-style
  trace evaluations use.  Offsets/sizes in bytes are mapped onto the
  array's logical block space (wrapping, so arbitrarily large traces replay
  against small simulated volumes).
* ``synthetic`` -- sequential / uniform-random / zipfian-hotspot address
  streams with Poisson or bursty (on-off modulated Poisson) arrivals.
* ``multi_tenant`` -- merge several :class:`TenantSpec` streams into one
  arrival-ordered workload; per-request tenant tags flow through to the
  latency recorder so per-tenant QoS (p99 under a noisy neighbour) falls
  out of the stats.

All randomness is drawn from per-tenant seeded generators: a workload is a
pure function of its spec, so timed runs are reproducible bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    t_us: float          # arrival (submission) time, virtual microseconds
    tenant: str
    op: str              # "R" | "W"
    lba: int
    n_blocks: int = 1


# ---------------------------------------------------------------- traces


def parse_msr_trace(
    text: str | Iterable[str],
    *,
    block_bytes: int,
    logical_blocks: int,
    tenant: str = "trace",
    time_scale: float = 1.0,
) -> list[Request]:
    """Parse an MSR-Cambridge-format trace into timestamped requests.

    ``time_scale`` compresses (<1) or stretches (>1) inter-arrival gaps --
    handy for replaying hour-long traces against seconds of virtual time.
    Lines that do not parse (headers, blanks) are skipped.
    """
    lines = text.splitlines() if isinstance(text, str) else text
    rows: list[tuple[int, str, int, int]] = []
    for line in lines:
        parts = line.strip().split(",")
        if len(parts) < 6:
            continue
        try:
            ticks = int(parts[0])
            offset = int(parts[4])
            size = int(parts[5])
        except ValueError:
            continue  # header or malformed row
        op = "W" if parts[3].strip().lower().startswith("w") else "R"
        n = max(1, -(-size // block_bytes))
        n = min(n, logical_blocks)
        lba = (offset // block_bytes) % (logical_blocks - n + 1)
        rows.append((ticks, op, int(lba), int(n)))
    rows.sort()  # traces are not always time-ordered; rebase after sorting
    if not rows:
        return []
    t0 = rows[0][0]
    return [
        Request((ticks - t0) / 10.0 * time_scale, tenant, op, lba, n)
        for ticks, op, lba, n in rows
    ]


# ---------------------------------------------------------- synthetic streams


def _arrivals(
    rng: np.random.Generator,
    n_ops: int,
    rate_iops: float,
    *,
    burst_factor: float = 1.0,
    burst_on_frac: float = 0.5,
    burst_period_us: float = 10_000.0,
) -> np.ndarray:
    """Open-loop arrival times: Poisson, optionally on-off burst modulated.

    With ``burst_factor > 1`` the stream alternates ON windows (first
    ``burst_on_frac`` of every ``burst_period_us``) at ``burst_factor x``
    the base rate and OFF windows at ``1/burst_factor x`` -- the classic
    bursty multi-tenant client."""
    if burst_factor <= 1.0:
        return np.cumsum(rng.exponential(1e6 / rate_iops, n_ops))
    rate_on = rate_iops * burst_factor
    rate_off = rate_iops / burst_factor
    out = np.empty(n_ops)
    now = 0.0
    for i in range(n_ops):
        on = (now % burst_period_us) < burst_on_frac * burst_period_us
        now += rng.exponential(1e6 / (rate_on if on else rate_off))
        out[i] = now
    return out


def _addresses(
    rng: np.random.Generator,
    kind: str,
    n_ops: int,
    logical_blocks: int,
    n_blocks: int,
    *,
    hot_frac: float = 0.1,
    hot_prob: float = 0.8,
) -> np.ndarray:
    # valid start LBAs are [0, logical_blocks - n_blocks], inclusive -- the
    # same modulus parse_msr_trace uses
    span = max(1, logical_blocks - n_blocks + 1)
    if kind == "seq":
        return (np.arange(n_ops, dtype=np.int64) * n_blocks) % span
    if kind == "uniform":
        return rng.integers(0, span, n_ops)
    if kind == "hotspot":  # zipfian-hotspot: hot_prob of ops on hot_frac of space
        hot_span = max(1, int(span * hot_frac))
        hot = rng.random(n_ops) < hot_prob
        addr = rng.integers(0, span, n_ops)
        addr[hot] = rng.integers(0, hot_span, int(hot.sum()))
        return addr
    if kind == "zipf":  # heavy-tailed ranks scattered over the address space
        ranks = rng.zipf(1.2, n_ops).astype(np.int64) % span
        return (ranks * np.int64(2654435761)) % span  # Knuth-hash dispersion
    raise ValueError(f"unknown address kind: {kind}")


@dataclasses.dataclass
class TenantSpec:
    """One client of a multi-tenant workload.

    ``arrival`` selects the arrival process:

    * ``"open"``   -- open-loop: timestamps are drawn up front (Poisson /
      bursty) and requests are fired at those instants regardless of how
      the device keeps up -- queueing delay is *observed*;
    * ``"closed"`` -- closed-loop: a fixed ``window`` of requests is kept
      outstanding and the next one is submitted only when a previous one
      completes (plus ``think_time_us``).  Submission times therefore
      depend on completions, so ``synthetic`` emits the op/address stream
      with ``t_us = 0`` and a driver with completion callbacks -- see
      :class:`repro.service.ClosedLoopClient` -- assigns the real times.
      This is the knob queue-depth sweeps are expressed with.
    """

    name: str
    kind: str = "uniform"        # seq | uniform | hotspot | zipf
    n_ops: int = 1000
    rate_iops: float = 20_000.0
    read_frac: float = 0.0
    n_blocks: int = 1
    burst_factor: float = 1.0    # >1 => bursty on-off arrivals
    burst_on_frac: float = 0.5
    burst_period_us: float = 10_000.0
    hot_frac: float = 0.1
    hot_prob: float = 0.8
    seed: int = 0
    arrival: str = "open"        # open | closed
    window: int = 4              # closed-loop outstanding-request window
    think_time_us: float = 0.0   # closed-loop delay completion -> next submit


def synthetic(spec: TenantSpec, logical_blocks: int) -> list[Request]:
    """Generate one tenant's request stream.

    Open-loop specs carry real arrival timestamps; closed-loop specs carry
    the deterministic op/address sequence with ``t_us = 0`` (the submission
    instants are decided at run time by the closed-loop driver)."""
    if spec.arrival not in ("open", "closed"):
        raise ValueError(f"unknown arrival mode: {spec.arrival}")
    rng = np.random.default_rng(spec.seed + 0x5EED)
    if spec.arrival == "closed":
        t = np.zeros(spec.n_ops)
    else:
        t = _arrivals(
            rng, spec.n_ops, spec.rate_iops,
            burst_factor=spec.burst_factor,
            burst_on_frac=spec.burst_on_frac,
            burst_period_us=spec.burst_period_us,
        )
    addr = _addresses(
        rng, spec.kind, spec.n_ops, logical_blocks, spec.n_blocks,
        hot_frac=spec.hot_frac, hot_prob=spec.hot_prob,
    )
    is_read = rng.random(spec.n_ops) < spec.read_frac
    return [
        Request(float(t[i]), spec.name, "R" if is_read[i] else "W",
                int(addr[i]), spec.n_blocks)
        for i in range(spec.n_ops)
    ]


def multi_tenant(specs: list[TenantSpec], logical_blocks: int) -> list[Request]:
    """Merge tenant streams into one arrival-ordered workload."""
    reqs: list[Request] = []
    for spec in specs:
        if spec.arrival != "open":
            raise ValueError(
                f"tenant {spec.name!r}: closed-loop streams have no arrival "
                "times to merge on; drive them with repro.service.ClosedLoopClient"
            )
        reqs.extend(synthetic(spec, logical_blocks))
    reqs.sort(key=lambda r: (r.t_us, r.tenant))
    return reqs
