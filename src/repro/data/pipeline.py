"""Deterministic synthetic data pipeline.

Produces per-step training batches from a counter-based PRNG so every host
generates exactly its own shard with no communication, and a restart from a
checkpointed step reproduces the identical stream (the property the
checkpoint/restart tests assert).  Real deployments would substitute a
tokenized corpus reader with the same ``(step) -> global batch`` contract.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0


def batch_for_step(dc: DataConfig, cfg: ModelConfig, step: int):
    """Global batch for ``step`` (tokens + labels (+ stub modality inputs))."""
    key = jax.random.fold_in(jax.random.PRNGKey(dc.seed), step)
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(
        ks[0], (dc.global_batch, dc.seq_len + 1), 0, dc.vocab, jnp.int32
    )
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if cfg.family == "encdec":
        batch["frames"] = 0.02 * jax.random.normal(
            ks[1], (dc.global_batch, cfg.enc_len, cfg.d_model), jnp.float32
        )
    if cfg.family == "vlm":
        batch["vis_embeds"] = 0.02 * jax.random.normal(
            ks[2], (dc.global_batch, cfg.vis_prefix_len, cfg.vis_embed_dim),
            jnp.float32,
        )
    return batch


def host_shard(batch, host_index: int, n_hosts: int):
    """Slice a global batch to this host's rows (per-host data loading)."""
    def slc(x):
        per = x.shape[0] // n_hosts
        return x[host_index * per : (host_index + 1) * per]
    return jax.tree.map(slc, batch)


def batch_specs(dc: DataConfig, cfg: ModelConfig, mesh):
    """PartitionSpecs for a batch (batch dim over the data axes)."""
    from repro.distributed import sharding as sh

    specs = {
        "tokens": sh.data_spec(mesh, 2),
        "labels": sh.data_spec(mesh, 2),
    }
    if cfg.family == "encdec":
        specs["frames"] = sh.data_spec(mesh, 3)
    if cfg.family == "vlm":
        specs["vis_embeds"] = sh.data_spec(mesh, 3)
    return specs
