"""Checkpoint traffic at scale under latency-sensitive serving.

The PR-6 service tier end to end, at API level:

1. build a timed ZapRAID pipeline and wrap it in the async
   ``BlockDeviceService`` (submission queues + dispatcher + completion
   queue; acks fire at device-completion times on the virtual clock);
2. register a latency-class "serve" tenant and several throughput-class
   training jobs, each with its own ``CheckpointEngine`` window on the
   shared array;
3. stream concurrent checkpoint saves through the service while serving
   reads run alongside, then restore one job's checkpoint through the
   same path and verify it bit-identical;
4. print the per-tenant queue-wait/service split and the QoS-vs-FIFO
   p99 comparison.

Run: PYTHONPATH=src python examples/ckpt_under_serving.py
"""
import numpy as np

from repro.checkpoint.zapraid_ckpt import (
    MANIFEST_LBAS,
    CheckpointConfig,
    CheckpointEngine,
)
from repro.core.handlers import HandlerPipeline
from repro.service import LATENCY, BlockDeviceService, QosClass
from repro.service.scenario import _precondition_region
from repro.sim.workload import TenantSpec, synthetic

N_JOBS = 3


def run(policy: str) -> dict:
    cfg = CheckpointConfig(zone_cap_blocks=2048, n_zones=32)
    serve_blocks = 1024
    span = MANIFEST_LBAS + 512
    logical = serve_blocks + N_JOBS * span

    pipe = HandlerPipeline.build_timed(cfg.zap_cfg(logical), cfg.zns_cfg(),
                                       seed=0, flush_interval_us=200.0)
    _precondition_region(pipe, 0, serve_blocks, seed=7)

    svc = BlockDeviceService(pipe, max_inflight=8, policy=policy)
    svc.register("serve", LATENCY)
    ckpt_qos = QosClass("ckpt", priority=2, max_inflight=4)
    jobs = []
    for j in range(N_JOBS):
        svc.register(f"job{j}", ckpt_qos)
        jobs.append(CheckpointEngine(cfg, logical, array=pipe.array,
                                     lba_base=serve_blocks + j * span,
                                     lba_span=span))

    # serving traffic: open-loop latency-class reads over the hot region
    for r in synthetic(TenantSpec(name="serve", kind="hotspot", n_ops=400,
                                  rate_iops=40_000.0, read_frac=1.0),
                       serve_blocks):
        svc.submit_read("serve", r.lba, r.n_blocks, at=r.t_us)

    # checkpoint traffic: every job saves twice on a staggered cadence
    rng = np.random.default_rng(11)
    states = [
        {f"layer{i}": rng.standard_normal(4096).astype(np.float32)
         for i in range(12)}
        for _ in range(N_JOBS)
    ]
    tickets = []
    for j in range(N_JOBS):
        for step in range(2):
            t = 100.0 + j * 700.0 + step * 2_000.0
            pipe.engine.at(t, lambda j=j, s=step: tickets.append(
                jobs[j].save_async(s, states[j], service=svc,
                                   tenant=f"job{j}")))
    svc.drain()
    assert all(t.done for t in tickets)

    # restore job 0's last checkpoint through the same service path
    rt = jobs[0].restore_async(1, states[0], service=svc, tenant="job0")
    svc.drain()
    assert all(np.array_equal(np.asarray(rt.state[k]), states[0][k])
               for k in states[0])

    serve = svc.recorder.percentiles(op="R", tenant="serve")
    stages = svc.recorder.summary()["tenants"]["serve"]["stage_means_us"]
    saves = [t.latency_us for t in tickets]
    print(f"[{policy:4s}] serve p50 {serve['p50']:7.1f}us  "
          f"p99 {serve['p99']:7.1f}us  "
          f"(queue-wait {stages['queue_wait_us']:.1f}us / "
          f"service {stages['service_us']:.1f}us) | "
          f"ckpt save mean {np.mean(saves):7.1f}us | "
          f"restore bit-identical, resolved at t={rt.t_done:.0f}us")
    return {"p99": serve["p99"]}


def main():
    res = {pol: run(pol) for pol in ("qos", "fifo")}
    print(f"QoS cuts the serving tenant's read p99 by "
          f"{res['fifo']['p99'] / res['qos']['p99']:.1f}x vs FIFO")


if __name__ == "__main__":
    main()
