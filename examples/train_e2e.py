"""End-to-end training driver.

Default: a fast CPU demonstration (reduced smollm config, 20 steps) with
ZapRAID checkpointing, a storage-lane failure at step 8, and a simulated
preemption + restore at step 14.

``--full`` trains the real smollm-135m (~135M params, the assignment's
~100M-scale model) for 200 steps -- sized for a real accelerator host.

Run: PYTHONPATH=src python examples/train_e2e.py
"""
import sys

sys.argv = [sys.argv[0]] + (
    ["--arch", "smollm-135m", "--steps", "20", "--ckpt-every", "5",
     "--fail-lane", "2", "--fail-at", "8", "--restart-at", "14",
     "--global-batch", "8", "--seq-len", "64"]
    if "--full" not in sys.argv
    else ["--arch", "smollm-135m", "--steps", "200", "--ckpt-every", "25",
          "--global-batch", "32", "--seq-len", "2048"]
)
if "--full" in sys.argv:
    sys.argv.remove("--full")

from repro.launch import train

train.run(sys.argv[1:])
