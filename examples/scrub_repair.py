"""End-to-end data integrity: silent corruption, scrub, self-repair.

The PR-10 integrity layer on the timed pipeline:

1. build a timed raid6 ZapRAID pipeline with ``verify_reads`` on and a
   :class:`~repro.obs.MetricsSampler` recording the stock metric catalog
   (now including the ``integrity/*`` counters) every 100 virtual us;
2. attach a probabilistic fault plan that fires a weighted *media*-fault
   mix -- bit rot, torn writes, misdirected writes, unreadable sectors --
   into the drives while a write stream is in flight;
3. arm the paced :meth:`~repro.core.handlers.HandlerPipeline.schedule_scrub`
   actor: it walks sealed segments on the virtual clock, bulk-verifies
   every block against the per-block CRC32C lane, reconstructs bad blocks
   through parity (or regenerates headers/footers from provenance),
   rewrites them in place, and books its device time in
   ``notes["scrub_device_us"]`` -- yielding whenever foreground I/O is
   queued;
4. drain, run one final scrub pass, and prove the point: every injected
   fault was detected, the media is byte-identical to an intact replay,
   and every logical read returns the reference bytes;
5. export ``out/scrub_metrics.json`` (schema-validated) whose final row
   carries nonzero ``integrity/blocks_repaired`` -- the figure the CI
   demo step asserts on.

Run: PYTHONPATH=src python examples/scrub_repair.py
(also `make scrub-demo`)
"""
import json
import os

import numpy as np

from repro.core.array import ZapRaidConfig
from repro.core.handlers import HandlerPipeline
from repro.core.zns import ZnsConfig
from repro.obs import (MetricsRegistry, MetricsSampler, standard_collector,
                       validate_metrics_series)
from repro.sim.faults import FaultPlan

BB = 256
OUT = os.path.normpath(os.path.join(os.path.dirname(__file__), "..", "out"))


def _pipe(seed: int = 0) -> HandlerPipeline:
    # raid6: the fault mix is hot enough that one stripe can take two
    # hits before the scrub reaches it -- m=2 keeps that repairable
    cfg = ZapRaidConfig(scheme="raid6", n_drives=5, group_size=4,
                        chunk_blocks=1, logical_blocks=128,
                        gc_free_segments_low=1, verify_reads=True)
    zns = ZnsConfig(n_zones=10, zone_cap_blocks=64, block_bytes=BB)
    return HandlerPipeline.build_timed(cfg, zns, seed=seed,
                                       flush_interval_us=200.0)


def main() -> None:
    pipe = _pipe()
    reg = MetricsRegistry()
    sampler = MetricsSampler(pipe.engine, reg, standard_collector(pipe),
                             interval_us=100.0)
    sampler.start(0.0)

    # weighted media-fault mix, Poisson arrivals on the virtual clock
    plan = FaultPlan.probabilistic(
        n_drives=5, horizon_us=4_000.0, seed=11,
        media_mix={"bit_rot": 3.0, "torn_write": 1.0,
                   "misdirected_write": 1.0, "unreadable": 2.0},
        media_mtbf_us=200.0,
    )
    inj = pipe.attach_faults(plan, seed=3)

    # write stream: several overwrite rounds so segments seal under load
    rng = np.random.default_rng(7)
    ref = {}
    t = 0.0
    for _ in range(4):
        for lba in range(0, 128, 2):
            blk = rng.integers(0, 256, (2, BB), dtype=np.uint8)
            pipe.submit_write(lba, blk, at=t)
            ref[lba], ref[lba + 1] = blk[0].copy(), blk[1].copy()
            t += 8.0

    # paced scrub actor starts mid-stream and yields to foreground I/O
    pipe.schedule_scrub(at=1_000.0, interval_us=50.0, n_passes=3)
    pipe.drain()
    # one closing pass picks up faults that landed after the actor's last
    # walk (the plan keeps firing until its horizon)
    totals = pipe.array.scrub_once()
    sampler.sample_once()

    arr = pipe.array
    injected = sum(d.media_faults for d in arr.drives)
    kinds = sorted({k for _, k, _ in inj.log})
    print("paced scrub under a live write stream (virtual-time run):")
    print(f"  media faults injected : {injected:4d}  kinds={kinds}")
    print(f"  scrub passes          : {arr.stats.integrity_scrub_passes:4d}  "
          f"(blocks verified {arr.stats.integrity_scrub_blocks})")
    print(f"  corruptions detected  : "
          f"{arr.stats.integrity_corruptions_detected:4d}  "
          f"(+{arr.stats.integrity_unreadable_hits} unreadable)")
    print(f"  blocks repaired       : {arr.stats.integrity_blocks_repaired:4d}"
          f"  (final pass: {totals['repaired']})")
    print(f"  scrub device time     : "
          f"{pipe.recorder.notes.get('scrub_device_us', 0.0):8.1f}us "
          f"(foreground writes kept priority)")

    assert arr.stats.integrity_blocks_repaired > 0, "demo needs repairs"
    bad = [lba for lba, want in ref.items()
           if not np.array_equal(arr.read(lba, 1)[0], want)]
    assert not bad, f"wrong bytes after scrub: lbas {bad}"
    print(f"  all {len(ref)} logical blocks read back bit-exact -- "
          f"no reader ever saw corrupt data")

    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, "scrub_metrics.json")
    sampler.to_json(path)
    with open(path) as f:
        doc = json.load(f)
    validate_metrics_series(doc)
    last = doc["series"][-1]["counters"]
    assert last.get("integrity/blocks_repaired", 0) > 0
    print(f"\n  wrote {path} ({len(doc['series'])} samples, "
          f"schema-validated; final integrity/blocks_repaired="
          f"{last['integrity/blocks_repaired']:.0f})")


if __name__ == "__main__":
    main()
