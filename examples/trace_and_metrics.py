"""Observability end to end: span traces, metric time-series, SLO control.

The PR-8 obs layer over the checkpoint-under-serving scenario:

1. run the scenario *static* (fixed per-class in-flight shares) to get the
   baseline serving p99 under checkpoint pressure;
2. run it again with the full observability stack attached -- a span
   :class:`~repro.obs.Tracer` threaded through every layer (request
   lifecycle, submission-queue wait, QoS dispatch, per-drive channel
   service, commit barriers, GC/rebuild passes), a
   :class:`~repro.obs.MetricsSampler` recording the metric catalog every
   100 virtual microseconds, and an :class:`~repro.obs.SloMonitor`
   protecting the serving tenant's windowed p99 by dynamically shrinking
   (and later restoring) the checkpoint class's in-flight share;
3. export ``out/trace.json`` -- open it at https://ui.perfetto.dev or
   chrome://tracing -- and ``out/metrics.json``, validating both against
   the schema checkers the CI gate uses;
4. print the static-vs-SLO serving p99 comparison and the monitor's
   actuation history.

Run: PYTHONPATH=src python examples/trace_and_metrics.py
(also `make obs-demo`)
"""
import json
import os

from repro.obs import Tracer, validate_metrics_series, validate_trace_events
from repro.service.scenario import checkpoint_under_serving

OBJECTIVE_US = 150.0
SLO_KW = dict(window_us=1500.0, interval_us=250.0, min_samples=8)
OUT = os.path.normpath(os.path.join(os.path.dirname(__file__), "..", "out"))


def main() -> None:
    print("checkpoint-under-serving, static admission (baseline):")
    static = checkpoint_under_serving(policy="qos", seed=0,
                                      restore_check=False)
    print(f"  serve p50={static['serve_p50_us']:6.1f}us  "
          f"p99={static['serve_p99_us']:6.1f}us  "
          f"ckpt save max={static['ckpt_save_max_us']:7.1f}us")

    print(f"\nsame scenario, SLO monitor (objective p99 <= {OBJECTIVE_US:.0f}us)"
          " + tracer + sampler:")
    tracer = Tracer()
    dyn = checkpoint_under_serving(
        policy="qos", seed=0, restore_check=False,
        slo_objective_us=OBJECTIVE_US, slo_kwargs=dict(SLO_KW),
        tracer=tracer, sampler_interval_us=100.0,
    )
    print(f"  serve p50={dyn['serve_p50_us']:6.1f}us  "
          f"p99={dyn['serve_p99_us']:6.1f}us  "
          f"ckpt save max={dyn['ckpt_save_max_us']:7.1f}us")
    slo = dyn["slo"]
    print(f"  SLO: cap {slo['default_cap']} -> min {slo['min_cap']} "
          f"(final {slo['final_cap']}), {slo['n_shrinks']} shrinks / "
          f"{slo['n_restores']} restores over {slo['ticks']} ticks")
    for a in dyn["slo_actions"]:
        print(f"    t={a['t_us']:7.1f}us  cap={a['cap']}  "
              f"window p99={a['p99_us']:6.1f}us (n={a['n']})")
    print(f"  serving p99 recovered "
          f"{static['serve_p99_us'] / dyn['serve_p99_us']:.2f}x vs static")

    os.makedirs(OUT, exist_ok=True)
    trace_path = os.path.join(OUT, "trace.json")
    metrics_path = os.path.join(OUT, "metrics.json")
    info = tracer.export(trace_path)
    dyn["sampler"].to_json(metrics_path)
    with open(trace_path) as f:
        validate_trace_events(json.load(f)["traceEvents"])
    with open(metrics_path) as f:
        validate_metrics_series(json.load(f))
    print(f"\n  wrote {trace_path} ({info['events']} events, "
          f"{info['dropped']} dropped) -- open at https://ui.perfetto.dev")
    print(f"  wrote {metrics_path} "
          f"({len(dyn['metrics_series'])} samples) -- both schema-validated")


if __name__ == "__main__":
    main()
