"""Fault-tolerance walkthrough: erasure-coded checkpoints + live state parity.

1. Save a training state into the ZapRAID checkpoint log (RAID-6 across 5
   lanes: survives any TWO lane losses).
2. Fail two lanes; restore WITHOUT rebuilding (degraded reads decode).
3. Crash the host; remount the log from the drives (crash consistency 3.4).
4. Beyond-paper: erasure-code live optimizer shards across 4 DP ranks and
   reconstruct a lost rank's shard on-device (no checkpoint read at all).

Run: PYTHONPATH=src python examples/degraded_restore.py
"""
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.state_parity import encode_shards, reconstruct_shard
from repro.checkpoint.zapraid_ckpt import CheckpointConfig, CheckpointEngine

rng = np.random.default_rng(0)
state = {"params": {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)},
         "step": jnp.int32(123)}

eng = CheckpointEngine(
    CheckpointConfig(n_lanes=5, scheme="raid6", group_size=8,
                     block_bytes=512, zone_cap_blocks=256, n_zones=64,
                     chunk_blocks=2),
    logical_blocks=1 << 13,
)
eng.save(123, state)
print("checkpoint saved (RAID-6 over 5 lanes)")

# host crash first (all lanes intact): remount from the log (crash recovery 3.4)
eng = eng.crash_and_remount()
print("crash + remount -> catalog recovered:", 123 in eng.catalog)

# now lose TWO lanes and restore without rebuilding (degraded reads decode)
eng.fail_lane(1)
eng.fail_lane(3)
out = eng.restore(123, state)
ok = np.array_equal(np.asarray(out["params"]["w"]), np.asarray(state["params"]["w"]))
print(f"two lanes failed -> degraded restore correct: {ok} "
      f"({eng.array.stats.degraded_reads} degraded reads)")

# --- live optimizer-state parity across DP ranks (beyond-paper) -----------
k = 4
shards = [{"m": jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)}
          for _ in range(k)]
parity = encode_shards(shards, m=1)
lost = 2
rec = reconstruct_shard(lost, {r: shards[r] for r in range(k) if r != lost},
                        parity, k)
print("lost DP rank 2's optimizer shard reconstructed on-device:",
      np.array_equal(np.asarray(rec["m"]), np.asarray(shards[lost]["m"])))
