"""Always-writable degraded array: survivor-width stripes end to end.

What the PR-9 degraded-write path buys a log-structured RAID array
(DESIGN.md §14):

1. build a timed (3+1) RAID-5 ZapRAID pipeline and replay a uniform
   write stream on the healthy array -- full-width stripe groups;
2. fail a drive mid-stream via the fault-injection harness
   (:mod:`repro.sim.faults`): writes never stall -- new stripe groups
   open at survivor width (2 data + 1 parity on the three healthy
   drives), tagged in OOB headers and the per-group CST;
3. schedule a paced replace-and-rebuild on the virtual clock: the
   rebuild reconstructs the failed member, then the re-widening pass
   relocates every survivor-width group back onto the full drive set;
4. replay the stream once more and compare write p50/p99 across the
   three states, then verify all data survived the round trip.

Run: PYTHONPATH=src python examples/degraded_writes.py
(also `make degraded-demo`)
"""
import dataclasses

import numpy as np


def build_pipe(seed: int = 11):
    from repro.core.array import ZapRaidConfig
    from repro.core.handlers import HandlerPipeline
    from repro.core.zns import ZnsConfig

    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=8,
                        chunk_blocks=1, logical_blocks=192,
                        gc_free_segments_low=1)
    zns = ZnsConfig(n_zones=16, zone_cap_blocks=64, block_bytes=256)
    return HandlerPipeline.build_timed(cfg, zns, seed=seed,
                                       flush_interval_us=200.0)


def write_stream(n_ops: int):
    from repro.sim import TenantSpec, multi_tenant

    return multi_tenant([
        TenantSpec(name="writer", kind="uniform", n_ops=n_ops,
                   rate_iops=50_000, read_frac=0.0, seed=23),
    ], logical_blocks=192)


def replay_now(pipe, load, ref):
    """Replay `load` re-based onto the current virtual clock, mirroring
    payloads into `ref` so the final verify can check the media."""
    from repro.sim import LatencyRecorder

    t0 = pipe.engine.now
    shifted = [dataclasses.replace(r, t_us=r.t_us + t0) for r in load]
    rng = np.random.default_rng(0xFEED)

    def payload(r):
        data = rng.integers(0, 256, (r.n_blocks, 256), dtype=np.uint8)
        ref[r.lba:r.lba + r.n_blocks] = data
        return data

    pipe.recorder = LatencyRecorder()
    rec = pipe.replay(shifted, payload_fn=payload)
    return rec.percentiles(op="W")


def narrow_segments(arr) -> int:
    return sum(1 for r in arr.segments.values()
               if len(r.info.drive_ids) < arr.cfg.n_drives)


def main() -> None:
    pipe = build_pipe()
    arr = pipe.array
    load = write_stream(240)
    ref = np.zeros((192, 256), dtype=np.uint8)

    print("always-writable degraded array (virtual-time figures):")

    healthy = replay_now(pipe, load, ref)
    print(f"  healthy   p50={healthy['p50']:7.1f}us  "
          f"p99={healthy['p99']:7.1f}us  (full-width groups)")

    # drive 1 dies on the virtual clock; the array stays writable
    from repro.sim.faults import FaultEvent, FaultPlan
    pipe.attach_faults(FaultPlan.scripted(
        [FaultEvent(t_us=pipe.engine.now + 5.0, kind="fail", drive=1)]))
    degraded = replay_now(pipe, load, ref)
    print(f"  degraded  p50={degraded['p50']:7.1f}us  "
          f"p99={degraded['p99']:7.1f}us  "
          f"(survivor-width groups: {narrow_segments(arr)} narrow, "
          f"degraded_mode="
          f"{int(any(d.failed for d in arr.drives))})")

    # paced replace-and-rebuild + re-widening pass
    before = narrow_segments(arr)
    pipe.schedule_rebuild(1, at=pipe.engine.now + 10.0, interval_us=20.0)
    pipe.drain()
    print(f"  rebuild   re-widened {before} survivor-width groups "
          f"({narrow_segments(arr)} remain), drive 1 back in rotation")

    rebuilt = replay_now(pipe, load, ref)
    print(f"  rebuilt   p50={rebuilt['p50']:7.1f}us  "
          f"p99={rebuilt['p99']:7.1f}us  "
          f"({rebuilt['p99'] / max(healthy['p99'], 1e-9):.2f}x healthy p99)")

    got = arr.read(0, 192)
    assert np.array_equal(got, ref), "data lost across fail/rebuild!"
    print("  verify    all 192 logical blocks intact across "
          "fail -> degraded writes -> rebuild")


if __name__ == "__main__":
    main()
