"""Warm-cache degraded reads: the PR-7 ZNS cache tier end to end.

What a read cache buys a log-structured RAID array when a drive dies:

1. build a timed ZapRAID pipeline and attach the device-resident
   :class:`~repro.cache.ZnsCacheTier` (zone-structured arena, count-min
   admission, zone-granular CLOCK eviction, cache-device latency on the
   virtual clock);
2. warm the cache with a hotspot read stream outside the measured
   timeline, then fail a drive;
3. replay the same latency-class read stream through the async block
   service twice -- once cold, once warm -- and compare p50/p99: cold,
   every read on the failed drive fans out into k survivor reads and
   queues; warm, the hot set is absorbed at cache latency (bypassing the
   dispatcher window entirely) and the residual misses see idle drives.

Run: PYTHONPATH=src python examples/warm_cache_degraded.py
(also `make cache-demo`)
"""
from repro.service.scenario import degraded_read_cache


def show(row: dict) -> None:
    mode = "warm" if row["warm"] else "cold"
    print(f"  {mode:5s} p50={row['p50_us']:8.1f}us  p99={row['p99_us']:8.1f}us  "
          f"hit_rate={row['hit_rate']:.2f}  "
          f"queue_bypasses={row['cache_bypasses']}")


def main() -> None:
    print("degraded reads, one drive down, hotspot stream "
          "(virtual-time figures):")
    cold = degraded_read_cache(warm=False)
    warm = degraded_read_cache(warm=True)
    show(cold)
    show(warm)
    print(f"  warm cache cuts degraded p99 "
          f"{cold['p99_us'] / warm['p99_us']:.1f}x "
          f"(p50 {cold['p50_us'] / warm['p50_us']:.1f}x)")


if __name__ == "__main__":
    main()
