"""Serving example: prefill + batched decode with KV caches.

Runs a reduced qwen2.5-3b-family model: prefill a batch of prompts, then
decode 16 tokens greedily. The same decode_step is what the decode_32k /
long_500k dry-run cells lower at production shapes.

Run: PYTHONPATH=src python examples/serve.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.config import smoke
from repro.models.model import build_model

cfg = smoke(get_config("qwen2.5-3b"))
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
B, T, NEW = 4, 24, 16
prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)

logits, cache = jax.jit(model.prefill)(params, {"tokens": prompts})
# grow caches for the decode budget
for k in ("k", "v"):
    pad = [(0, 0)] * cache[k].ndim
    pad[2] = (0, NEW)
    cache[k] = jnp.pad(cache[k], pad)

decode = jax.jit(model.decode_step)
tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
out = [tok]
for _ in range(NEW - 1):
    logits, cache = decode(params, cache, tok)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out.append(tok)
gen = jnp.concatenate(out, axis=1)
print(f"prefilled {B}x{T}, decoded {NEW} tokens each:")
print(np.asarray(gen))
