"""Trace replay on the discrete-event timed engine (DESIGN.md §8).

Parses a small embedded MSR-Cambridge-style trace, replays it through the
timed ZapRAID pipeline (virtual clock, per-zone device queues, real group
barriers), then runs a bursty multi-tenant mix and a degraded-read scenario
-- printing the p50/p99 latency figures the functional simulator alone
cannot produce.

Run: PYTHONPATH=src python examples/trace_replay.py
"""
import numpy as np

from repro.core.array import ZapRaidConfig
from repro.core.handlers import HandlerPipeline
from repro.core.zns import ZnsConfig
from repro.sim import TenantSpec, multi_tenant, parse_msr_trace

BLOCK = 512

# A miniature MSR-format trace: Timestamp(100ns),Host,Disk,Type,Offset,Size,RT
TRACE = "\n".join(
    f"12816637200{3061629 + i * 400},src1,0,"
    f"{'Write' if i % 4 else 'Read'},{(i * 7 % 96) * BLOCK},{BLOCK * (1 + i % 2)},0"
    for i in range(200)
)


def build_pipeline(seed=0):
    cfg = ZapRaidConfig(scheme="raid5", n_drives=4, group_size=8,
                        chunk_blocks=1, logical_blocks=128,
                        gc_free_segments_low=1)
    zns = ZnsConfig(n_zones=12, zone_cap_blocks=64, block_bytes=BLOCK)
    pipe = HandlerPipeline.build_timed(cfg, zns, seed=seed)
    rng = np.random.default_rng(seed)
    pipe.precondition(
        (lba, rng.integers(0, 256, (1, BLOCK), dtype=np.uint8))
        for lba in range(128)
    )
    return pipe


def show(tag, rec):
    for op, name in (("W", "write"), ("R", "read")):
        p = rec.percentiles(op=op)
        if p.get("n"):
            print(f"  {tag} {name}: n={p['n']} p50={p['p50']:.1f}us "
                  f"p99={p['p99']:.1f}us p999={p['p999']:.1f}us")


# 1. replay the trace
reqs = parse_msr_trace(TRACE, block_bytes=BLOCK, logical_blocks=128)
print(f"parsed {len(reqs)} trace requests spanning "
      f"{reqs[-1].t_us / 1e3:.1f} ms of virtual time")
rec = build_pipeline(seed=1).replay(reqs)
show("trace", rec)
print(f"  stage means: {({k: round(v, 1) for k, v in rec.stage_means().items()})}")

# 2. bursty multi-tenant mix: who pays for the noisy neighbour?
mix = multi_tenant([
    TenantSpec(name="bursty-writer", kind="hotspot", n_ops=400,
               rate_iops=30_000, burst_factor=3.0, seed=5),
    TenantSpec(name="steady-reader", kind="uniform", n_ops=400,
               rate_iops=15_000, read_frac=1.0, seed=6),
], logical_blocks=128)
rec = build_pipeline(seed=2).replay(mix)
for tenant in ("bursty-writer", "steady-reader"):
    op = "R" if "reader" in tenant else "W"
    p = rec.percentiles(op=op, tenant=tenant)
    print(f"  tenant {tenant}: p50={p['p50']:.1f}us p99={p['p99']:.1f}us")

# 3. degraded reads under load: fail a drive, replay the same read storm
load = multi_tenant([
    TenantSpec(name="reader", kind="uniform", n_ops=500,
               rate_iops=80_000, read_frac=1.0, seed=7),
], logical_blocks=128)
healthy = build_pipeline(seed=3).replay(load).percentiles(op="R")
pipe = build_pipeline(seed=3)
pipe.array.fail_drive(1)
degraded = pipe.replay(load).percentiles(op="R")
print(f"  healthy  read p99: {healthy['p99']:.1f}us")
print(f"  degraded read p99: {degraded['p99']:.1f}us "
      f"({degraded['p99'] / healthy['p99']:.2f}x, "
      f"{pipe.array.stats.degraded_reads} degraded decodes)")
