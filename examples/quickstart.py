"""Quickstart: a ZapRAID array in 40 lines.

Creates a (3+1)-RAID-5 array over four simulated ZNS drives with the
group-based Zone-Append layout, writes a few blocks, fails a drive, and
reads everything back through degraded decoding.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.array import ZapRaidConfig, ZapRAIDArray
from repro.core.zns import ZnsConfig

cfg = ZapRaidConfig(
    scheme="raid5", n_drives=4,
    group_size=16,        # G: stripes per Zone-Append group (paper 3.2)
    chunk_blocks=1, logical_blocks=512, gc_free_segments_low=1,
    use_pallas=True, interpret=True,   # Pallas parity kernels (CPU interpret)
)
zns = ZnsConfig(n_zones=16, zone_cap_blocks=128, block_bytes=4096)
arr = ZapRAIDArray(cfg, zns)

rng = np.random.default_rng(0)
blocks = {lba: rng.integers(0, 256, (1, 4096), dtype=np.uint8) for lba in range(64)}
for lba, blk in blocks.items():
    arr.write(lba, blk)
arr.flush()
print(f"wrote 64 blocks; write amplification = {arr.stats.write_amp():.2f}")

seg = next(iter(arr.segments.values()))
print(f"CST for segment 0 (first group, per drive):\n{seg.cst.table[:, :8]}")

arr.fail_drive(2)
ok = all(np.array_equal(arr.read(l, 1)[0], b[0]) for l, b in blocks.items())
print(f"drive 2 failed -> all reads still correct: {ok} "
      f"(degraded reads: {arr.stats.degraded_reads}, "
      f"CST entries touched: {arr.stats.cst_entries_accessed})")

arr.rebuild_drive(2)
print("drive 2 rebuilt from survivors (full-drive recovery, paper 3.5)")
